//! Bench: the AltUp overhead decomposition — measured latency deltas
//! baseline -> AltUp -> Dense2x at each size, against the paper's claim
//! that predict/correct adds O(dK^2) per token (negligible) while dense
//! widening adds O(d^2 K^2) (quadratic). Also prints the L1 kernels'
//! VMEM/roofline footprints (the TPU-side §Perf evidence).

use altup::experiments::latency;
use altup::runtime::client::Client;
use altup::sim::vmem;

fn main() -> anyhow::Result<()> {
    let client = Client::cpu()?;
    println!("== altup_overhead: widening cost, measured ==");
    let sizes: &[&str] = if std::env::var("ALTUP_BENCH_FULL").is_ok() {
        &["micro", "tiny", "mini"]
    } else {
        &["micro"]
    };
    for size in sizes {
        let base = format!("{size}-baseline");
        let alt = format!("{size}-altup");
        let d2 = format!("{size}-dense2x");
        if !(latency::available(&base) && latency::available(&alt)) {
            continue;
        }
        let lb = latency::measure(&client, &base)?;
        let la = latency::measure(&client, &alt)?;
        print!(
            "{size:<6} baseline {:>8.2} ms | altup {:>8.2} ms ({:+5.1}%)",
            lb.train_s * 1e3,
            la.train_s * 1e3,
            (la.train_s / lb.train_s - 1.0) * 100.0
        );
        if latency::available(&d2) {
            let ld = latency::measure(&client, &d2)?;
            print!(
                " | dense2x {:>8.2} ms ({:+5.1}%)",
                ld.train_s * 1e3,
                (ld.train_s / lb.train_s - 1.0) * 100.0
            );
        }
        println!();
    }

    println!("\n== L1 kernel footprints (TPUv3 VMEM 16 MiB/core) ==");
    for (d, f, k) in [(512usize, 1024usize, 2usize), (768, 2048, 2), (2048, 5120, 4)] {
        println!("model d={d} f={f} K={k}:");
        for fp in vmem::report(d, f, k) {
            println!(
                "  {:<48} vmem(x2buf) {:>9} B  fits={}  MXU={}  AI={:.2} flop/B",
                fp.name,
                fp.vmem_double_buffered,
                fp.fits(),
                fp.uses_mxu,
                fp.arithmetic_intensity
            );
        }
    }
    Ok(())
}
