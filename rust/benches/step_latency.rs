//! Bench: per-variant train/forward step latency (the measured basis of
//! Fig. 1/4 and Tables 3-4's speed columns). `cargo bench --offline`.

use altup::experiments::latency;
use altup::runtime::client::Client;

fn main() -> anyhow::Result<()> {
    println!("== step_latency: measured CPU step time per artifact ==");
    println!("(quick mode measures micro-*; set ALTUP_BENCH_FULL=1 for all sizes)");
    let full = std::env::var("ALTUP_BENCH_FULL").is_ok();
    let client = Client::cpu()?;
    let names = [
        "micro-baseline",
        "micro-altup",
        "micro-altup-k4",
        "micro-sameup",
        "micro-sum",
        "micro-recycled",
        "micro-dense2x",
        "micro-dense4x",
        "micro-seqaltup",
        "micro-strideskip",
        "micro-avgpool",
        "micro-moe",
        "micro-altup-moe",
        "tiny-baseline",
        "tiny-altup",
        "tiny-dense2x",
        "mini-baseline",
        "mini-altup",
        "mini-recycled",
        "mini-dense2x",
    ];
    println!(
        "{:<20} {:>12} {:>12} {:>14}",
        "artifact", "fwd ms", "train ms", "train ex/s"
    );
    let mut base: Option<f64> = None;
    for name in names {
        if !latency::available(name) || (!full && !name.starts_with("micro")) {
            continue;
        }
        let l = latency::measure(&client, name)?;
        if name == "micro-baseline" {
            base = Some(l.train_s);
        }
        let rel = base
            .map(|b| format!(" ({:.2}x micro-base)", l.train_s / b))
            .unwrap_or_default();
        println!(
            "{:<20} {:>12} {:>12.2} {:>14.1}{}",
            name,
            l.forward_s.map(|f| format!("{:.2}", f * 1e3)).unwrap_or_else(|| "-".into()),
            l.train_s * 1e3,
            l.train_examples_per_sec,
            rel
        );
    }
    Ok(())
}
