//! Bench: per-variant train/forward step latency (the measured basis of
//! Fig. 1/4 and Tables 3-4's speed columns). `cargo bench --offline`.
//!
//! Flags (after `--`):
//!   --ab     also measure each artifact with the state cache fully
//!            off (the host-round-trip baseline; same as running under
//!            ALTUP_NO_STATE_CACHE=1) and print the speedup.
//!   --json   write BENCH_step_latency.json with the per-artifact
//!            fwd/train ms, examples/s, and the marshal/exec/transfer
//!            split (implies --ab) — the §Perf trajectory record read
//!            across PRs (see EXPERIMENTS.md).
//!   --json-path <p>  override the output path.
//!
//! Env: ALTUP_BENCH_FULL=1 measures all sizes; ALTUP_NO_DEVICE_CACHE /
//! ALTUP_NO_STATE_CACHE select the default measurement mode.

use altup::experiments::latency;
use altup::runtime::client::Client;
use altup::runtime::session::CacheMode;
use altup::util::cli::Args;
use altup::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    println!("== step_latency: measured CPU step time per artifact ==");
    println!("(quick mode measures micro-*; set ALTUP_BENCH_FULL=1 for all sizes)");
    let full = std::env::var("ALTUP_BENCH_FULL").is_ok() || args.has("full");
    let json_out = args.has("json") || args.has("json-path");
    let ab = args.has("ab") || json_out;
    let client = Client::cpu()?;
    let names = [
        "micro-baseline",
        "micro-altup",
        "micro-altup-k4",
        "micro-sameup",
        "micro-sum",
        "micro-recycled",
        "micro-dense2x",
        "micro-dense4x",
        "micro-seqaltup",
        "micro-strideskip",
        "micro-avgpool",
        "micro-moe",
        "micro-altup-moe",
        "tiny-baseline",
        "tiny-altup",
        "tiny-dense2x",
        "mini-baseline",
        "mini-altup",
        "mini-recycled",
        "mini-dense2x",
    ];
    println!(
        "{:<20} {:>10} {:>10} {:>12} {:>24}{}",
        "artifact",
        "fwd ms",
        "train ms",
        "train ex/s",
        "exec/marshal/xfer ms",
        if ab { "   host-rt ms (speedup)" } else { "" }
    );
    let mut base: Option<f64> = None;
    let mut rows: Vec<(String, Json)> = Vec::new();
    for name in names {
        if !latency::available(name) || (!full && !name.starts_with("micro")) {
            continue;
        }
        let l = latency::measure(&client, name)?;
        if name == "micro-baseline" {
            base = Some(l.train_s);
        }
        // A/B reference: the same step with the cache fully off — every
        // param/opt literal re-marshalled and synced per step.
        let host_rt = if ab {
            Some(latency::measure_with_mode(&client, name, CacheMode::Off)?)
        } else {
            None
        };
        let rel = base
            .map(|b| format!(" ({:.2}x micro-base)", l.train_s / b))
            .unwrap_or_default();
        let ab_col = host_rt
            .as_ref()
            .map(|h| format!("   {:>8.2} ({:.2}x)", h.train_s * 1e3, h.train_s / l.train_s))
            .unwrap_or_default();
        println!(
            "{:<20} {:>10} {:>10.2} {:>12.1} {:>8.2}/{:>6.2}/{:>6.2}{}{}",
            name,
            l.forward_s.map(|f| format!("{:.2}", f * 1e3)).unwrap_or_else(|| "-".into()),
            l.train_s * 1e3,
            l.train_examples_per_sec,
            l.train_exec_s * 1e3,
            l.train_marshal_s * 1e3,
            l.train_transfer_s * 1e3,
            ab_col,
            rel
        );
        if json_out {
            let mut fields: Vec<(&str, Json)> = vec![
                ("train_ms", Json::num(l.train_s * 1e3)),
                ("examples_per_sec", Json::num(l.train_examples_per_sec)),
                (
                    "split_ms",
                    Json::obj(vec![
                        ("exec", Json::num(l.train_exec_s * 1e3)),
                        ("marshal", Json::num(l.train_marshal_s * 1e3)),
                        ("transfer", Json::num(l.train_transfer_s * 1e3)),
                    ]),
                ),
            ];
            if let Some(f) = l.forward_s {
                fields.push(("fwd_ms", Json::num(f * 1e3)));
            }
            if let Some(h) = &host_rt {
                fields.push((
                    "host_roundtrip",
                    Json::obj(vec![
                        ("train_ms", Json::num(h.train_s * 1e3)),
                        ("speedup", Json::num(h.train_s / l.train_s)),
                        (
                            "split_ms",
                            Json::obj(vec![
                                ("exec", Json::num(h.train_exec_s * 1e3)),
                                ("marshal", Json::num(h.train_marshal_s * 1e3)),
                                ("transfer", Json::num(h.train_transfer_s * 1e3)),
                            ]),
                        ),
                    ]),
                ));
            }
            rows.push((name.to_string(), Json::obj(fields)));
        }
    }
    if json_out {
        let path = args.str_or("json-path", "BENCH_step_latency.json");
        let artifacts =
            Json::Obj(rows.into_iter().collect::<std::collections::BTreeMap<_, _>>());
        let doc = Json::obj(vec![
            ("bench", Json::Str("step_latency".into())),
            ("default_mode", Json::Str(format!("{:?}", CacheMode::from_env()))),
            ("ab_mode", Json::Str("Off (full host round-trip)".into())),
            ("artifacts", artifacts),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }
    Ok(())
}
