//! Bench: serving throughput under batch-level vs continuous (slot)
//! scheduling at 1/2/4 replicas, on a mixed short/long prompt workload
//! with EOS-distributed decode lengths (§Perf L5 + L6).
//!
//! Flags (after `--`):
//!   --json             write BENCH_server_throughput.json
//!   --json-path <p>    override the output path
//!   --requests <n>     total requests per configuration (default 384)
//!   --clients <n>      concurrent closed-loop clients (default 32)
//!   --window-ms <n>    router batch window (default 2)
//!   --slots <n>        decode slots per replica (default 0 = batch_size)
//!   --timeout-ms <n>   per-request deadline (default 0 = none)
//!   --kill-replica <r> degraded A/B: replica id to kill (default 1)
//!   --kill-after <c>   degraded A/B: engine call that triggers the
//!                      kill (default 40)
//!   --spec-gamma <g>   §L8 spec-vs-plain A/B draft length (default 4;
//!                      0 skips the A/B)
//!   --spec-dec-len <n> dec_len of the decode-heavy spec A/B workload
//!                      (default 128 — generation-dominated, the
//!                      regime speculative decoding targets)
//!   --paged <0|1>      run the §L9 paged-pool A/Bs and their
//!                      acceptance bars (default 1; 0 skips — small CI
//!                      smokes use this, the bars assume a loaded run)
//!   --qos <0|1>        run the §L10 trace-driven multi-tenant QoS +
//!                      chaos A/B (default 1; 0 skips)
//!   --trace <path>     §L10 load trace to replay (default: the
//!                      checked-in benches/traces/burst_mix.trace)
//!   --trace-limit <n>  replay only the first n trace requests (0 =
//!                      all). A truncated replay keeps the invariant
//!                      checks but skips the overload acceptance bars
//!                      (they assume the full 2x-capacity burst).
//!   --qos-kill-call <c> §L10 chaos schedule: engine call at which
//!                      replica 1 is killed mid-burst (default 600)
//!   --swap <0|1>       run the §L11 rolling-weight-swap A/B on the
//!                      burst trace (default 1; 0 skips)
//!   --swap-kill-call <c> §L11 chaos arm: engine call at which replica
//!                      1 is killed mid-rollout (default 220)
//!   --tp <n>           §L12 group width for the equal-device TP-vs-DP
//!                      crossover A/B (default 2; 0 skips)
//!   --tp-kill-call <c> §L12 shard-kill chaos arm: engine call at
//!                      which shard 1 of the TP group is killed
//!                      (default 40)
//!   --trace-ab <0|1>   run the §L13 span-trace A/Bs: tracing-on vs
//!                      tracing-off overhead, burst-replay phase
//!                      attribution QoS-on vs QoS-off, and the
//!                      slow-link allreduce-share pair (default 1;
//!                      0 skips)
//!   --trace-jsonl <p>  write the QoS-on attribution arm's spans +
//!                      timeline windows as JSONL to <p> (the §L13
//!                      trace contract the CI smoke validates and
//!                      `main trace-report` renders)
//!
//! Besides the L5/L6 grid, the bench runs a §L7 **degraded-mode A/B**
//! (sim engine only): `cont x4` healthy vs `cont x4` with one replica
//! killed mid-run. The supervisor must requeue the crashed replica's
//! in-flight requests, respawn a replacement, and deliver a terminal
//! response for every request; the acceptance bar is degraded QPS >=
//! 65% of healthy QPS.
//!
//! §L8 adds a **spec-vs-plain A/B** (sim engine only): `cont x1` with
//! γ-draft/verify speculation vs `cont x1` plain, on a decode-heavy
//! variant of the workload (dec_len raised so generation dominates).
//! The comparison is decode-token throughput (tokens/s) — speculation
//! changes tokens delivered per full-model step, not request count —
//! and the acceptance bar is >= 1.4x at the Sim default acceptance
//! model (hash coin α = 0.8). Output parity (spec tokens == plain
//! tokens) is `ensure!`d on every run.
//!
//! §L9 adds two **paged-pool A/Bs** (sim engine only — `SimPoolSpec`
//! rides on `SimSpec`). Equal-memory pairs: a pool sized to S
//! monolithic slots' KV (`pages_for(enc_len + dec_len)` pages each)
//! hosts 2S paged slots on the same mixed workload — paging reclaims
//! the padded tail of every short or early-exited row, so mean slot
//! occupancy must reach >= 1.5x at token parity. Shared-prefix: a
//! tenant-skewed workload (4 fixed 96-token system-prompt headers plus
//! short distinct tails) served with the cross-request prefix cache on
//! vs unpaged monolithic at equal slots — >= 40% of prefill tokens
//! must come from cached pages, with identical generated tokens. Both
//! workloads and bars are mirrored draw-for-draw by the Python twin
//! (`python/tools/server_throughput_twin.py`).
//!
//! §L10 adds a **trace-driven multi-tenant QoS + chaos A/B** (sim
//! engine only): the checked-in burst trace (bursty arrivals at >= 2x
//! serving capacity, heavy-tailed prompt lengths, 55/30/15 tenant
//! skew) is replayed open-loop through a paged cont-x2 fleet three
//! ways — QoS on (token buckets + weighted priority queues + overload
//! ladder + autoscale budget) with a `ChaosSpec` killing replica 1
//! mid-burst under page-pool pressure, QoS on without chaos, and QoS
//! off with the same chaos. Bars on the full trace: every request
//! terminal, gold p95 within its SLO despite the kill, >= 80% of
//! sheds absorbed by the lowest class, chaos goodput >= 0.8x of the
//! clean QoS run — while the QoS-off arm shows gold collapsing.
//!
//! §L13 adds the **span-trace A/Bs** (sim engine only): tracing at
//! sample 1.0 must keep >= 0.97x of the untraced QPS on the cont x2
//! workload; the burst trace is replayed healthy QoS-on vs QoS-off
//! with full tracing and every request's e2e latency attributed to
//! the five top-level phases (the shares sum to 1.0 by the tiling
//! invariant — see `coordinator::trace`); and a tp2 slow-link pair
//! shows the narrow AltUp sync as a smaller aggregate allreduce
//! share of engine time than the dense payload. `--trace-jsonl`
//! exports the QoS-on arm's spans for `main trace-report`.
//!
//! Backend: when `make artifacts` has run AND a real PJRT backend is
//! linked, the bench serves the micro-altup artifact; otherwise it
//! falls back to the deterministic sim engine (prefill cost
//! proportional to executed prompt tokens, fused decode-step cost
//! proportional to the slot geometry, generation lengths hash-sampled
//! in [1, dec_len] — see `coordinator::server::SimSpec`), which
//! exercises the identical router/bucketing/slot-scheduler machinery.
//!
//! The A/B the acceptance gate reads: `batch xN` runs run-to-completion
//! `decode_step` batches (every row pays the full `dec_len`); `cont xN`
//! runs the §Perf L6 slot scheduler (prefill/decode_token split, EOS
//! early-exit, iteration-level admission) at the same replica count.

use altup::coordinator::admission::{parse_tenant_spec, TenantSpec};
use altup::coordinator::deploy::{DeployOptions, DeployStatus};
use altup::coordinator::server::{
    BadVersionMode, ChaosSpec, CollectiveSpec, EngineSpec, Request, ServerHandle, ServerOptions,
    ServerStats, SimPoolSpec, SimSpec, SimSwapSpec,
};
use altup::coordinator::trace as trc;
use altup::runtime::artifact::load_named;
use altup::runtime::pages::pages_for;
use altup::runtime::client::Client;
use altup::util::cli::Args;
use altup::util::json::Json;
use altup::util::rng::Rng;
use std::time::{Duration, Instant};

/// 70% short prompts (uniform in [4, enc_len/4)) / 30% long (uniform in
/// [enc_len/2, enc_len)): the mixed workload where always-full padding
/// hurts most. Decode lengths ride along for free: the sim engine
/// samples each row's EOS position from the prompt hash, so the same
/// stream is also a mixed-generation-length workload.
fn mixed_prompts(n: usize, enc_len: usize, vocab: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = if rng.next_f64() < 0.7 {
                rng.range(4, (enc_len / 4).max(5))
            } else {
                rng.range(enc_len / 2, enc_len)
            };
            (0..len).map(|_| rng.range(1, vocab) as i32).collect()
        })
        .collect()
}

/// §L9 tenant-skewed shared-prefix workload: each request is one of
/// `tenants` fixed page-aligned system-prompt headers plus a short
/// distinct tail (uniform in [8, 32)) — the regime where cross-request
/// prefix caching pays. The Python twin's `shared_prefix_prompts`
/// mirrors the draw order token-for-token.
fn shared_prefix_prompts(
    n: usize,
    vocab: usize,
    seed: u64,
    tenants: usize,
    header_len: usize,
) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    let headers: Vec<Vec<i32>> = (0..tenants)
        .map(|_| (0..header_len).map(|_| rng.range(1, vocab) as i32).collect())
        .collect();
    (0..n)
        .map(|_| {
            let t = rng.range(0, tenants);
            let tail = rng.range(8, 32);
            let mut tokens = headers[t].clone();
            tokens.extend((0..tail).map(|_| rng.range(1, vocab) as i32));
            tokens
        })
        .collect()
}

fn drive(
    engine: &EngineSpec,
    opts: ServerOptions,
    prompts: &[Vec<i32>],
    clients: usize,
) -> anyhow::Result<(f64, ServerStats)> {
    let server = ServerHandle::spawn_engine(engine.clone(), opts);
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let sender = server.sender.clone();
        let mine: Vec<Vec<i32>> =
            prompts.iter().skip(c).step_by(clients).cloned().collect();
        joins.push(std::thread::spawn(move || -> anyhow::Result<()> {
            for p in mine {
                let (tx, rx) = std::sync::mpsc::channel();
                sender
                    .send(Request::new(p, tx))
                    .map_err(|_| anyhow::anyhow!("router down"))?;
                // §L7 contract: always a terminal response (tokens or
                // an explicit failure) — a dropped channel is a bug.
                rx.recv().map_err(|_| anyhow::anyhow!("reply channel dropped"))?;
            }
            Ok(())
        }));
    }
    for j in joins {
        j.join().expect("client thread panicked")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    anyhow::ensure!(
        stats.requests + stats.failed == prompts.len(),
        "terminal accounting: {} ok + {} failed != {} submitted",
        stats.requests,
        stats.failed,
        prompts.len()
    );
    Ok((prompts.len() as f64 / wall.max(1e-9), stats))
}

/// One parsed §L10 trace request: arrival offset, tenant index, and
/// the materialized prompt.
struct TraceEvent {
    arrival_us: u64,
    tenant: usize,
    prompt: Vec<i32>,
}

/// Parse a `#altup-trace v1` file (see `python/tools/gen_burst_trace.py`
/// for the format) and materialize prompt tokens from the header seed:
/// one shared SplitMix64 stream, `prompt_len` draws of `range(1,
/// vocab)` per line in file order — bit-identical to the Python twin's
/// loader, so the hash-sampled generation lengths match across the two
/// harnesses. `limit` truncates the request list *before* tokens are
/// drawn; sequential draws make the truncated stream a prefix of the
/// full one.
fn load_trace(path: &str, vocab: usize, limit: usize) -> anyhow::Result<Vec<TraceEvent>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("trace {path}: {e}"))?;
    let mut seed = 0x51C0DEu64;
    let mut rows: Vec<(u64, usize, usize)> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            for tok in rest.split_whitespace() {
                if let Some(v) = tok.strip_prefix("seed=") {
                    let v = v.strip_prefix("0x").unwrap_or(v);
                    seed = u64::from_str_radix(v, 16)
                        .map_err(|e| anyhow::anyhow!("trace {path} seed: {e}"))?;
                }
            }
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(a), Some(t), Some(l)) = (it.next(), it.next(), it.next()) else {
            anyhow::bail!("trace {path} line {line:?}: want `arrival_us tenant prompt_len`");
        };
        rows.push((a.parse()?, t.parse()?, l.parse()?));
    }
    if limit > 0 {
        rows.truncate(limit);
    }
    let mut rng = Rng::new(seed);
    Ok(rows
        .into_iter()
        .map(|(arrival_us, tenant, len)| TraceEvent {
            arrival_us,
            tenant,
            prompt: (0..len).map(|_| rng.range(1, vocab) as i32).collect(),
        })
        .collect())
}

/// Open-loop trace replay: a feeder thread submits each request at its
/// trace arrival offset (tagged with its tenant and the tenant's
/// configured priority) instead of the closed-loop client pool `drive`
/// uses — offered load is set by the trace, not by service capacity,
/// which is what makes overload reachable. Latency/SLO accounting is
/// read server-side from the per-tenant meters.
fn drive_trace(
    engine: &EngineSpec,
    opts: ServerOptions,
    trace: &[TraceEvent],
    tenants: &[TenantSpec],
) -> anyhow::Result<(f64, ServerStats)> {
    let server = ServerHandle::spawn_engine(engine.clone(), opts);
    let sender = server.sender.clone();
    let events: Vec<(u64, usize, u8, Vec<i32>)> = trace
        .iter()
        .map(|e| {
            let prio = tenants.get(e.tenant).map_or(e.tenant as u8, |t| t.priority);
            (e.arrival_us, e.tenant, prio, e.prompt.clone())
        })
        .collect();
    let t0 = Instant::now();
    let feeder = std::thread::spawn(move || {
        let mut replies = Vec::with_capacity(events.len());
        for (at_us, tenant, prio, prompt) in events {
            let due = t0 + Duration::from_micros(at_us);
            if let Some(wait) = due.checked_duration_since(Instant::now()) {
                std::thread::sleep(wait);
            }
            let (tx, rx) = std::sync::mpsc::channel();
            if sender.send(Request::for_tenant(prompt, tx, tenant, prio)).is_err() {
                break;
            }
            replies.push(rx);
        }
        replies
    });
    let replies = feeder.join().expect("trace feeder panicked");
    anyhow::ensure!(
        replies.len() == trace.len(),
        "router disconnected mid-trace: {}/{} submitted",
        replies.len(),
        trace.len()
    );
    for rx in &replies {
        rx.recv().map_err(|_| anyhow::anyhow!("reply channel dropped"))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    anyhow::ensure!(
        stats.requests + stats.failed == trace.len(),
        "terminal accounting: {} ok + {} failed != {} submitted",
        stats.requests,
        stats.failed,
        trace.len()
    );
    // Per-tenant meters must partition the global outcome counts —
    // the invariant the CI chaos smoke re-checks from the JSON.
    let (tok, tfail): (u64, u64) = stats
        .tenants
        .iter()
        .fold((0, 0), |(a, b), m| (a + m.requests, b + m.failed));
    anyhow::ensure!(
        tok as usize == stats.requests && tfail as usize == stats.failed,
        "tenant meters disagree with totals: {tok}+{tfail} vs {}+{}",
        stats.requests,
        stats.failed
    );
    Ok((trace.len() as f64 / wall.max(1e-9), stats))
}

/// One §L11 swap-arm outcome: throughput, server stats, the rollout's
/// terminal verdict, and an order-sensitive FNV hash over every
/// response's token stream (the cross-arm output-parity fingerprint —
/// trace replay answers in submission order, and the sim engine's
/// tokens are a pure function of the prompt, so arms that serve the
/// same versions hash identically regardless of scheduling).
struct SwapRun {
    qps: f64,
    stats: ServerStats,
    status: DeployStatus,
    token_hash: u64,
}

/// §L11 open-loop trace replay with a rollout fired mid-burst:
/// `swap_to` (if any) is `deploy_start`ed once the trace clock passes
/// `swap_at`, the feeder keeps the offered load flowing throughout,
/// and the run does not shut down until the rollout reaches a terminal
/// `DeployStatus` — the swap outcome is part of the measurement, never
/// racing the drain. The per-version ledger partition invariant is
/// `ensure!`d on every run (the CI swap smoke re-checks it from JSON).
fn drive_trace_swap(
    engine: &EngineSpec,
    opts: ServerOptions,
    trace: &[TraceEvent],
    swap_to: Option<EngineSpec>,
    swap_at: Duration,
) -> anyhow::Result<SwapRun> {
    let server = ServerHandle::spawn_engine(engine.clone(), opts);
    let sender = server.sender.clone();
    let events: Vec<(u64, Vec<i32>)> =
        trace.iter().map(|e| (e.arrival_us, e.prompt.clone())).collect();
    let t0 = Instant::now();
    let feeder = std::thread::spawn(move || {
        let mut replies = Vec::with_capacity(events.len());
        for (at_us, prompt) in events {
            let due = t0 + Duration::from_micros(at_us);
            if let Some(wait) = due.checked_duration_since(Instant::now()) {
                std::thread::sleep(wait);
            }
            let (tx, rx) = std::sync::mpsc::channel();
            if sender.send(Request::new(prompt, tx)).is_err() {
                break;
            }
            replies.push(rx);
        }
        replies
    });
    // Fire the rollout from this thread mid-burst (`deploy_start` is
    // non-blocking; the feeder keeps submitting independently).
    let fired = swap_to.is_some();
    if let Some(new_engine) = swap_to {
        if let Some(wait) = (t0 + swap_at).checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        server.deploy_start(new_engine);
    }
    let replies = feeder.join().expect("trace feeder panicked");
    anyhow::ensure!(
        replies.len() == trace.len(),
        "router disconnected mid-trace: {}/{} submitted",
        replies.len(),
        trace.len()
    );
    let mut token_hash: u64 = 0xcbf2_9ce4_8422_2325;
    for rx in &replies {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("reply channel dropped"))?;
        for &t in &resp.tokens {
            token_hash = (token_hash ^ t as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        token_hash ^= (resp.tokens.len() as u64).rotate_left(17);
    }
    // Wall clock stops when the last response lands — the idle wait
    // for a still-probating canary below must not deflate qps.
    let wall = t0.elapsed().as_secs_f64();
    if fired {
        let deadline = Instant::now() + Duration::from_secs(120);
        while !server.deploy_status().terminal() {
            anyhow::ensure!(Instant::now() < deadline, "rollout wedged (never terminal)");
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    let status = server.deploy_status();
    let stats = server.shutdown()?;
    anyhow::ensure!(
        stats.requests + stats.failed == trace.len(),
        "terminal accounting: {} ok + {} failed != {} submitted",
        stats.requests,
        stats.failed,
        trace.len()
    );
    let (vr, vf) = stats
        .deploy
        .versions
        .iter()
        .fold((0u64, 0u64), |(a, b), m| (a + m.requests, b + m.failed));
    anyhow::ensure!(
        vr as usize == stats.requests && vf as usize == stats.failed,
        "per-version ledger disagrees with totals: {vr}+{vf} vs {}+{}",
        stats.requests,
        stats.failed
    );
    Ok(SwapRun { qps: trace.len() as f64 / wall.max(1e-9), stats, status, token_hash })
}

/// Per-tenant outcome rows for the §L10 JSON section. `tenants` names
/// the rows; the QoS-off arm reuses the same spec so the two arms are
/// comparable tenant-by-tenant.
fn tenant_rows(stats: &ServerStats, tenants: &[TenantSpec]) -> Json {
    Json::Arr(
        stats
            .tenants
            .iter()
            .enumerate()
            .filter(|(_, m)| m.active())
            .map(|(i, m)| {
                let name =
                    tenants.get(i).map_or_else(|| format!("tenant-{i}"), |t| t.name.clone());
                Json::obj(vec![
                    ("tenant", Json::str(&name)),
                    ("requests", Json::num(m.requests as f64)),
                    ("failed", Json::num(m.failed as f64)),
                    ("sheds", Json::num(m.sheds as f64)),
                    ("slo_hits", Json::num(m.slo_hits as f64)),
                    ("goodput", Json::num(m.goodput_ratio())),
                    ("p50_ms", Json::num(m.p50_ms())),
                    ("p95_ms", Json::num(m.p95_ms())),
                    ("tokens_generated", Json::num(m.tokens_generated as f64)),
                ])
            })
            .collect(),
    )
}

fn row_json(mode: &str, replicas: usize, qps: f64, stats: &ServerStats) -> Json {
    let mut fields = vec![
        ("mode", Json::str(mode)),
        ("replicas", Json::num(replicas as f64)),
        ("qps", Json::num(qps)),
        ("mean_fill", Json::num(stats.mean_fill())),
        ("waste_ratio", Json::num(stats.waste_ratio())),
        ("prompt_tokens", Json::num(stats.prompt_tokens as f64)),
        ("executed_tokens", Json::num(stats.executed_tokens as f64)),
        ("batches", Json::num(stats.batches as f64)),
        ("tokens_generated", Json::num(stats.tokens_generated as f64)),
        ("early_exit_saved_ratio", Json::num(stats.early_exit_ratio())),
        ("decode_steps", Json::num(stats.decode_steps as f64)),
        ("mean_occupancy", Json::num(stats.occupancy.mean())),
        ("token_ms", Json::num(stats.token_ms())),
        ("p50_ms", Json::num(stats.p50_ms())),
        ("p95_ms", Json::num(stats.p95_ms())),
        ("p99_ms", Json::num(stats.p99_ms())),
    ];
    // §L12: device accounting plus collective telemetry whenever the
    // fleet ran sharded execution groups.
    fields.push(("devices", Json::num(stats.devices as f64)));
    if stats.collectives > 0 {
        fields.extend([
            ("collectives", Json::num(stats.collectives as f64)),
            ("collective_ns", Json::num(stats.collective_ns as f64)),
            (
                "mean_allreduce_ns",
                Json::num(stats.collective_ns as f64 / stats.collectives as f64),
            ),
        ]);
    }
    // §L9: pool telemetry rides along whenever the run served paged.
    if stats.pool.active() {
        fields.extend([
            ("pool_capacity", Json::num(stats.pool.capacity as f64)),
            ("pool_occupancy", Json::num(stats.pool.utilization())),
            ("pool_peak", Json::num(stats.pool.peak_used as f64)),
            ("prefix_hit_rate", Json::num(stats.pool.hit_rate())),
            (
                "prefill_tokens_saved",
                Json::num(stats.pool.prefill_tokens_saved as f64),
            ),
            ("prefix_evictions", Json::num(stats.pool.evictions as f64)),
            ("alloc_stalls", Json::num(stats.pool.alloc_stalls as f64)),
        ]);
    }
    Json::obj(fields)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.usize_or("requests", 384);
    let clients = args.usize_or("clients", 32);
    let window = Duration::from_millis(args.u64_or("window-ms", 2));
    let slots = args.usize_or("slots", 0);
    let timeout_ms = args.u64_or("timeout-ms", 0);
    let kill_replica = args.usize_or("kill-replica", 1);
    let kill_after = args.u64_or("kill-after", 40);
    let spec_gamma = args.usize_or("spec-gamma", 4);
    let spec_dec_len = args.usize_or("spec-dec-len", 128);
    let paged_ab = args.usize_or("paged", 1) != 0;
    let qos_ab = args.usize_or("qos", 1) != 0;
    let trace_path = args.str_or(
        "trace",
        concat!(env!("CARGO_MANIFEST_DIR"), "/benches/traces/burst_mix.trace"),
    );
    let trace_limit = args.usize_or("trace-limit", 0);
    let qos_kill_call = args.u64_or("qos-kill-call", 600);
    let swap_ab = args.usize_or("swap", 1) != 0;
    let swap_kill_call = args.u64_or("swap-kill-call", 220);
    let tp = args.usize_or("tp", 2);
    let tp_kill_call = args.u64_or("tp-kill-call", 40);
    let trace_ab = args.usize_or("trace-ab", 1) != 0;
    let json_out = args.has("json") || args.has("json-path");

    // Pick the backend: real artifact when present and executable,
    // else the deterministic sim engine. dec_len 48 makes generation
    // (not prefill) the dominant cost, mirroring serving reality.
    let client = Client::cpu()?;
    let stub = client.platform() == "cpu-stub";
    let (engine, engine_name, batch_size, enc_len, dec_len, vocab) =
        match (!stub).then(|| load_named("micro-altup")) {
            Some(Ok(a)) => {
                let cfg = a.config.clone();
                (
                    EngineSpec::Artifact { name: "micro-altup".into() },
                    "artifact:micro-altup".to_string(),
                    cfg.batch_size,
                    cfg.enc_len,
                    cfg.dec_len,
                    cfg.vocab_size,
                )
            }
            _ => {
                let spec = SimSpec::new(8, 128, 48);
                let (b, e, d, v) =
                    (spec.batch_size, spec.enc_len, spec.dec_len, spec.vocab_size);
                (EngineSpec::Sim(spec), "sim".to_string(), b, e, d, v)
            }
        };
    println!(
        "== server_throughput: engine={engine_name} batch={batch_size} enc_len={enc_len} \
         dec_len={dec_len} requests={requests} clients={clients} =="
    );
    let prompts = mixed_prompts(requests, enc_len, vocab, 0x5E_0A11);
    let opts = |replicas: usize, bucketed: bool, continuous: bool| ServerOptions {
        batch_window: window,
        replicas,
        bucketed,
        continuous,
        slots,
        request_timeout_ms: (timeout_ms > 0).then_some(timeout_ms),
        // Pinned off so an exported ALTUP_SPEC_GAMMA cannot silently
        // turn speculation on in the plain grid/degraded rows; only
        // the dedicated spec A/B (below) overrides this.
        spec_gamma: 0,
        // §L12: likewise pinned so an exported ALTUP_TP cannot shard
        // the legacy rows; only the TP A/B (below) overrides this.
        tp: 0,
        tp_groups: usize::MAX,
        ..Default::default()
    };

    println!(
        "{:<26} {:>9} {:>10} {:>8} {:>7} {:>7} {:>9} {:>9} {:>9}",
        "config", "qps", "mean fill", "waste", "occup", "saved", "p50 ms", "p95 ms", "p99 ms"
    );
    let report = |label: &str, qps: f64, stats: &ServerStats| {
        println!(
            "{:<26} {:>9.1} {:>10.2} {:>7.1}% {:>7.2} {:>6.1}% {:>9.2} {:>9.2} {:>9.2}",
            label,
            qps,
            stats.mean_fill(),
            stats.waste_ratio() * 100.0,
            stats.occupancy.mean(),
            stats.early_exit_ratio() * 100.0,
            stats.p50_ms(),
            stats.p95_ms(),
            stats.p99_ms()
        );
    };

    // Pre-L5 baseline: one replica, batch-level, everything padded to
    // enc_len.
    let (base_qps, base_stats) = drive(&engine, opts(1, false, false), &prompts, clients)?;
    report("baseline full-length x1", base_qps, &base_stats);

    // The L6 A/B: batch-level vs continuous at equal replica counts.
    let mut rows: Vec<Json> = Vec::new();
    let mut qps_by: Vec<(String, usize, f64, f64)> = Vec::new(); // (mode, replicas, qps, p95)
    for replicas in [1usize, 2, 4] {
        for (mode, continuous) in [("batch", false), ("cont", true)] {
            let (qps, stats) =
                drive(&engine, opts(replicas, true, continuous), &prompts, clients)?;
            report(&format!("{mode} x{replicas}"), qps, &stats);
            qps_by.push((mode.to_string(), replicas, qps, stats.p95_ms()));
            rows.push(row_json(mode, replicas, qps, &stats));
        }
    }

    let find = |mode: &str, replicas: usize| {
        qps_by
            .iter()
            .find(|(m, r, _, _)| m == mode && *r == replicas)
            .map(|(_, _, q, p)| (*q, *p))
            .unwrap_or((0.0, 0.0))
    };

    // §L7 degraded-mode A/B (sim engine only — the fault injector lives
    // in SimSpec): cont x4 with one replica killed mid-run, against the
    // healthy cont x4 just measured. The expected panic prints to
    // stderr — that is the fault firing, not the bench failing.
    let mut degraded_row: Option<Json> = None;
    if let EngineSpec::Sim(base) = &engine {
        let mut spec = base.clone();
        spec.fault.kill_replica = Some(kill_replica);
        spec.fault.kill_after_calls = kill_after;
        let (dq, dstats) =
            drive(&EngineSpec::Sim(spec), opts(4, true, true), &prompts, clients)?;
        report("cont x4 degraded", dq, &dstats);
        let (hq, _) = find("cont", 4);
        let ratio = if hq > 0.0 { dq / hq } else { 0.0 };
        println!(
            "degraded (replica {kill_replica} killed at call {kill_after}): \
             {ratio:.2}x of healthy cont x4 QPS | {} retried, {} restarts, \
             {} failed, terminal {}/{requests}",
            dstats.retries,
            dstats.restarts,
            dstats.failed,
            dstats.requests + dstats.failed
        );
        degraded_row = Some(Json::obj(vec![
            ("kill_replica", Json::num(kill_replica as f64)),
            ("kill_after_calls", Json::num(kill_after as f64)),
            ("healthy_qps", Json::num(hq)),
            ("qps", Json::num(dq)),
            ("qps_ratio", Json::num(ratio)),
            ("retries", Json::num(dstats.retries as f64)),
            ("restarts", Json::num(dstats.restarts as f64)),
            ("sheds", Json::num(dstats.sheds as f64)),
            ("failed", Json::num(dstats.failed as f64)),
            ("terminal", Json::num((dstats.requests + dstats.failed) as f64)),
            ("requests", Json::num(requests as f64)),
        ]));
    }
    // §L8 spec-vs-plain A/B (sim engine only — the draft cost model
    // lives in SimSpec): cont x1 with γ-draft/verify speculation vs
    // cont x1 plain, on a decode-heavy workload variant (dec_len
    // raised so generation, not prefill, dominates — the regime
    // speculative decoding targets). Decode-token throughput is the
    // comparison: speculation changes tokens per full-model step.
    let mut spec_row: Option<Json> = None;
    if let (EngineSpec::Sim(base), true) = (&engine, spec_gamma > 0) {
        let mut sspec = base.clone();
        sspec.dec_len = spec_dec_len;
        // 2x the grid's request count (an A/B over ~2 s runs is inside
        // the scheduler-noise floor of a small shared host), and
        // best-of-2 per arm: decode is deterministic — identical
        // tokens every trial — so trial spread is pure one-sided
        // scheduler noise and the faster trial is the better estimate.
        let spec_requests = requests * 2;
        let sprompts = mixed_prompts(spec_requests, enc_len, vocab, 0x5E_0A11);
        let run_at = |gamma: usize| -> anyhow::Result<(f64, ServerStats)> {
            let mut best: Option<(f64, ServerStats)> = None;
            for _ in 0..2 {
                let mut o = opts(1, true, true);
                o.spec_gamma = gamma;
                let (q, stats) =
                    drive(&EngineSpec::Sim(sspec.clone()), o, &sprompts, clients)?;
                if best.as_ref().is_none_or(|(bq, _)| q > *bq) {
                    best = Some((q, stats));
                }
            }
            Ok(best.expect("at least one trial ran"))
        };
        let (pq, pstats) = run_at(0)?;
        let (sq, sstats) = run_at(spec_gamma)?;
        anyhow::ensure!(
            pstats.tokens_generated == sstats.tokens_generated,
            "spec parity: {} tokens plain vs {} spec",
            pstats.tokens_generated,
            sstats.tokens_generated
        );
        anyhow::ensure!(sstats.spec.verify_steps > 0, "speculation did not engage");
        report(&format!("cont x1 plain dl{spec_dec_len}"), pq, &pstats);
        report(&format!("cont x1 spec g{spec_gamma}"), sq, &sstats);
        let plain_tps = pq * pstats.tokens_generated as f64 / spec_requests as f64;
        let spec_tps = sq * sstats.tokens_generated as f64 / spec_requests as f64;
        let tokens_ratio = if plain_tps > 0.0 { spec_tps / plain_tps } else { 0.0 };
        let accept_rate = sspec.draft.as_ref().map_or(0.0, |d| d.accept_rate);
        println!(
            "speculative g={spec_gamma} (accept coin {accept_rate:.2}): \
             {tokens_ratio:.2}x decode-token throughput \
             ({spec_tps:.0} vs {plain_tps:.0} tok/s), \
             {:.1}% acceptance, {:.2} tokens/verify over {} verify steps",
            sstats.spec.acceptance_rate() * 100.0,
            sstats.spec.tokens_per_verify(),
            sstats.spec.verify_steps
        );
        spec_row = Some(Json::obj(vec![
            ("gamma", Json::num(spec_gamma as f64)),
            ("requests", Json::num(spec_requests as f64)),
            ("dec_len", Json::num(spec_dec_len as f64)),
            ("accept_coin", Json::num(accept_rate)),
            ("plain", row_json("cont-plain", 1, pq, &pstats)),
            ("spec", row_json("cont-spec", 1, sq, &sstats)),
            ("plain_tokens_per_sec", Json::num(plain_tps)),
            ("spec_tokens_per_sec", Json::num(spec_tps)),
            ("tokens_ratio", Json::num(tokens_ratio)),
            ("acceptance_rate", Json::num(sstats.spec.acceptance_rate())),
            ("tokens_per_verify", Json::num(sstats.spec.tokens_per_verify())),
            ("drafted", Json::num(sstats.spec.drafted as f64)),
            ("accepted", Json::num(sstats.spec.accepted as f64)),
            ("verify_steps", Json::num(sstats.spec.verify_steps as f64)),
            ("draft_steps", Json::num(sstats.spec.draft_steps as f64)),
        ]));
    }

    // §L9 paged-pool A/B #1 (sim engine only — `SimPoolSpec` rides on
    // `SimSpec`): equal-memory monolithic-vs-paged pairs. A pool of
    // `pages_for(enc+dec) * S` pages holds exactly S monolithic slots'
    // worth of KV; the paged scheduler runs 2S slots against it on the
    // same mixed workload, reclaiming every padded short-prompt tail
    // and early-exited decode suffix. Bar: best mean-occupancy ratio
    // >= 1.5x at token parity.
    let mut paged_row: Option<Json> = None;
    let mut prefix_row: Option<Json> = None;
    if let (EngineSpec::Sim(base), true) = (&engine, paged_ab) {
        const PAGE_SIZE: usize = 16;
        const PREFIX_TENANTS: usize = 4;
        const PREFIX_HEADER: usize = 96;
        const PREFIX_POOL_PAGES: usize = 128;
        const PREFIX_SLOTS: usize = 8;
        let pages_per_slot = pages_for(enc_len + dec_len, PAGE_SIZE);
        // Hermetic monolithic arm: an exported ALTUP_POOL_PAGES must
        // not silently page the baseline side of the A/B.
        let mono = {
            let mut m = base.clone();
            m.pool = None;
            EngineSpec::Sim(m)
        };
        let mut pairs: Vec<Json> = Vec::new();
        let mut best_slots_ratio = 0.0f64;
        for (mono_slots, paged_slots) in [(2usize, 4usize), (4, 8), (8, 16)] {
            let pool_pages = pages_per_slot * mono_slots;
            let mut mo = opts(1, true, true);
            mo.slots = mono_slots;
            let (mq, ms) = drive(&mono, mo, &prompts, clients)?;
            let mut pspec = base.clone();
            pspec.pool = Some(SimPoolSpec {
                page_size: PAGE_SIZE,
                pool_pages,
                prefix_cache: false,
            });
            let mut po = opts(1, true, true);
            po.slots = paged_slots;
            let (gq, gs) = drive(&EngineSpec::Sim(pspec), po, &prompts, clients)?;
            anyhow::ensure!(
                ms.tokens_generated == gs.tokens_generated,
                "paged parity: {} tokens mono vs {} paged",
                ms.tokens_generated,
                gs.tokens_generated
            );
            let mono_occ = ms.occupancy.mean();
            let ratio = if mono_occ > 0.0 { gs.occupancy.mean() / mono_occ } else { 0.0 };
            best_slots_ratio = best_slots_ratio.max(ratio);
            println!(
                "paged pool={pool_pages}p: mono x{mono_slots} slots occup {:.2} \
                 ({mq:.1} qps) vs paged x{paged_slots} slots occup {:.2} \
                 ({gq:.1} qps) = {ratio:.2}x slots, {} stalls",
                ms.occupancy.mean(),
                gs.occupancy.mean(),
                gs.pool.alloc_stalls
            );
            pairs.push(Json::obj(vec![
                ("pool_pages", Json::num(pool_pages as f64)),
                ("monolithic_slots", Json::num(mono_slots as f64)),
                ("paged_slots", Json::num(paged_slots as f64)),
                ("monolithic", row_json("cont-mono", 1, mq, &ms)),
                ("paged", row_json("cont-paged", 1, gq, &gs)),
                ("slots_ratio", Json::num(ratio)),
                ("qps_ratio", Json::num(if mq > 0.0 { gq / mq } else { 0.0 })),
            ]));
        }
        anyhow::ensure!(
            best_slots_ratio >= 1.5,
            "paged slots-per-replica bar: best {best_slots_ratio:.2}x < 1.5x"
        );
        paged_row = Some(Json::obj(vec![
            ("page_size", Json::num(PAGE_SIZE as f64)),
            ("pages_per_slot", Json::num(pages_per_slot as f64)),
            ("pairs", Json::Arr(pairs)),
            ("slots_ratio", Json::num(best_slots_ratio)),
        ]));

        // §L9 paged-pool A/B #2: tenant-skewed shared-prefix workload
        // (4 fixed 96-token system-prompt headers = 6 full pages each,
        // plus short distinct tails). Prefix cache on vs unpaged
        // monolithic at equal slots: identical generated tokens, and
        // >= 40% of prefill tokens served by mapping cached header
        // pages instead of re-running them.
        let pprompts =
            shared_prefix_prompts(requests, vocab, 0x5E_0A11, PREFIX_TENANTS, PREFIX_HEADER);
        let mut uo = opts(1, true, true);
        uo.slots = PREFIX_SLOTS;
        let (uq, us) = drive(&mono, uo, &pprompts, clients)?;
        let mut fspec = base.clone();
        fspec.pool = Some(SimPoolSpec {
            page_size: PAGE_SIZE,
            pool_pages: PREFIX_POOL_PAGES,
            prefix_cache: true,
        });
        let mut fo = opts(1, true, true);
        fo.slots = PREFIX_SLOTS;
        let (fq, fs) = drive(&EngineSpec::Sim(fspec), fo, &pprompts, clients)?;
        anyhow::ensure!(
            us.tokens_generated == fs.tokens_generated,
            "prefix parity: {} tokens unpaged vs {} paged",
            us.tokens_generated,
            fs.tokens_generated
        );
        let saved = fs.pool.prefill_tokens_saved as f64;
        let saved_ratio = saved / (saved + fs.executed_tokens as f64).max(1.0);
        anyhow::ensure!(
            saved_ratio >= 0.40,
            "prefix-cache bar: {:.1}% prefill tokens saved < 40%",
            saved_ratio * 100.0
        );
        anyhow::ensure!(fs.pool.hit_rate() > 0.0, "prefix cache never hit");
        println!(
            "prefix cache ({PREFIX_TENANTS} tenants, {PREFIX_HEADER}-token headers): \
             {:.1}% prefill tokens saved, hit rate {:.1}%, {} evictions, \
             {:.2}x qps vs unpaged, tokens {} == {}",
            saved_ratio * 100.0,
            fs.pool.hit_rate() * 100.0,
            fs.pool.evictions,
            if uq > 0.0 { fq / uq } else { 0.0 },
            fs.tokens_generated,
            us.tokens_generated
        );
        prefix_row = Some(Json::obj(vec![
            ("page_size", Json::num(PAGE_SIZE as f64)),
            ("tenants", Json::num(PREFIX_TENANTS as f64)),
            ("header_tokens", Json::num(PREFIX_HEADER as f64)),
            ("pool_pages", Json::num(PREFIX_POOL_PAGES as f64)),
            ("slots", Json::num(PREFIX_SLOTS as f64)),
            ("requests", Json::num(requests as f64)),
            ("unpaged", row_json("cont-mono", 1, uq, &us)),
            ("paged", row_json("cont-prefix", 1, fq, &fs)),
            ("prefill_saved_ratio", Json::num(saved_ratio)),
            ("prefix_hit_rate", Json::num(fs.pool.hit_rate())),
            ("qps_ratio", Json::num(if uq > 0.0 { fq / uq } else { 0.0 })),
            ("tokens_match", Json::Bool(true)),
        ]));
    }

    // §L10 trace-driven multi-tenant QoS + chaos A/B (sim engine only —
    // ChaosSpec composes onto SimSpec). The checked-in burst trace is
    // replayed open-loop through a paged cont x2 fleet three ways:
    //   A: QoS on + chaos (replica 1 killed mid-burst, 25% of the page
    //      pool withheld), autoscale budget 2;
    //   B: QoS on, healthy — the goodput baseline;
    //   C: QoS off (passthrough admission), same chaos — the contrast
    //      arm where gold has no priority and no SLO protection.
    let mut qos_row: Option<Json> = None;
    if let (EngineSpec::Sim(base), true) = (&engine, qos_ab) {
        let trace = load_trace(&trace_path, vocab, trace_limit)?;
        anyhow::ensure!(!trace.is_empty(), "empty trace {trace_path}");
        let full = trace_limit == 0;
        let span_s =
            trace.last().map_or(0.0, |e| e.arrival_us as f64 / 1e6).max(1e-9);
        let offered_qps = trace.len() as f64 / span_s;
        let tenant_spec = "free:0:1:250:40:0;silver:1:2:0:0:4000;gold:2:4:0:0:1500";
        let tenants = parse_tenant_spec(tenant_spec);
        const GOLD: usize = 2;
        const FREE: usize = 0;
        let gold_slo_ms = tenants[GOLD].slo_ms as f64;
        // The QoS arms serve paged (the §L9 path is the production
        // one); pool sized to stay tight but serviceable at 8 slots.
        let mut qspec = base.clone();
        qspec.pool =
            Some(SimPoolSpec { page_size: 16, pool_pages: 96, prefix_cache: false });
        let chaos = ChaosSpec {
            kills: vec![(1, qos_kill_call)],
            pool_reserve: 0.25,
            ..ChaosSpec::default()
        };
        let mut cspec = qspec.clone();
        chaos.apply(&mut cspec);
        let qos_opts = || {
            let mut o = opts(2, true, true);
            o.queue_cap = 1024;
            o.tenants = tenants.clone();
            o.autoscale = 2;
            o
        };
        let (hq, hstats) =
            drive_trace(&EngineSpec::Sim(qspec.clone()), qos_opts(), &trace, &tenants)?;
        let (aq, astats) =
            drive_trace(&EngineSpec::Sim(cspec.clone()), qos_opts(), &trace, &tenants)?;
        let off_opts = {
            let mut o = opts(2, true, true);
            o.queue_cap = 1024;
            o
        };
        let (oq, ostats) =
            drive_trace(&EngineSpec::Sim(cspec.clone()), off_opts, &trace, &tenants)?;

        let goodput = |s: &ServerStats| s.tenants.iter().map(|m| m.slo_hits).sum::<u64>();
        let meter = |s: &ServerStats, t: usize| s.tenants.get(t).cloned().unwrap_or_default();
        let (hgood, agood) = (goodput(&hstats), goodput(&astats));
        let goodput_ratio = if hgood > 0 { agood as f64 / hgood as f64 } else { 0.0 };
        let free_shed_share = if astats.sheds > 0 {
            meter(&astats, FREE).sheds as f64 / astats.sheds as f64
        } else {
            1.0
        };
        let (a_gold, o_gold) = (meter(&astats, GOLD), meter(&ostats, GOLD));
        let (cq2, _) = find("cont", 2);
        println!(
            "qos trace ({} reqs over {span_s:.2}s, offered {offered_qps:.0}/s = \
             {:.1}x cont x2 capacity): clean {hq:.1} qps goodput {hgood}, \
             chaos {aq:.1} qps goodput {agood} ({goodput_ratio:.2}x), \
             qos-off chaos {oq:.1} qps",
            trace.len(),
            if cq2 > 0.0 { offered_qps / cq2 } else { 0.0 },
        );
        println!(
            "qos chaos arm: level sheds {} ({:.0}% from free), gold p95 \
             {:.1} ms (slo {gold_slo_ms:.0}) goodput {:.2} | qos-off gold p95 \
             {:.1} ms, {} gold sheds, goodput {:.2}",
            astats.sheds,
            free_shed_share * 100.0,
            a_gold.p95_ms(),
            a_gold.goodput_ratio(),
            o_gold.p95_ms(),
            o_gold.sheds,
            o_gold.goodput_ratio(),
        );
        if full {
            // The §L10 acceptance bars — meaningful only when the whole
            // 2x-capacity burst is replayed (a truncated smoke still
            // runs the invariant ensures inside drive_trace).
            anyhow::ensure!(
                a_gold.p95_ms() <= gold_slo_ms,
                "gold p95 {:.1} ms blew its {gold_slo_ms:.0} ms SLO under chaos",
                a_gold.p95_ms()
            );
            anyhow::ensure!(
                free_shed_share >= 0.80,
                "only {:.0}% of sheds landed on the lowest class",
                free_shed_share * 100.0
            );
            anyhow::ensure!(
                goodput_ratio >= 0.80,
                "chaos goodput {agood} < 0.8x of clean {hgood}"
            );
            anyhow::ensure!(
                o_gold.sheds > 0 || o_gold.p95_ms() > gold_slo_ms,
                "qos-off contrast arm unexpectedly protected gold \
                 (p95 {:.1} ms, 0 sheds)",
                o_gold.p95_ms()
            );
        }
        let run_row = |qps: f64, s: &ServerStats| {
            Json::obj(vec![
                ("qps", Json::num(qps)),
                ("requests", Json::num(s.requests as f64)),
                ("failed", Json::num(s.failed as f64)),
                ("sheds", Json::num(s.sheds as f64)),
                ("retries", Json::num(s.retries as f64)),
                ("restarts", Json::num(s.restarts as f64)),
                ("terminal", Json::num((s.requests + s.failed) as f64)),
                ("goodput", Json::num(goodput(s) as f64)),
                ("tenants", tenant_rows(s, &tenants)),
            ])
        };
        qos_row = Some(Json::obj(vec![
            ("trace", Json::str(&trace_path)),
            ("trace_requests", Json::num(trace.len() as f64)),
            ("trace_span_s", Json::num(span_s)),
            ("offered_qps", Json::num(offered_qps)),
            ("capacity_qps_cont_x2", Json::num(cq2)),
            ("tenant_spec", Json::str(tenant_spec)),
            (
                "chaos_schedule",
                Json::obj(vec![
                    ("kill_replica", Json::num(1.0)),
                    ("kill_at_call", Json::num(qos_kill_call as f64)),
                    ("pool_reserve", Json::num(0.25)),
                ]),
            ),
            ("bars_enforced", Json::Bool(full)),
            ("qos_clean", run_row(hq, &hstats)),
            ("qos_chaos", run_row(aq, &astats)),
            ("qos_off_chaos", run_row(oq, &ostats)),
            ("goodput_ratio_chaos_over_clean", Json::num(goodput_ratio)),
            ("free_shed_share", Json::num(free_shed_share)),
            ("gold_slo_ms", Json::num(gold_slo_ms)),
            ("gold_p95_ms_qos", Json::num(a_gold.p95_ms())),
            ("gold_p95_ms_qos_off", Json::num(o_gold.p95_ms())),
        ]));
    }

    // §L11 rolling-weight-swap A/B (sim engine only — `SimSwapSpec`
    // derives the successor version). The burst trace is replayed
    // open-loop through a paged cont x2 fleet four ways:
    //   0: no swap — the goodput baseline and the token-parity oracle;
    //   1: clean rolling swap fired at 25% of the trace span (successor
    //      at 0.9x step cost, identical tokens) — must Complete;
    //   2: the same swap with a ChaosSpec killing replica 1 mid-rollout
    //      — crash supervision and the rollout must compose;
    //   3: a wrong-token successor — the canary's pinned probe decode
    //      must fail token parity and auto-roll back, with the fleet's
    //      output bit-identical to the no-swap oracle.
    // Bars on the full trace: every request terminal, zero requests
    // failed by the swap itself, per-version ledger partitions the
    // totals (ensure!d inside drive_trace_swap on every run), swap and
    // swap+chaos goodput >= 0.85x the no-swap run, and arms 1-3 all at
    // token parity with arm 0.
    let mut swap_row: Option<Json> = None;
    if let (EngineSpec::Sim(base), true) = (&engine, swap_ab) {
        let trace = load_trace(&trace_path, vocab, trace_limit)?;
        anyhow::ensure!(!trace.is_empty(), "empty trace {trace_path}");
        let full = trace_limit == 0;
        let span_s = trace.last().map_or(0.0, |e| e.arrival_us as f64 / 1e6).max(1e-9);
        let swap_at = Duration::from_secs_f64(span_s * 0.25);
        // Production path: paged decode state, pool roomy enough that
        // the swap arms never shed on pool pressure (PoolExhausted
        // counts as a canary failure — a §L9 capacity problem must not
        // masquerade as a §L11 rollback).
        let mut sspec = base.clone();
        sspec.pool = Some(SimPoolSpec { page_size: 16, pool_pages: 192, prefix_cache: false });
        let swap_opts = || {
            let mut o = opts(2, true, true);
            o.queue_cap = 1024;
            // Explicit gates (env-free): a probation sized to resolve
            // well inside the burst, generous latency headroom (the
            // canary shares the overloaded router queue, so its p95 is
            // queue-dominated like the fleet's), and an idle-promotion
            // clock that finishes a post-trace probation quickly.
            o.deploy = DeployOptions {
                probation: 12,
                probation_ms: 300,
                probes: 2,
                max_err: 0.25,
                lat_factor: 8.0,
                hold_ms: 15_000,
            };
            o
        };
        let upgrade = SimSwapSpec { cost_mult: 0.9, bad: BadVersionMode::None };
        let bad = SimSwapSpec { cost_mult: 0.9, bad: BadVersionMode::WrongTokens };
        let clean = drive_trace_swap(
            &EngineSpec::Sim(sspec.clone()),
            swap_opts(),
            &trace,
            None,
            swap_at,
        )?;
        let swap = drive_trace_swap(
            &EngineSpec::Sim(sspec.clone()),
            swap_opts(),
            &trace,
            Some(EngineSpec::Sim(upgrade.apply(&sspec))),
            swap_at,
        )?;
        let mut kspec = sspec.clone();
        ChaosSpec { kills: vec![(1, swap_kill_call)], ..ChaosSpec::default() }
            .apply(&mut kspec);
        let chaos = drive_trace_swap(
            &EngineSpec::Sim(kspec),
            swap_opts(),
            &trace,
            Some(EngineSpec::Sim(upgrade.apply(&sspec))),
            swap_at,
        )?;
        let rollback = drive_trace_swap(
            &EngineSpec::Sim(sspec.clone()),
            swap_opts(),
            &trace,
            Some(EngineSpec::Sim(bad.apply(&sspec))),
            swap_at,
        )?;

        let ratio = |r: &SwapRun| if clean.qps > 0.0 { r.qps / clean.qps } else { 0.0 };
        println!(
            "swap trace ({} reqs over {span_s:.2}s, rollout at {:.2}s): \
             no-swap {:.1} qps | rolling {:.1} qps ({:.2}x) -> {} | \
             +kill@{swap_kill_call} {:.1} qps ({:.2}x) -> {} | bad-version -> {}",
            trace.len(),
            swap_at.as_secs_f64(),
            clean.qps,
            swap.qps,
            ratio(&swap),
            swap.status,
            chaos.qps,
            ratio(&chaos),
            chaos.status,
            rollback.status,
        );
        println!(
            "swap ledger: rolling v-requests {:?} ({} canary pass) | chaos v-requests {:?} \
             ({} restarts) | bad rollbacks {} ({} canary fail), parity {}",
            swap.stats.deploy.versions.iter().map(|m| m.requests).collect::<Vec<_>>(),
            swap.stats.deploy.canary_pass,
            chaos.stats.deploy.versions.iter().map(|m| m.requests).collect::<Vec<_>>(),
            chaos.stats.restarts,
            rollback.stats.deploy.rollbacks,
            rollback.stats.deploy.canary_fail,
            rollback.token_hash == clean.token_hash,
        );

        // Invariants that hold at any trace length.
        anyhow::ensure!(
            matches!(swap.status, DeployStatus::Completed { .. }),
            "clean rolling swap did not complete: {}",
            swap.status
        );
        anyhow::ensure!(
            matches!(rollback.status, DeployStatus::RolledBack { .. }),
            "bad version was not rolled back: {}",
            rollback.status
        );
        anyhow::ensure!(
            rollback.stats.deploy.rollbacks >= 1 && rollback.stats.deploy.canary_pass == 0,
            "bad version passed a canary gate"
        );
        anyhow::ensure!(
            swap.token_hash == clean.token_hash,
            "clean swap broke token parity: {:016x} vs {:016x}",
            swap.token_hash,
            clean.token_hash
        );
        anyhow::ensure!(
            rollback.token_hash == clean.token_hash,
            "rollback did not pin old-version tokens: {:016x} vs {:016x}",
            rollback.token_hash,
            clean.token_hash
        );
        anyhow::ensure!(
            swap.stats.failed == 0,
            "{} requests failed during the clean rolling swap",
            swap.stats.failed
        );
        if full {
            // Bars that assume the whole 2x-capacity burst.
            anyhow::ensure!(
                matches!(chaos.status, DeployStatus::Completed { .. }),
                "rollout under chaos did not complete: {}",
                chaos.status
            );
            anyhow::ensure!(
                chaos.stats.failed == 0,
                "{} requests lost to swap+kill chaos",
                chaos.stats.failed
            );
            anyhow::ensure!(
                chaos.token_hash == clean.token_hash,
                "swap+chaos broke token parity: {:016x} vs {:016x}",
                chaos.token_hash,
                clean.token_hash
            );
            anyhow::ensure!(
                ratio(&swap) >= 0.85,
                "rolling swap goodput {:.2}x < 0.85x of no-swap",
                ratio(&swap)
            );
            anyhow::ensure!(
                ratio(&chaos) >= 0.85,
                "swap+chaos goodput {:.2}x < 0.85x of no-swap",
                ratio(&chaos)
            );
        }

        let arm_row = |r: &SwapRun| {
            let d = &r.stats.deploy;
            Json::obj(vec![
                ("qps", Json::num(r.qps)),
                ("requests", Json::num(r.stats.requests as f64)),
                ("failed", Json::num(r.stats.failed as f64)),
                ("sheds", Json::num(r.stats.sheds as f64)),
                ("retries", Json::num(r.stats.retries as f64)),
                ("restarts", Json::num(r.stats.restarts as f64)),
                ("terminal", Json::num((r.stats.requests + r.stats.failed) as f64)),
                ("status", Json::str(&r.status.to_string())),
                ("canary_pass", Json::num(d.canary_pass as f64)),
                ("canary_fail", Json::num(d.canary_fail as f64)),
                ("rollbacks", Json::num(d.rollbacks as f64)),
                ("completed", Json::num(d.completed as f64)),
                ("aborted", Json::num(d.aborted as f64)),
                ("token_hash", Json::str(&format!("{:016x}", r.token_hash))),
                (
                    "version_requests",
                    Json::Arr(
                        d.versions
                            .iter()
                            .map(|m| Json::num(m.requests as f64))
                            .collect(),
                    ),
                ),
                (
                    "version_failed",
                    Json::Arr(
                        d.versions.iter().map(|m| Json::num(m.failed as f64)).collect(),
                    ),
                ),
            ])
        };
        swap_row = Some(Json::obj(vec![
            ("trace", Json::str(&trace_path)),
            ("trace_requests", Json::num(trace.len() as f64)),
            ("trace_span_s", Json::num(span_s)),
            ("swap_at_s", Json::num(swap_at.as_secs_f64())),
            ("cost_mult", Json::num(0.9)),
            (
                "chaos_schedule",
                Json::obj(vec![
                    ("kill_replica", Json::num(1.0)),
                    ("kill_at_call", Json::num(swap_kill_call as f64)),
                ]),
            ),
            ("bars_enforced", Json::Bool(full)),
            ("no_swap", arm_row(&clean)),
            ("rolling", arm_row(&swap)),
            ("rolling_chaos", arm_row(&chaos)),
            ("bad_version", arm_row(&rollback)),
            ("goodput_ratio_rolling", Json::num(ratio(&swap))),
            ("goodput_ratio_chaos", Json::num(ratio(&chaos))),
            (
                "token_parity",
                Json::obj(vec![
                    ("rolling", Json::Bool(swap.token_hash == clean.token_hash)),
                    ("rolling_chaos", Json::Bool(chaos.token_hash == clean.token_hash)),
                    ("bad_version_rollback", Json::Bool(rollback.token_hash == clean.token_hash)),
                ]),
            ),
        ]));
    }

    // §L12 equal-device TP-vs-DP crossover A/B (sim engine only — the
    // collective cost model rides on SimSpec). One tp-way execution
    // group (`replicas=1, tp` → tp devices) against tp whole-model DP
    // replicas (`replicas=tp, tp=0` → tp devices) on identical
    // workloads at two load levels:
    //   peak  — the full closed-loop client pool saturates the fleet.
    //           DP wins: tp independent step streams beat one faster
    //           stream on capacity.
    //   light — a single closed-loop client: one request in flight at
    //           a time, so the arms compare pure per-request service
    //           time. The fused step runs the full static
    //           slot geometry, so per-step cost is occupancy-
    //           independent and per-step speed is all that matters:
    //           the group's sharded compute wins p95 — as long as the
    //           collectives stay cheaper than the compute they shave.
    // The 2x2 cost-model grid crosses AltUp's narrow active block
    // (all-reduce payload `d_model/4` per token) against a
    // dense-widened baseline (payload `d_model`) on a fast and a
    // constrained link: on the slow link the dense baseline's
    // collectives eat the sharding win (group p95 falls behind DP)
    // while the AltUp payload keeps the group ahead — the paper's
    // activation-width asymmetry, measured on the wire.
    // Bars (full runs): token parity everywhere, the crossover at the
    // altup/fast point (DP peak QPS wins, group light-load p95 wins),
    // group still ahead on the slow link under the AltUp payload but
    // behind under the dense payload, and per-round all-reduce cost
    // under 0.7x of dense at the same link. A shard-kill chaos arm
    // pins the §L7 contract at group granularity: one follower dies,
    // the whole group requeues and respawns as a group, and token
    // parity holds through the restart.
    let mut tp_row: Option<Json> = None;
    if let (EngineSpec::Sim(base), true) = (&engine, tp >= 2) {
        let full = requests >= 256;
        const TP_DMODEL: usize = 1024;
        // Hermetic: pin every collective knob per point and keep the
        // pool off, so an exported ALTUP_TP_* / ALTUP_POOL_PAGES
        // cannot skew the A/B.
        let mk_spec = |active_width: usize, link_gbps: f64| {
            let mut s = base.clone();
            s.pool = None;
            s.collective = CollectiveSpec {
                d_model: TP_DMODEL,
                active_width,
                elem_bytes: 2,
                link_bps: link_gbps * 1e9,
                latency_ns: 500,
                syncs_per_step: 12,
                partitioned_frac: 0.85,
            };
            s
        };
        let mk_opts = |replicas: usize, tpv: usize| {
            let mut o = opts(replicas, true, true);
            o.tp = tpv;
            o.tp_groups = usize::MAX;
            o
        };
        let lat_clients = 1usize;
        let lat_n = (requests / 2).max(lat_clients).min(prompts.len());
        let lprompts = &prompts[..lat_n];

        // Whole-model single-device references: the token-parity
        // oracle for every arm (sharding changes timing, never
        // tokens) and the 1-device latency baseline.
        let ref_spec = EngineSpec::Sim(mk_spec(TP_DMODEL / 4, 25.0));
        let (ref_q, ref_stats) = drive(&ref_spec, mk_opts(1, 0), &prompts, clients)?;
        report("single-ref (peak)", ref_q, &ref_stats);
        let (lref_q, lref_stats) = drive(&ref_spec, mk_opts(1, 0), lprompts, lat_clients)?;
        report("single-ref (light)", lref_q, &lref_stats);

        struct TpPoint {
            name: &'static str,
            tp_peak_qps: f64,
            dp_peak_qps: f64,
            tp_light_p95: f64,
            dp_light_p95: f64,
            mean_allreduce_ns: f64,
            json: Json,
        }
        let mut pts: Vec<TpPoint> = Vec::new();
        for (name, active_width, link_gbps) in [
            ("altup-25g", TP_DMODEL / 4, 25.0),
            ("dense-25g", TP_DMODEL, 25.0),
            ("altup-2g", TP_DMODEL / 4, 2.0),
            ("dense-2g", TP_DMODEL, 2.0),
        ] {
            let spec = EngineSpec::Sim(mk_spec(active_width, link_gbps));
            let (tq, ts) = drive(&spec, mk_opts(1, tp), &prompts, clients)?;
            let (dq, ds) = drive(&spec, mk_opts(tp, 0), &prompts, clients)?;
            let (tlq, tls) = drive(&spec, mk_opts(1, tp), lprompts, lat_clients)?;
            let (dlq, dls) = drive(&spec, mk_opts(tp, 0), lprompts, lat_clients)?;
            report(&format!("tp{tp}-{name} (peak)"), tq, &ts);
            report(&format!("dp{tp}-{name} (peak)"), dq, &ds);
            report(&format!("tp{tp}-{name} (light)"), tlq, &tls);
            report(&format!("dp{tp}-{name} (light)"), dlq, &dls);
            anyhow::ensure!(
                ts.tokens_generated == ref_stats.tokens_generated
                    && ds.tokens_generated == ref_stats.tokens_generated,
                "{name}: sharding changed tokens at peak (tp {} / dp {} vs single {})",
                ts.tokens_generated,
                ds.tokens_generated,
                ref_stats.tokens_generated
            );
            anyhow::ensure!(
                tls.tokens_generated == lref_stats.tokens_generated
                    && dls.tokens_generated == lref_stats.tokens_generated,
                "{name}: sharding changed tokens at light load (tp {} / dp {} vs single {})",
                tls.tokens_generated,
                dls.tokens_generated,
                lref_stats.tokens_generated
            );
            anyhow::ensure!(
                ts.devices == ds.devices,
                "{name}: arms are not equal-device (tp {} vs dp {})",
                ts.devices,
                ds.devices
            );
            anyhow::ensure!(
                ts.collectives > 0 && ds.collectives == 0,
                "{name}: collective accounting sits on the wrong arm \
                 (tp {} rounds, dp {} rounds)",
                ts.collectives,
                ds.collectives
            );
            let mean_ar = ts.collective_ns as f64 / ts.collectives.max(1) as f64;
            let json = Json::obj(vec![
                ("point", Json::str(name)),
                ("active_width", Json::num(active_width as f64)),
                ("link_gbps", Json::num(link_gbps)),
                ("tp_peak", row_json("cont-tp", 1, tq, &ts)),
                ("dp_peak", row_json("cont-dp", tp, dq, &ds)),
                ("tp_light", row_json("cont-tp", 1, tlq, &tls)),
                ("dp_light", row_json("cont-dp", tp, dlq, &dls)),
                ("peak_qps_dp_over_tp", Json::num(if tq > 0.0 { dq / tq } else { 0.0 })),
                (
                    "light_p95_tp_over_dp",
                    Json::num(if dls.p95_ms() > 0.0 { tls.p95_ms() / dls.p95_ms() } else { 0.0 }),
                ),
                ("mean_allreduce_ns", Json::num(mean_ar)),
            ]);
            pts.push(TpPoint {
                name,
                tp_peak_qps: tq,
                dp_peak_qps: dq,
                tp_light_p95: tls.p95_ms(),
                dp_light_p95: dls.p95_ms(),
                mean_allreduce_ns: mean_ar,
                json,
            });
        }
        let pt = |n: &str| pts.iter().find(|p| p.name == n).expect("tp point recorded");
        let (cross, altup_slow, dense_slow) = (pt("altup-25g"), pt("altup-2g"), pt("dense-2g"));
        println!(
            "tp{tp} crossover @altup-25g: light p95 dp {:.2} -> tp {:.2} ms | peak \
             tp {:.1} vs dp {:.1} qps | slow-link p95 ratio altup {:.2} dense {:.2} | \
             allreduce {:.1} vs {:.1} us",
            cross.dp_light_p95,
            cross.tp_light_p95,
            cross.tp_peak_qps,
            cross.dp_peak_qps,
            altup_slow.tp_light_p95 / altup_slow.dp_light_p95.max(1e-9),
            dense_slow.tp_light_p95 / dense_slow.dp_light_p95.max(1e-9),
            altup_slow.mean_allreduce_ns / 1e3,
            dense_slow.mean_allreduce_ns / 1e3,
        );
        if full {
            anyhow::ensure!(
                cross.dp_peak_qps > cross.tp_peak_qps,
                "crossover broke: dp{tp} peak {:.1} qps did not beat tp{tp} {:.1}",
                cross.dp_peak_qps,
                cross.tp_peak_qps
            );
            anyhow::ensure!(
                cross.tp_light_p95 < cross.dp_light_p95,
                "crossover broke: tp{tp} light p95 {:.2} ms did not beat dp{tp} {:.2}",
                cross.tp_light_p95,
                cross.dp_light_p95
            );
            anyhow::ensure!(
                altup_slow.tp_light_p95 < altup_slow.dp_light_p95,
                "altup payload no longer keeps tp{tp} ahead on the slow link \
                 ({:.2} vs {:.2} ms p95)",
                altup_slow.tp_light_p95,
                altup_slow.dp_light_p95
            );
            anyhow::ensure!(
                dense_slow.tp_light_p95 > dense_slow.dp_light_p95,
                "dense payload unexpectedly survives the slow link \
                 ({:.2} vs {:.2} ms p95)",
                dense_slow.tp_light_p95,
                dense_slow.dp_light_p95
            );
            anyhow::ensure!(
                altup_slow.mean_allreduce_ns < 0.7 * dense_slow.mean_allreduce_ns,
                "narrow active block stopped shrinking the wire: {:.0} vs {:.0} ns/round",
                altup_slow.mean_allreduce_ns,
                dense_slow.mean_allreduce_ns
            );
        }

        // Shard-kill chaos arm: follower shard 1 of the only group
        // dies mid-run; §L7 must treat the whole group as the failure
        // unit — requeue everything in flight once, respawn a full
        // group (shape carried by the supervisor), finish with token
        // parity intact.
        let mut cspec = mk_spec(TP_DMODEL / 4, 25.0);
        cspec.fault.kill_replica = Some(0);
        cspec.fault.kill_after_calls = tp_kill_call;
        cspec.fault.kill_shard = 1;
        let (cq, cs) = drive(&EngineSpec::Sim(cspec), mk_opts(1, tp), &prompts, clients)?;
        report(&format!("tp{tp}-shard-kill"), cq, &cs);
        println!(
            "tp{tp} shard-kill@{tp_kill_call}: {} requeued, {} restarts, {} failed, \
             devices {} (respawn re-counts the group), parity {}",
            cs.retries,
            cs.restarts,
            cs.failed,
            cs.devices,
            cs.tokens_generated == ref_stats.tokens_generated,
        );
        anyhow::ensure!(
            cs.restarts >= 1,
            "shard kill did not respawn the execution group"
        );
        anyhow::ensure!(cs.retries >= 1, "group kill requeued nothing");
        if full {
            anyhow::ensure!(
                cs.failed == 0,
                "{} requests lost to the shard-kill chaos arm",
                cs.failed
            );
            anyhow::ensure!(
                cs.tokens_generated == ref_stats.tokens_generated,
                "shard-kill respawn broke token parity ({} vs {})",
                cs.tokens_generated,
                ref_stats.tokens_generated
            );
        }

        let dp_wins_peak = cross.dp_peak_qps > cross.tp_peak_qps;
        let tp_wins_light = cross.tp_light_p95 < cross.dp_light_p95;
        let slow_altup_ahead = altup_slow.tp_light_p95 < altup_slow.dp_light_p95;
        let slow_dense_behind = dense_slow.tp_light_p95 > dense_slow.dp_light_p95;
        let allreduce_ratio =
            altup_slow.mean_allreduce_ns / dense_slow.mean_allreduce_ns.max(1e-9);
        tp_row = Some(Json::obj(vec![
            ("tp", Json::num(tp as f64)),
            ("d_model", Json::num(TP_DMODEL as f64)),
            ("elem_bytes", Json::num(2.0)),
            ("latency_ns", Json::num(500.0)),
            ("syncs_per_step", Json::num(12.0)),
            ("partitioned_frac", Json::num(0.85)),
            ("clients_peak", Json::num(clients as f64)),
            ("clients_light", Json::num(lat_clients as f64)),
            ("requests_light", Json::num(lat_n as f64)),
            ("bars_enforced", Json::Bool(full)),
            ("single_reference_peak", row_json("cont-single", 1, ref_q, &ref_stats)),
            ("single_reference_light", row_json("cont-single", 1, lref_q, &lref_stats)),
            ("points", Json::Arr(pts.into_iter().map(|p| p.json).collect())),
            (
                "crossover",
                Json::obj(vec![
                    ("point", Json::str("altup-25g")),
                    ("dp_wins_peak_qps", Json::Bool(dp_wins_peak)),
                    ("tp_wins_light_p95", Json::Bool(tp_wins_light)),
                ]),
            ),
            (
                "slow_link",
                Json::obj(vec![
                    ("altup_point", Json::str("altup-2g")),
                    ("dense_point", Json::str("dense-2g")),
                    ("tp_still_ahead_on_altup", Json::Bool(slow_altup_ahead)),
                    ("tp_behind_on_dense", Json::Bool(slow_dense_behind)),
                    (
                        "mean_allreduce_ratio_altup_over_dense",
                        Json::num(allreduce_ratio),
                    ),
                ]),
            ),
            (
                "chaos",
                Json::obj(vec![
                    ("kill_shard", Json::num(1.0)),
                    ("kill_at_call", Json::num(tp_kill_call as f64)),
                    ("qps", Json::num(cq)),
                    ("requests", Json::num(cs.requests as f64)),
                    ("failed", Json::num(cs.failed as f64)),
                    ("retries", Json::num(cs.retries as f64)),
                    ("restarts", Json::num(cs.restarts as f64)),
                    ("devices", Json::num(cs.devices as f64)),
                    (
                        "token_parity",
                        Json::Bool(cs.tokens_generated == ref_stats.tokens_generated),
                    ),
                ]),
            ),
        ]));
    }

    // §L13 span-trace attribution + overhead A/B (sim engine only).
    // Three sub-arms: (a) tracing-on vs tracing-off QPS on the
    // closed-loop cont x2 workload — observability must be ~free;
    // (b) the §L10 burst trace replayed healthy through the paged
    // cont x2 fleet QoS-on vs QoS-off at sample 1.0, attributing the
    // all-request mean and the slowest-5% tail to the five top-level
    // phases (QoS moves tail queueing out of the FIFO dispatch path
    // and into the visible qos-queue phase, shedding the rest);
    // (c) a §L12 slow-link TP pair where the narrow AltUp sync shows
    // up as a smaller allreduce share of engine time than dense.
    let mut trace_row: Option<Json> = None;
    if let (EngineSpec::Sim(base), true) = (&engine, trace_ab) {
        let full_load = requests >= 256;
        let full_trace = trace_limit == 0;

        // (a) Overhead: identical workload, sample 0.0 vs 1.0; two
        // runs per arm, best-of, to damp scheduler noise.
        let traced_opts = |sample: f64| {
            let mut o = opts(2, true, true);
            o.trace_sample = sample;
            o.trace_ring = 1 << 15;
            o.trace_window_ms = 100;
            o
        };
        let best = |sample: f64| -> anyhow::Result<(f64, ServerStats)> {
            let (q1, s1) = drive(&engine, traced_opts(sample), &prompts, clients)?;
            let (q2, s2) = drive(&engine, traced_opts(sample), &prompts, clients)?;
            Ok(if q2 > q1 { (q2, s2) } else { (q1, s1) })
        };
        let (off_q, _) = best(0.0)?;
        let (on_q, on_stats) = best(1.0)?;
        let overhead_ratio = if off_q > 0.0 { on_q / off_q } else { 0.0 };
        println!(
            "trace overhead: off {off_q:.1} qps, on {on_q:.1} qps \
             ({overhead_ratio:.3}x, {} spans, {} dropped)",
            on_stats.trace.span_count(),
            on_stats.trace.dropped_spans,
        );
        if full_load {
            anyhow::ensure!(
                overhead_ratio >= 0.97,
                "full tracing cost more than 3% of throughput ({overhead_ratio:.3}x)"
            );
        }

        // (b) Burst-replay attribution. Same fleet/pool/tenant shape
        // as the §L10 clean arm, no chaos (a requeue would double-
        // count a request's spans and muddy the phase ledger).
        let trace_reqs = load_trace(&trace_path, vocab, trace_limit)?;
        anyhow::ensure!(!trace_reqs.is_empty(), "empty trace {trace_path}");
        let tenant_spec = "free:0:1:250:40:0;silver:1:2:0:0:4000;gold:2:4:0:0:1500";
        let tenants = parse_tenant_spec(tenant_spec);
        let mut qspec = base.clone();
        qspec.pool =
            Some(SimPoolSpec { page_size: 16, pool_pages: 96, prefix_cache: false });
        let attr_opts = |with_tenants: bool| {
            let mut o = opts(2, true, true);
            o.queue_cap = 1024;
            o.trace_sample = 1.0;
            o.trace_ring = 1 << 17;
            o.trace_window_ms = 100;
            if with_tenants {
                o.tenants = tenants.clone();
            }
            o
        };
        let (on_qps, qon) =
            drive_trace(&EngineSpec::Sim(qspec.clone()), attr_opts(true), &trace_reqs, &tenants)?;
        let (off_qps, qoff) =
            drive_trace(&EngineSpec::Sim(qspec.clone()), attr_opts(false), &trace_reqs, &tenants)?;

        let phase_shares = |a: &trc::Attribution| {
            let sh = a.shares();
            Json::obj(
                trc::Phase::ALL
                    .iter()
                    .map(|p| (p.as_str(), Json::num(sh[p.index()])))
                    .collect(),
            )
        };
        let analyze = |label: &str,
                       qps: f64,
                       s: &ServerStats|
         -> anyhow::Result<(Json, trc::Attribution)> {
            let attrs = trc::per_request(s.trace.spans());
            anyhow::ensure!(!attrs.is_empty(), "{label}: no traced requests");
            let all = trc::attribute(&attrs, 1.0);
            let tail = trc::attribute(&attrs, 0.05);
            let top_sum: f64 = {
                let sh = all.shares();
                trc::Phase::TOP_LEVEL.iter().map(|p| sh[p.index()]).sum()
            };
            anyhow::ensure!(
                (top_sum - 1.0).abs() < 1e-6,
                "{label}: top-level phase shares sum to {top_sum:.6}, not 1.0"
            );
            let escalations = s
                .trace
                .spans()
                .filter(|sp| sp.phase == trc::Phase::LadderLevel && sp.value > 0)
                .count();
            let mean_e2e_ms = all.e2e_ns as f64 / all.requests.max(1) as f64 / 1e6;
            let tail_e2e_ms = tail.e2e_ns as f64 / tail.requests.max(1) as f64 / 1e6;
            println!(
                "trace {label}: {qps:.1} qps, {} attributed reqs, mean e2e \
                 {mean_e2e_ms:.1} ms, slowest-5% e2e {tail_e2e_ms:.1} ms, \
                 {escalations} ladder escalations, {} dropped spans",
                all.requests,
                s.trace.dropped_spans,
            );
            let row = Json::obj(vec![
                ("qps", Json::num(qps)),
                ("requests_attributed", Json::num(all.requests as f64)),
                ("dropped_spans", Json::num(s.trace.dropped_spans as f64)),
                ("ladder_escalations", Json::num(escalations as f64)),
                ("mean_e2e_ms", Json::num(mean_e2e_ms)),
                ("tail_e2e_ms", Json::num(tail_e2e_ms)),
                ("shares_all", phase_shares(&all)),
                ("shares_tail_p95", phase_shares(&tail)),
            ]);
            Ok((row, tail))
        };
        let (on_json, on_tail) = analyze("qos-on", on_qps, &qon)?;
        let (off_json, off_tail) = analyze("qos-off", off_qps, &qoff)?;
        let queue_share = |a: &trc::Attribution| {
            let sh = a.shares();
            sh[trc::Phase::AdmissionQueue.index()]
                + sh[trc::Phase::QosQueue.index()]
                + sh[trc::Phase::RouterDispatch.index()]
        };
        println!(
            "trace tail queue-wait share (admission+qos+dispatch): qos-on {:.0}%, \
             qos-off {:.0}%",
            queue_share(&on_tail) * 100.0,
            queue_share(&off_tail) * 100.0,
        );
        if let Some(p) = args.get("trace-jsonl") {
            trc::write_jsonl(std::path::Path::new(p), &qon.trace, 1.0)?;
            println!(
                "trace: wrote {} spans + {} windows to {p}",
                qon.trace.span_count(),
                qon.trace.timeline.windows.len(),
            );
        }

        // (c) Slow-link TP pair (§L12 geometry, 2 Gb/s link): the
        // breakdown's aggregate allreduce wall-ns against traced
        // engine time (prefill + decode iterations). The narrow
        // active block must put a smaller share on the wire.
        const TP_DMODEL: usize = 1024;
        let mk_tp_spec = |active_width: usize| {
            let mut s = base.clone();
            s.pool = None;
            s.collective = CollectiveSpec {
                d_model: TP_DMODEL,
                active_width,
                elem_bytes: 2,
                link_bps: 2e9,
                latency_ns: 500,
                syncs_per_step: 12,
                partitioned_frac: 0.85,
            };
            s
        };
        let tp_opts = || {
            let mut o = opts(1, true, true);
            o.tp = 2;
            o.tp_groups = usize::MAX;
            o.trace_sample = 1.0;
            o.trace_ring = 1 << 15;
            o.trace_window_ms = 100;
            o
        };
        let ar_share = |s: &ServerStats| {
            let (ar, _) = s.trace.phases.get(trc::Phase::Allreduce);
            let (pf, _) = s.trace.phases.get(trc::Phase::Prefill);
            let (di, _) = s.trace.phases.get(trc::Phase::DecodeIter);
            ar as f64 / (pf + di).max(1) as f64
        };
        let (nq, nstats) =
            drive(&EngineSpec::Sim(mk_tp_spec(TP_DMODEL / 4)), tp_opts(), &prompts, clients)?;
        let (dq, dstats) =
            drive(&EngineSpec::Sim(mk_tp_spec(TP_DMODEL)), tp_opts(), &prompts, clients)?;
        anyhow::ensure!(
            nstats.collectives > 0 && dstats.collectives > 0,
            "trace tp arms recorded no collective rounds"
        );
        let (narrow_share, dense_share) = (ar_share(&nstats), ar_share(&dstats));
        println!(
            "trace tp2@2g allreduce share of engine time: altup {:.1}% vs dense {:.1}% \
             ({nq:.1} vs {dq:.1} qps)",
            narrow_share * 100.0,
            dense_share * 100.0,
        );
        if full_load {
            anyhow::ensure!(
                narrow_share < dense_share,
                "narrow AltUp sync no longer shrinks the traced allreduce share \
                 ({narrow_share:.3} vs {dense_share:.3})"
            );
        }

        trace_row = Some(Json::obj(vec![
            ("sample", Json::num(1.0)),
            ("bars_enforced", Json::Bool(full_load && full_trace)),
            (
                "overhead",
                Json::obj(vec![
                    ("qps_off", Json::num(off_q)),
                    ("qps_on", Json::num(on_q)),
                    ("ratio_on_over_off", Json::num(overhead_ratio)),
                    ("spans_recorded", Json::num(on_stats.trace.span_count() as f64)),
                    ("dropped_spans", Json::num(on_stats.trace.dropped_spans as f64)),
                ]),
            ),
            ("qos_on", on_json),
            ("qos_off", off_json),
            (
                "tail_queue_wait_share",
                Json::obj(vec![
                    ("qos_on", Json::num(queue_share(&on_tail))),
                    ("qos_off", Json::num(queue_share(&off_tail))),
                ]),
            ),
            (
                "tp_slow_link",
                Json::obj(vec![
                    ("tp", Json::num(2.0)),
                    ("d_model", Json::num(TP_DMODEL as f64)),
                    ("narrow_active_width", Json::num((TP_DMODEL / 4) as f64)),
                    ("link_gbps", Json::num(2.0)),
                    ("qps_narrow", Json::num(nq)),
                    ("qps_dense", Json::num(dq)),
                    ("allreduce_share_narrow", Json::num(narrow_share)),
                    ("allreduce_share_dense", Json::num(dense_share)),
                ]),
            ),
        ]));
    }

    let (bq1, bp1) = find("batch", 1);
    let (cq1, cp1) = find("cont", 1);
    let (cq4, _) = find("cont", 4);
    let qps_ratio_x1 = if bq1 > 0.0 { cq1 / bq1 } else { 0.0 };
    let p95_reduction_x1 = if bp1 > 0.0 { 1.0 - cp1 / bp1 } else { 0.0 };
    println!(
        "continuous vs batch @x1: {qps_ratio_x1:.2}x QPS, p95 {bp1:.2} -> {cp1:.2} ms \
         ({:.1}% lower) | cont scaling x4/x1 = {:.2}x",
        p95_reduction_x1 * 100.0,
        if cq1 > 0.0 { cq4 / cq1 } else { 0.0 }
    );

    if json_out {
        let path = args.str_or("json-path", "BENCH_server_throughput.json");
        let mut top = vec![
            ("bench", Json::str("server_throughput")),
            ("engine", Json::str(&engine_name)),
            (
                "workload",
                Json::obj(vec![
                    ("requests", Json::num(requests as f64)),
                    ("clients", Json::num(clients as f64)),
                    ("batch_size", Json::num(batch_size as f64)),
                    ("enc_len", Json::num(enc_len as f64)),
                    ("dec_len", Json::num(dec_len as f64)),
                    ("slots", Json::num(slots as f64)),
                    ("mix", Json::str("70% short [4, enc/4), 30% long [enc/2, enc)")),
                    (
                        "eos",
                        Json::str("generation length hash-sampled uniform in [1, dec_len]"),
                    ),
                    ("batch_window_ms", Json::num(window.as_secs_f64() * 1e3)),
                ]),
            ),
            (
                "baseline_full_length",
                row_json("batch-unbucketed", 1, base_qps, &base_stats),
            ),
            ("configs", Json::Arr(rows)),
            (
                "cont_over_batch_x1",
                Json::obj(vec![
                    ("qps_ratio", Json::num(qps_ratio_x1)),
                    ("p95_reduction", Json::num(p95_reduction_x1)),
                ]),
            ),
            (
                "qps_scaling_x4_over_x1",
                Json::num(if cq1 > 0.0 { cq4 / cq1 } else { 0.0 }),
            ),
            (
                "producer",
                Json::str("cargo bench --bench server_throughput -- --json"),
            ),
        ];
        if let Some(d) = degraded_row {
            top.push(("degraded", d));
        }
        if let Some(s) = spec_row {
            top.push(("speculative", s));
        }
        if let Some(p) = paged_row {
            top.push(("paged", p));
        }
        if let Some(p) = prefix_row {
            top.push(("prefix", p));
        }
        if let Some(q) = qos_row {
            top.push(("qos", q));
        }
        if let Some(t) = tp_row {
            top.push(("tp", t));
        }
        if let Some(s) = swap_row {
            top.push(("deploy", s));
        }
        if let Some(t) = trace_row {
            top.push(("trace", t));
        }
        let doc = Json::obj(top);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }
    Ok(())
}
