//! Bench: serving throughput under shape-bucketed batching and 1/2/4
//! model replicas, on a mixed short/long prompt workload (§Perf L5).
//!
//! Flags (after `--`):
//!   --json             write BENCH_server_throughput.json
//!   --json-path <p>    override the output path
//!   --requests <n>     total requests per configuration (default 384)
//!   --clients <n>      concurrent closed-loop clients (default 32)
//!   --window-ms <n>    router batch window (default 2)
//!
//! Backend: when `make artifacts` has run AND a real PJRT backend is
//! linked, the bench serves the micro-altup artifact; otherwise it
//! falls back to the deterministic sim engine (decode cost proportional
//! to the executed `batch_size x bucket` geometry, see
//! `coordinator::server::SimSpec`), which exercises the identical
//! router/bucketing/replica machinery.
//!
//! Reported per configuration: QPS, mean batch fill, padded-token
//! waste ratio, and p50/p95/p99 latency; the `baseline_full_length` row
//! is the same workload forced to always pad to `enc_len` on one
//! replica — the pre-L5 serving path.

use altup::coordinator::server::{
    EngineSpec, Request, ServerHandle, ServerOptions, ServerStats, SimSpec,
};
use altup::runtime::artifact::load_named;
use altup::runtime::client::Client;
use altup::util::cli::Args;
use altup::util::json::Json;
use altup::util::rng::Rng;
use std::time::{Duration, Instant};

/// 70% short prompts (uniform in [4, enc_len/4)) / 30% long (uniform in
/// [enc_len/2, enc_len)): the mixed workload where always-full padding
/// hurts most.
fn mixed_prompts(n: usize, enc_len: usize, vocab: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = if rng.next_f64() < 0.7 {
                rng.range(4, (enc_len / 4).max(5))
            } else {
                rng.range(enc_len / 2, enc_len)
            };
            (0..len).map(|_| rng.range(1, vocab) as i32).collect()
        })
        .collect()
}

fn drive(
    engine: &EngineSpec,
    opts: ServerOptions,
    prompts: &[Vec<i32>],
    clients: usize,
) -> anyhow::Result<(f64, ServerStats)> {
    let server = ServerHandle::spawn_engine(engine.clone(), opts);
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let sender = server.sender.clone();
        let mine: Vec<Vec<i32>> =
            prompts.iter().skip(c).step_by(clients).cloned().collect();
        joins.push(std::thread::spawn(move || -> anyhow::Result<()> {
            for p in mine {
                let (tx, rx) = std::sync::mpsc::channel();
                sender
                    .send(Request::new(p, tx))
                    .map_err(|_| anyhow::anyhow!("router down"))?;
                rx.recv().map_err(|_| anyhow::anyhow!("no reply"))?;
            }
            Ok(())
        }));
    }
    for j in joins {
        j.join().expect("client thread panicked")?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    Ok((prompts.len() as f64 / wall.max(1e-9), stats))
}

fn row_json(replicas: Option<usize>, qps: f64, stats: &ServerStats) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    if let Some(r) = replicas {
        pairs.push(("replicas", Json::num(r as f64)));
    }
    pairs.extend([
        ("qps", Json::num(qps)),
        ("mean_fill", Json::num(stats.mean_fill())),
        ("waste_ratio", Json::num(stats.waste_ratio())),
        ("prompt_tokens", Json::num(stats.prompt_tokens as f64)),
        ("executed_tokens", Json::num(stats.executed_tokens as f64)),
        ("batches", Json::num(stats.batches as f64)),
        ("p50_ms", Json::num(stats.p50_ms())),
        ("p95_ms", Json::num(stats.p95_ms())),
        ("p99_ms", Json::num(stats.p99_ms())),
    ]);
    Json::obj(pairs)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.usize_or("requests", 384);
    let clients = args.usize_or("clients", 32);
    let window = Duration::from_millis(args.u64_or("window-ms", 2));
    let json_out = args.has("json") || args.has("json-path");

    // Pick the backend: real artifact when present and executable,
    // else the deterministic sim engine.
    let client = Client::cpu()?;
    let stub = client.platform() == "cpu-stub";
    let (engine, engine_name, batch_size, enc_len, vocab) =
        match (!stub).then(|| load_named("micro-altup")) {
            Some(Ok(a)) => {
                let cfg = a.config.clone();
                (
                    EngineSpec::Artifact { name: "micro-altup".into() },
                    "artifact:micro-altup".to_string(),
                    cfg.batch_size,
                    cfg.enc_len,
                    cfg.vocab_size,
                )
            }
            _ => {
                let spec = SimSpec::new(8, 128, 16);
                let (b, e, v) = (spec.batch_size, spec.enc_len, spec.vocab_size);
                (EngineSpec::Sim(spec), "sim".to_string(), b, e, v)
            }
        };
    println!(
        "== server_throughput: engine={engine_name} batch={batch_size} enc_len={enc_len} \
         requests={requests} clients={clients} =="
    );
    let prompts = mixed_prompts(requests, enc_len, vocab, 0x5E_0A11);
    let opts = |replicas: usize, bucketed: bool| ServerOptions {
        batch_window: window,
        replicas,
        bucketed,
        ..Default::default()
    };

    println!(
        "{:<26} {:>9} {:>10} {:>8} {:>9} {:>9} {:>9}",
        "config", "qps", "mean fill", "waste", "p50 ms", "p95 ms", "p99 ms"
    );
    let report = |label: &str, qps: f64, stats: &ServerStats| {
        println!(
            "{:<26} {:>9.1} {:>10.2} {:>7.1}% {:>9.2} {:>9.2} {:>9.2}",
            label,
            qps,
            stats.mean_fill(),
            stats.waste_ratio() * 100.0,
            stats.p50_ms(),
            stats.p95_ms(),
            stats.p99_ms()
        );
    };

    // Pre-L5 baseline: one replica, everything padded to enc_len.
    let (base_qps, base_stats) = drive(&engine, opts(1, false), &prompts, clients)?;
    report("baseline full-length x1", base_qps, &base_stats);

    let mut rows: Vec<Json> = Vec::new();
    let mut qps_by_replicas: Vec<(usize, f64)> = Vec::new();
    for replicas in [1usize, 2, 4] {
        let (qps, stats) = drive(&engine, opts(replicas, true), &prompts, clients)?;
        report(&format!("bucketed x{replicas}"), qps, &stats);
        qps_by_replicas.push((replicas, qps));
        rows.push(row_json(Some(replicas), qps, &stats));
    }

    let q1 = qps_by_replicas.iter().find(|(r, _)| *r == 1).map(|(_, q)| *q).unwrap_or(0.0);
    let q4 = qps_by_replicas.iter().find(|(r, _)| *r == 4).map(|(_, q)| *q).unwrap_or(0.0);
    let bucketed_waste =
        rows.first().and_then(|r| r.get("waste_ratio").as_f64()).unwrap_or(1.0);
    println!(
        "scaling: x4/x1 = {:.2}x  |  waste: baseline {:.1}% -> bucketed {:.1}%",
        if q1 > 0.0 { q4 / q1 } else { 0.0 },
        base_stats.waste_ratio() * 100.0,
        bucketed_waste * 100.0
    );

    if json_out {
        let path = args.str_or("json-path", "BENCH_server_throughput.json");
        let doc = Json::obj(vec![
            ("bench", Json::str("server_throughput")),
            ("engine", Json::str(&engine_name)),
            (
                "workload",
                Json::obj(vec![
                    ("requests", Json::num(requests as f64)),
                    ("clients", Json::num(clients as f64)),
                    ("batch_size", Json::num(batch_size as f64)),
                    ("enc_len", Json::num(enc_len as f64)),
                    ("mix", Json::str("70% short [4, enc/4), 30% long [enc/2, enc)")),
                    ("batch_window_ms", Json::num(window.as_secs_f64() * 1e3)),
                ]),
            ),
            ("baseline_full_length", row_json(None, base_qps, &base_stats)),
            ("replicas", Json::Arr(rows)),
            ("qps_scaling_x4_over_x1", Json::num(if q1 > 0.0 { q4 / q1 } else { 0.0 })),
            (
                "producer",
                Json::str("cargo bench --bench server_throughput -- --json"),
            ),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {path}");
    }
    Ok(())
}
