//! Bench: regenerate every paper table/figure in quick mode.
//!
//! `cargo bench --offline --bench paper_tables` runs the full
//! experiment suite with a reduced step budget (fast, CI-friendly);
//! `altup bench-table all` (binary) runs the full budget. Each harness
//! prints the paper's reference rows next to measured values and writes
//! CSV under results/.

use altup::coordinator::pipeline::PipelineOptions;
use altup::experiments;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("ALTUP_FULL").is_err();
    let opts = if quick {
        PipelineOptions {
            pretrain_steps: 40,
            finetune_steps: 20,
            warmup: 1000,
            eval_batches: 3,
            ..Default::default()
        }
    } else {
        PipelineOptions::default()
    };
    println!(
        "== paper_tables ({} mode: pretrain {} / finetune {} steps) ==",
        if quick { "quick — set ALTUP_FULL=1 for full budget" } else { "full" },
        opts.pretrain_steps,
        opts.finetune_steps
    );
    if quick {
        // Bounded subset for `cargo bench`: the analytic Tables 3/4/5
        // (instant) plus the measured micro-scale speed shape. The full
        // quality sweep (fig4/tab1/tab2/tab6/tab7/fig5/tab8/bert) runs
        // via `altup bench-table all` or ALTUP_FULL=1 (takes ~1h on one
        // core; results recorded in EXPERIMENTS.md).
        experiments::table3_params::print_table()?;
        experiments::table3_params::measured_speed(&opts)
    } else {
        experiments::run("all", &opts)
    }
}
