//! Bench: data pipeline throughput — corpus generation, span
//! corruption, task generation, and batch assembly must never be the
//! training bottleneck (§Perf target: >= 1M tokens/s/core).

use altup::data::batcher::{PretrainBatcher, TaskBatcher};
use altup::data::corpus::Corpus;
use altup::data::span::{corrupt, SpanConfig};
use altup::data::tasks::{Task, TaskKind};
use altup::data::tokenizer::Tokenizer;
use altup::util::bench;
use altup::util::rng::Rng;
use std::time::Duration;

fn main() {
    println!("== data_pipeline throughput ==");

    let corpus = Corpus::new(2000, 1);
    let mut idx = 0u64;
    let s = bench::bench("corpus.document (48-192 words)", 10, 200, Duration::from_millis(400), || {
        std::hint::black_box(corpus.document(idx, 48, 192));
        idx += 1;
    });
    println!("{}", s.report());

    let tk = Tokenizer::new(2048).unwrap();
    let doc: Vec<i32> = corpus.document(0, 150, 192).iter().map(|&w| tk.encode_word(w)).collect();
    let mut rng = Rng::new(2);
    let s = bench::bench("span.corrupt (~160 tokens)", 10, 200, Duration::from_millis(400), || {
        std::hint::black_box(corrupt(&doc, SpanConfig::default(), &tk, &mut rng));
    });
    println!("{}", s.report());
    let tokens_per_sec = 160.0 / s.mean.as_secs_f64();
    println!("  -> {:.2}M corrupted tokens/s", tokens_per_sec / 1e6);

    let mut pb = PretrainBatcher::new(2048, 8, 64, 32, 3);
    let s = bench::bench("pretrain batch (8x(64+32))", 5, 100, Duration::from_millis(400), || {
        std::hint::black_box(pb.next_batch());
    });
    println!("{}", s.report());
    let batch_tokens = 8.0 * 96.0;
    println!("  -> {:.2}M batch tokens/s", batch_tokens / s.mean.as_secs_f64() / 1e6);

    for kind in [TaskKind::Glue, TaskKind::SuperGlue, TaskKind::Squad, TaskKind::TriviaQa] {
        let task = Task::new(kind, 2048, 4);
        let mut tb = TaskBatcher::new(task, 8, 64, 32);
        let s = bench::bench(
            &format!("task batch: {}", kind.name()),
            5,
            100,
            Duration::from_millis(300),
            || {
                std::hint::black_box(tb.next_batch());
            },
        );
        println!("{}", s.report());
    }
}
