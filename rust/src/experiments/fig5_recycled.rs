//! Figure 5 + Table 8: Recycled-AltUp.
//!
//! Shape: Recycled-AltUp improves pretrain accuracy over the baseline
//! with no perceptible slowdown (latency ~= baseline, clearly faster
//! than full AltUp's widened embedding/head path at large vocab), and
//! (Table 8) transfers to finetune gains.

use crate::coordinator::pipeline::{finetune_task, pretrain, PipelineOptions};
use crate::data::tasks::TaskKind;
use crate::experiments::{latency, write_csv};
use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use anyhow::Result;

pub fn run(opts: &PipelineOptions, with_finetune: bool) -> Result<()> {
    let client = Client::cpu()?;
    println!("\n=== Figure 5: Recycled-AltUp speed + pretrain accuracy ===");
    println!("paper: Recycled-AltUp ~= baseline speed, strictly better pretrain acc");
    let names = ["micro-baseline", "micro-recycled", "micro-altup"];
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for name in names {
        if !latency::available(name) {
            continue;
        }
        let lat = latency::measure(&client, name)?;
        let artifact = load_named(name)?;
        let (session, ev, sps, _data_wait) = pretrain(&client, artifact, opts)?;
        println!(
            "  {name:<16} train {:>8.2} ms/step ({:>5.2} steps/s)  pretrain acc {:>5.2}%",
            lat.train_s * 1e3,
            sps,
            ev.accuracy * 100.0
        );
        rows.push(format!("{name},{:.5},{sps:.3},{:.4}", lat.train_s, ev.accuracy));
        results.push((name, session, ev, lat));
    }
    write_csv("fig5_recycled", "model,train_s,steps_per_s,pretrain_acc", &rows)?;

    if results.len() == 3 {
        let base_t = results[0].3.train_s;
        let rec_t = results[1].3.train_s;
        println!(
            "  shape: recycled/base latency ratio {:.2} (paper: ~1.0); \
             recycled acc - base acc = {:+.2}pp (paper: +0.12..+0.21)",
            rec_t / base_t,
            (results[1].2.accuracy - results[0].2.accuracy) * 100.0
        );
    }

    if with_finetune {
        println!("\n=== Table 8: Recycled-AltUp finetune ===");
        let tasks =
            [TaskKind::Glue, TaskKind::SuperGlue, TaskKind::Squad, TaskKind::TriviaQa];
        let mut rows8 = Vec::new();
        for (name, session, _, _) in &results {
            let mut line = format!("  {name:<16}");
            let mut csv = name.to_string();
            for kind in tasks {
                let ev = finetune_task(&client, session, kind, opts)?;
                let v = if kind.is_generative() { ev.f1 } else { ev.accuracy };
                line.push_str(&format!(" {}={:.1}", kind.name(), v * 100.0));
                csv.push_str(&format!(",{:.4}", v));
            }
            println!("{line}");
            rows8.push(csv);
        }
        write_csv("table8_recycled_finetune", "model,glue,superglue,squad_f1,triviaqa_f1", &rows8)?;
    }
    Ok(())
}
