//! Table 6 (App. C): AltUp + partial-experts MoE synergy.
//!
//! Paper shape at 100k steps: MoE > baseline, AltUp > MoE, and
//! AltUp+MoE > each in isolation (additive gains).

use crate::coordinator::pipeline::{pretrain, PipelineOptions};
use crate::experiments::write_csv;
use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use anyhow::Result;

/// Paper Table 6, T5-Small column (pretrain acc @100k).
const PAPER_S: &[(&str, f64)] = &[
    ("Baseline", 59.10),
    ("MoE", 59.42),
    ("AltUp (K=2)", 59.67),
    ("AltUp + MoE", 59.91),
];

pub fn run(opts: &PipelineOptions) -> Result<()> {
    let client = Client::cpu()?;
    println!("\n=== Table 6: AltUp + MoE synergy (micro scale) ===");
    println!("paper reference (T5-S pretrain acc @100k):");
    for (m, v) in PAPER_S {
        println!("  {m:<14} {v:.2}");
    }
    println!("\nmeasured (pretrain acc, {} steps):", opts.pretrain_steps);
    let names = [
        ("micro-baseline", "Baseline"),
        ("micro-moe", "MoE"),
        ("micro-altup", "AltUp (K=2)"),
        ("micro-altup-moe", "AltUp + MoE"),
    ];
    let mut rows = Vec::new();
    let mut accs = Vec::new();
    for (name, label) in names {
        let artifact = load_named(name)?;
        let (_, ev, sps, _) = pretrain(&client, artifact, opts)?;
        println!("  {label:<14} acc={:.2}% ({sps:.2} steps/s)", ev.accuracy * 100.0);
        rows.push(format!("{label},{:.4},{sps:.3}", ev.accuracy));
        accs.push(ev.accuracy);
    }
    write_csv("table6_moe", "model,pretrain_acc,steps_per_s", &rows)?;
    if accs.len() == 4 {
        let ok = accs[3] >= accs[2] && accs[3] >= accs[1] && accs[2] >= accs[0];
        println!(
            "  shape: AltUp+MoE >= AltUp >= baseline and >= MoE alone ({})",
            if ok { "OK" } else { "MISS (noise at this step budget)" }
        );
    }
    Ok(())
}
