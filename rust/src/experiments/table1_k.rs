//! Table 1: AltUp with varying expansion factor K (2 vs 4).
//!
//! Paper shape: K=4 strictly improves *pretrain* accuracy over K=2, but
//! does not always help finetune metrics at small scale (less frequent
//! activation of each block).

use crate::coordinator::pipeline::{run_pipeline, PipelineOptions};
use crate::data::tasks::TaskKind;
use crate::experiments::write_csv;
use crate::runtime::client::Client;
use anyhow::Result;

const TASKS: &[TaskKind] =
    &[TaskKind::Glue, TaskKind::SuperGlue, TaskKind::Squad, TaskKind::TriviaQa];

/// Paper Table 1, T5-Small rows (our micro stands in for S).
const PAPER_S: &[(&str, f64, f64, f64, f64)] = &[
    // (model, pretrain, glue, sg, squad-f1)
    ("S", 61.21, 75.83, 59.52, 84.97),
    ("S+AltUp(K=2)", 61.86, 76.82, 59.60, 85.79),
    ("S+AltUp(K=4)", 62.00, 76.40, 59.54, 84.86),
];

pub fn run(opts: &PipelineOptions) -> Result<()> {
    let client = Client::cpu()?;
    println!("\n=== Table 1: AltUp with K in {{1, 2, 4}} (micro scale) ===");
    println!("paper reference (T5-S): pretrain / GLUE / SG / SQuAD-F1");
    for (m, p, g, s, q) in PAPER_S {
        println!("  {m:<16} {p:>6.2} {g:>6.2} {s:>6.2} {q:>6.2}");
    }
    println!("\nmeasured (micro, synthetic tasks):");
    let mut rows = Vec::new();
    let mut pretrains = Vec::new();
    for name in ["micro-baseline", "micro-altup", "micro-altup-k4"] {
        let res = run_pipeline(&client, name, TASKS, opts)?;
        let line = res
            .task_results
            .iter()
            .map(|(k, ev)| {
                let v = if k.is_generative() { ev.f1 } else { ev.accuracy };
                format!("{}={:.1}", k.name(), v * 100.0)
            })
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "  {name:<16} pretrain={:.2}% {line}",
            res.pretrain_accuracy * 100.0
        );
        pretrains.push((name, res.pretrain_accuracy));
        let vals = res
            .task_results
            .iter()
            .map(|(_, ev)| {
                format!(
                    "{:.4},{:.4},{:.4}",
                    ev.accuracy, ev.em, ev.f1
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        rows.push(format!("{name},{:.4},{vals}", res.pretrain_accuracy));
    }
    write_csv(
        "table1_k",
        "model,pretrain_acc,glue_acc,glue_em,glue_f1,sg_acc,sg_em,sg_f1,squad_acc,squad_em,squad_f1,tqa_acc,tqa_em,tqa_f1",
        &rows,
    )?;
    // Shape check: AltUp pretrain >= baseline pretrain.
    if pretrains.len() >= 2 && pretrains[1].1 >= pretrains[0].1 {
        println!("  shape OK: AltUp(K=2) pretrain >= baseline (paper: 61.86 vs 61.21)");
    } else {
        println!("  shape MISS: AltUp(K=2) pretrain < baseline at this scale/step budget");
    }
    Ok(())
}
