//! Figure 4 (and Figure 1's premise): accuracy-vs-latency frontier
//! across model sizes, baseline vs AltUp, on all four benchmark tasks.
//!
//! Scaled reproduction: sizes micro/tiny/mini stand in for B/L/XL; each
//! (size, variant) is pretrained on the synthetic corpus and finetuned
//! per task; latency is measured on the compiled forward HLO. The
//! paper's claim has two parts we verify in shape:
//!   1. AltUp adds little latency at each size;
//!   2. at matched accuracy, AltUp models are faster than the dense
//!      frontier (speedup computed by interpolating the dense
//!      size-frontier at the AltUp model's accuracy, as in the paper).

use crate::coordinator::pipeline::{run_pipeline, PipelineOptions};
use crate::data::tasks::TaskKind;
use crate::experiments::{latency, write_csv};
use crate::runtime::client::Client;
use anyhow::Result;

const SIZES: &[&str] = &["micro", "tiny", "mini"];
const TASKS: &[TaskKind] =
    &[TaskKind::Glue, TaskKind::SuperGlue, TaskKind::Squad, TaskKind::TriviaQa];

#[derive(Debug, Clone)]
struct Point {
    name: String,
    latency_s: f64,
    /// metric per task (acc for cls, F1 for generative)
    scores: Vec<(TaskKind, f64)>,
}

pub fn run(opts: &PipelineOptions) -> Result<()> {
    let client = Client::cpu()?;
    println!("\n=== Figure 4: accuracy vs latency (scaled sizes, 4 tasks) ===");
    let mut dense: Vec<Point> = Vec::new();
    let mut altup: Vec<Point> = Vec::new();

    for size in SIZES {
        for (variant, bucket) in [("baseline", 0), ("altup", 1)] {
            let name = format!("{size}-{variant}");
            if !latency::available(&name) {
                println!("  (skipping {name}: artifact missing)");
                continue;
            }
            let lat = latency::measure(&client, &name)?;
            let res = run_pipeline(&client, &name, TASKS, opts)?;
            let scores: Vec<(TaskKind, f64)> = res
                .task_results
                .iter()
                .map(|(k, ev)| {
                    let v = if k.is_generative() { ev.f1 } else { ev.accuracy };
                    (*k, v)
                })
                .collect();
            let fwd = lat.forward_s.unwrap_or(lat.train_s / 3.0);
            println!(
                "  {name:<16} fwd {:>8.2} ms  pretrain acc {:>5.1}%  {}",
                fwd * 1e3,
                res.pretrain_accuracy * 100.0,
                scores
                    .iter()
                    .map(|(k, v)| format!("{}={:.1}", k.name(), v * 100.0))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
            let p = Point { name: name.clone(), latency_s: fwd, scores };
            if bucket == 0 {
                dense.push(p);
            } else {
                altup.push(p);
            }
        }
    }

    // Speedup at matched accuracy, per task: interpolate the dense
    // frontier (latency as a function of score) at each AltUp score.
    println!("\n  speedup at same accuracy (paper: +27%..+87% on L):");
    let mut rows = Vec::new();
    for (ti, task) in TASKS.iter().enumerate() {
        for p in &altup {
            let score = p.scores.get(ti).map(|(_, v)| *v).unwrap_or(0.0);
            if let Some(dense_lat) = interpolate_latency(&dense, ti, score) {
                let speedup = (dense_lat - p.latency_s) / p.latency_s;
                println!(
                    "    {:<10} {:<14} speedup {:>6.1}%",
                    task.name(),
                    p.name,
                    speedup * 100.0
                );
                rows.push(format!("{},{},{:.4}", task.name(), p.name, speedup));
            }
        }
    }
    write_csv("fig4_speedup", "task,model,speedup_at_same_accuracy", &rows)?;

    let mut rows2 = Vec::new();
    for p in dense.iter().chain(altup.iter()) {
        let scores = p
            .scores
            .iter()
            .map(|(_, v)| format!("{v:.4}"))
            .collect::<Vec<_>>()
            .join(",");
        rows2.push(format!("{},{:.6},{scores}", p.name, p.latency_s));
    }
    write_csv("fig4_points", "model,forward_s,glue,superglue,squad_f1,triviaqa_f1", &rows2)?;
    Ok(())
}

/// Latency of the dense frontier at `score`, by linear interpolation
/// over (score, latency) pairs; extrapolates the last segment like the
/// paper's "extrapolated dense baselines".
fn interpolate_latency(dense: &[Point], task_idx: usize, score: f64) -> Option<f64> {
    let mut pts: Vec<(f64, f64)> = dense
        .iter()
        .filter_map(|p| p.scores.get(task_idx).map(|(_, v)| (*v, p.latency_s)))
        .collect();
    if pts.len() < 2 {
        return pts.first().map(|&(_, l)| l);
    }
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let (lo, hi) = (pts[0], pts[pts.len() - 1]);
    let (a, b) = if score <= pts[1].0 {
        (pts[0], pts[1])
    } else {
        (pts[pts.len() - 2], hi)
    };
    let _ = lo;
    if (b.0 - a.0).abs() < 1e-9 {
        return Some(b.1);
    }
    let t = (score - a.0) / (b.0 - a.0);
    Some(a.1 + t * (b.1 - a.1))
}
