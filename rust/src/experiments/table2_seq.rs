//! Table 2: sequence-length reduction methods on the encoder —
//! average pooling vs stride-and-skip vs Sequence-AltUp, plus speed.
//!
//! Paper shape: avg pooling is fastest but degrades hard; Sequence-
//! AltUp is slightly slower than stride-and-skip but much closer to the
//! baseline's quality (~40% faster than baseline overall).

use crate::coordinator::pipeline::{run_pipeline, PipelineOptions};
use crate::data::tasks::TaskKind;
use crate::experiments::{latency, write_csv};
use crate::runtime::client::Client;
use anyhow::Result;

/// Paper Table 2 reference (pretrain acc, GLUE, SG-avg, speed seq/s/core).
const PAPER: &[(&str, f64, f64, f64)] = &[
    ("B (Baseline)", 66.42, 73.56, 52.4),
    ("Average pooling", 63.89, 57.85, 91.9),
    ("Stride-and-Skip", 65.02, 65.98, 79.4),
    ("Sequence-AltUp", 65.39, 66.94, 74.9),
];

const TASKS: &[TaskKind] = &[TaskKind::Glue, TaskKind::SuperGlue];

pub fn run(opts: &PipelineOptions) -> Result<()> {
    let client = Client::cpu()?;
    println!("\n=== Table 2: sequence-length reduction (micro scale, stride 4) ===");
    println!("paper reference (pretrain / GLUE / speed):");
    for (m, p, g, s) in PAPER {
        println!("  {m:<18} {p:>6.2} {g:>6.2} {s:>7.1} seq/s");
    }
    println!("\nmeasured:");
    let names = [
        ("micro-baseline", "Baseline"),
        ("micro-avgpool", "Average pooling"),
        ("micro-strideskip", "Stride-and-Skip"),
        ("micro-seqaltup", "Sequence-AltUp"),
    ];
    let mut rows = Vec::new();
    let mut measured: Vec<(String, f64, f64, f64)> = Vec::new();
    for (name, label) in names {
        if !latency::available(name) {
            continue;
        }
        let lat = latency::measure(&client, name)?;
        let res = run_pipeline(&client, name, TASKS, opts)?;
        let glue = res.task_results[0].1.accuracy;
        let sg = res.task_results[1].1.accuracy;
        let seq_per_s = lat.train_examples_per_sec;
        println!(
            "  {label:<18} pretrain={:.2}% glue={:.1}% sg={:.1}% speed={:.1} seq/s",
            res.pretrain_accuracy * 100.0,
            glue * 100.0,
            sg * 100.0,
            seq_per_s
        );
        rows.push(format!(
            "{label},{:.4},{:.4},{:.4},{:.2}",
            res.pretrain_accuracy, glue, sg, seq_per_s
        ));
        measured.push((label.to_string(), res.pretrain_accuracy, glue, seq_per_s));
    }
    write_csv("table2_seq", "method,pretrain_acc,glue,sg,seq_per_s", &rows)?;

    // Shape assertions (printed, not panicking — these are experiments).
    if measured.len() == 4 {
        let speed = |i: usize| measured[i].3;
        let qual = |i: usize| measured[i].1;
        println!(
            "  shape: speeds base {:.1} < seqaltup {:.1} <= strideskip {:.1} <= avgpool {:.1} ({})",
            speed(0), speed(3), speed(2), speed(1),
            if speed(3) > speed(0) && speed(1) >= speed(2) { "OK" } else { "MISS" }
        );
        println!(
            "  shape: quality avgpool {:.3} < strideskip {:.3} <= seqaltup {:.3} <= base {:.3} ({})",
            qual(1), qual(2), qual(3), qual(0),
            if qual(1) <= qual(2) && qual(2) <= qual(3) + 1e-9 { "OK" } else { "MISS" }
        );
    }
    Ok(())
}
