//! Table 7 (App. D): widening mechanisms — Sum vs SameUp vs AltUp.
//!
//! Paper shape: all three beat the baseline on pretrain; the
//! predict-compute-correct variants (SameUp/AltUp) beat plain summation
//! on finetune, with alternating selection best overall at B/L scale.

use crate::coordinator::pipeline::{run_pipeline, PipelineOptions};
use crate::data::tasks::TaskKind;
use crate::experiments::write_csv;
use crate::runtime::client::Client;
use anyhow::Result;

/// Paper Table 7, Base rows (pretrain / GLUE / SG / SQuAD-F1).
const PAPER_B: &[(&str, f64, f64, f64, f64)] = &[
    ("B (baseline)", 66.42, 84.25, 73.56, 91.19),
    ("B + Sum", 66.82, 84.85, 75.20, 91.36),
    ("B + SameUp", 66.82, 84.06, 74.15, 91.76),
    ("B + AltUp", 66.96, 85.32, 75.80, 92.36),
];

const TASKS: &[TaskKind] = &[TaskKind::Glue, TaskKind::SuperGlue, TaskKind::Squad];

pub fn run(opts: &PipelineOptions) -> Result<()> {
    let client = Client::cpu()?;
    println!("\n=== Table 7: block-selection / widening method comparison ===");
    println!("paper reference (T5-B): pretrain / GLUE / SG / SQuAD-F1");
    for (m, p, g, s, q) in PAPER_B {
        println!("  {m:<14} {p:>6.2} {g:>6.2} {s:>6.2} {q:>6.2}");
    }
    println!("\nmeasured (micro):");
    let names = [
        ("micro-baseline", "baseline"),
        ("micro-sum", "Sum"),
        ("micro-sameup", "SameUp"),
        ("micro-altup", "AltUp"),
    ];
    let mut rows = Vec::new();
    for (name, label) in names {
        let res = run_pipeline(&client, name, TASKS, opts)?;
        let line = res
            .task_results
            .iter()
            .map(|(k, ev)| {
                let v = if k.is_generative() { ev.f1 } else { ev.accuracy };
                format!("{}={:.1}", k.name(), v * 100.0)
            })
            .collect::<Vec<_>>()
            .join(" ");
        println!("  {label:<14} pretrain={:.2}% {line}", res.pretrain_accuracy * 100.0);
        let vals = res
            .task_results
            .iter()
            .map(|(_, ev)| {
                format!("{:.4}", if ev.f1 > 0.0 { ev.f1 } else { ev.accuracy })
            })
            .collect::<Vec<_>>()
            .join(",");
        rows.push(format!("{label},{:.4},{vals}", res.pretrain_accuracy));
    }
    write_csv("table7_selection", "model,pretrain_acc,glue,superglue,squad", &rows)?;
    Ok(())
}
