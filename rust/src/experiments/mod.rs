//! Experiment harnesses — one per paper table/figure (DESIGN.md §5).
//!
//! Every harness prints the paper's reference rows next to our measured
//! (or analytically estimated, for paper-scale rows) values, and writes
//! CSV under `results/`. Absolute numbers will differ (synthetic data,
//! 1-core CPU); the *shape* — who wins, by what factor, where the
//! crossovers fall — is what each harness asserts in its summary line.

pub mod bert_mlm;
pub mod fig4_speed;
pub mod fig5_recycled;
pub mod latency;
pub mod table1_k;
pub mod table2_seq;
pub mod table3_params;
pub mod table6_moe;
pub mod table7_selection;

use crate::coordinator::pipeline::PipelineOptions;
use anyhow::Result;

/// Dispatch an experiment by id ("all" runs the full set).
pub fn run(which: &str, opts: &PipelineOptions) -> Result<()> {
    let all = which == "all";
    let mut ran = false;
    if all || which == "tab3" || which == "tab4" || which == "tab5" || which == "params" {
        table3_params::print_table()?;
        table3_params::measured_speed(opts)?;
        ran = true;
    }
    if all || which == "fig4" || which == "fig1" {
        fig4_speed::run(opts)?;
        ran = true;
    }
    if all || which == "tab1" {
        table1_k::run(opts)?;
        ran = true;
    }
    if all || which == "fig5" || which == "tab8" {
        fig5_recycled::run(opts, which == "tab8" || all)?;
        ran = true;
    }
    if all || which == "tab2" {
        table2_seq::run(opts)?;
        ran = true;
    }
    if all || which == "tab6" {
        table6_moe::run(opts)?;
        ran = true;
    }
    if all || which == "tab7" {
        table7_selection::run(opts)?;
        ran = true;
    }
    if all || which == "bert" || which == "appE" {
        bert_mlm::run(opts)?;
        ran = true;
    }
    if !ran {
        anyhow::bail!(
            "unknown experiment '{which}' (try: fig4 tab1 tab2 tab3 tab6 tab7 fig5 tab8 bert all)"
        );
    }
    Ok(())
}

/// Write a CSV table under results/.
pub fn write_csv(name: &str, header: &str, rows: &[String]) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(format!("results/{name}.csv"), text)?;
    Ok(())
}
