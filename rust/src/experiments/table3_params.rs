//! Tables 3, 4, 5: parameter counts + train speed.
//!
//! Parameter columns are *analytic at the paper's real scales* and must
//! match the paper's numbers (asserted in unit tests of
//! `model::counting`). Speed columns: measured at testbed scale +
//! TPUv3-roofline estimates at paper scale.

use crate::config::{paper_preset, Variant};
use crate::coordinator::pipeline::PipelineOptions;
use crate::experiments::{latency, write_csv};
use crate::model::counting::count_params;
use crate::runtime::client::Client;
use crate::sim::roofline::{estimate, TPU_V3_CORE};
use anyhow::Result;

/// Paper Table 3 reference values (emb, non-emb, train speed ex/s/core).
const PAPER_TABLE3: &[(&str, f64, f64, f64)] = &[
    ("S", 3.29e7, 3.78e7, 166.1),
    ("S + AltUp", 6.58e7, 3.99e7, 119.4),
    ("B", 4.93e7, 1.98e8, 52.4),
    ("B + AltUp", 9.87e7, 2.12e8, 42.3),
    ("L", 6.58e7, 7.17e8, 17.1),
    ("L + AltUp", 1.32e8, 7.68e8, 14.4),
];

/// Paper Table 5 (XL rows; speed at 400k steps).
const PAPER_TABLE5: &[(&str, f64, f64, f64)] = &[
    ("XL", 1.32e8, 2.72e9, 3.6),
    ("XL + AltUp", 2.63e8, 2.92e9, 3.0),
];

pub fn print_table() -> Result<()> {
    println!("\n=== Tables 3 & 5: parameter counts + speed (paper scale, analytic) ===");
    println!(
        "{:<14} {:>12} {:>12} | {:>12} {:>12} | {:>9} {:>10}",
        "model", "paper emb", "ours emb", "paper nonemb", "ours nonemb", "paper ex/s", "roofline"
    );
    let mut rows = Vec::new();
    for (label, pe, pn, psp) in PAPER_TABLE3.iter().chain(PAPER_TABLE5.iter()) {
        let (size, variant) = match label.split_once(" + ") {
            Some((s, _)) => (s, Variant::AltUp),
            None => (*label, Variant::Baseline),
        };
        let cfg = paper_preset(size, variant, 2);
        let p = count_params(&cfg);
        let est = estimate(&cfg, &TPU_V3_CORE);
        // examples/sec/core per roofline (8 cores in the paper's setup
        // but speed is reported per core).
        let roofline_eps = cfg.batch_size as f64 / est.train_step_seconds / 8.0;
        println!(
            "{:<14} {:>12.3e} {:>12.3e} | {:>12.3e} {:>12.3e} | {:>9.1} {:>10.1}",
            label, pe, p.embedding as f64, pn, p.non_embedding as f64, psp, roofline_eps
        );
        rows.push(format!(
            "{label},{pe},{},{pn},{},{psp},{roofline_eps:.2}",
            p.embedding, p.non_embedding
        ));
    }
    write_csv(
        "table3_params",
        "model,paper_emb,ours_emb,paper_nonemb,ours_nonemb,paper_exps,roofline_exps",
        &rows,
    )?;

    println!("\n=== Table 4: AltUp vs dense scaling (B-sized, analytic + roofline) ===");
    println!(
        "{:<20} {:>12} {:>12} {:>14} {:>12}",
        "model", "emb", "non-emb", "roofline ex/s", "paper ex/s"
    );
    let paper4: &[(&str, Variant, usize, f64)] = &[
        ("T5 Base", Variant::Baseline, 2, 52.4),
        ("Base + AltUp2x", Variant::AltUp, 2, 42.3),
        ("Base + Dense2X", Variant::DenseWide, 2, 32.9),
        ("Base + AltUp4x", Variant::AltUp, 4, 28.1),
        ("Base + Dense4X", Variant::DenseWide, 4, 12.6),
    ];
    let mut rows4 = Vec::new();
    for (label, variant, k, psp) in paper4 {
        let cfg = paper_preset("B", variant.clone(), *k);
        let p = count_params(&cfg);
        let est = estimate(&cfg, &TPU_V3_CORE);
        let eps = cfg.batch_size as f64 / est.train_step_seconds / 8.0;
        println!(
            "{:<20} {:>12.3e} {:>12.3e} {:>14.1} {:>12.1}",
            label, p.embedding as f64, p.non_embedding as f64, eps, psp
        );
        rows4.push(format!("{label},{},{},{eps:.2},{psp}", p.embedding, p.non_embedding));
    }
    write_csv("table4_dense", "model,emb,nonemb,roofline_exps,paper_exps", &rows4)?;
    Ok(())
}

/// Measured train speed at testbed scale (the Table 3/4 speed column's
/// *shape*: AltUp ~0.8x baseline, Dense2X ~0.6x, Dense4X ~0.25x).
pub fn measured_speed(_opts: &PipelineOptions) -> Result<()> {
    let client = Client::cpu()?;
    println!("\n=== Table 3/4 speed shape (measured, micro scale, 1-core CPU) ===");
    let names = [
        "micro-baseline",
        "micro-altup",
        "micro-altup-k4",
        "micro-dense2x",
        "micro-dense4x",
        "micro-recycled",
    ];
    let mut base_eps = None;
    let mut rows = Vec::new();
    println!("{:<18} {:>12} {:>12} {:>10}", "artifact", "train ms", "examples/s", "vs base");
    for name in names {
        if !latency::available(name) {
            continue;
        }
        let l = latency::measure(&client, name)?;
        if name == "micro-baseline" {
            base_eps = Some(l.train_examples_per_sec);
        }
        let rel = base_eps.map(|b| l.train_examples_per_sec / b).unwrap_or(1.0);
        println!(
            "{:<18} {:>12.2} {:>12.1} {:>9.2}x",
            name,
            l.train_s * 1e3,
            l.train_examples_per_sec,
            rel
        );
        rows.push(format!("{name},{:.4},{:.2},{rel:.3}", l.train_s, l.train_examples_per_sec));
    }
    write_csv("table34_speed_measured", "artifact,train_s,examples_per_s,vs_base", &rows)?;
    println!(
        "paper shape: AltUp2x 0.81x, Dense2X 0.63x, AltUp4x 0.54x, Dense4X 0.24x of baseline"
    );
    Ok(())
}
