//! Appendix E: the lightweight-BERT MLM probe.
//!
//! The paper reports 54.7 -> 56.2 MLM accuracy from adding AltUp to a
//! small BERT. We reproduce the *objective* shape by switching span
//! corruption to single-token spans (mean_span=1), which is masked-token
//! prediction re-expressed text-to-text; the claim under test is the
//! same — AltUp's widened representation lifts masked-prediction
//! accuracy at matched compute.

use crate::coordinator::metrics::MetricsLog;
use crate::coordinator::pipeline::PipelineOptions;
use crate::coordinator::trainer::{DataSource, TrainOptions, Trainer};
use crate::data::batcher::PretrainBatcher;
use crate::data::span::SpanConfig;
use crate::experiments::write_csv;
use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use crate::runtime::session::Session;
use anyhow::Result;

pub fn run(opts: &PipelineOptions) -> Result<()> {
    let client = Client::cpu()?;
    println!("\n=== Appendix E: MLM-style probe (mean_span=1) ===");
    println!("paper: lightweight BERT 54.7 -> +AltUp 56.2 MLM accuracy");
    let mut rows = Vec::new();
    let mut accs = Vec::new();
    for name in ["micro-baseline", "micro-altup"] {
        let artifact = load_named(name)?;
        let cfg = artifact.config.clone();
        let session = Session::open(&client, artifact, opts.seed)?;
        let mut batcher = PretrainBatcher::new(
            cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, opts.seed ^ 0xB42,
        );
        batcher.set_span_config(SpanConfig { corrupt_rate: 0.15, mean_span: 1.0 });
        let mut trainer =
            Trainer::new(session, DataSource::Pretrain(batcher), MetricsLog::in_memory());
        let topts = TrainOptions {
            steps: opts.pretrain_steps,
            warmup: opts.warmup,
            log_every: 100,
            verbose: opts.verbose,
            ..Default::default()
        };
        trainer.run(&client, &topts)?;
        let ev = trainer.eval(&client, opts.eval_batches)?;
        println!("  {name:<16} MLM-style acc {:.2}%", ev.accuracy * 100.0);
        rows.push(format!("{name},{:.4}", ev.accuracy));
        accs.push(ev.accuracy);
    }
    write_csv("appE_mlm", "model,mlm_acc", &rows)?;
    if accs.len() == 2 {
        println!(
            "  shape: AltUp {} baseline ({:+.2}pp; paper +1.5pp)",
            if accs[1] >= accs[0] { ">=" } else { "<" },
            (accs[1] - accs[0]) * 100.0
        );
    }
    Ok(())
}
