//! Shared latency rig: measured CPU step time per artifact (forward and
//! train), used by Fig. 1/4/5 and Tables 2-4.
//!
//! §Perf L4: besides the headline per-step time, the rig now reports
//! where a train step's wall-clock goes — PJRT execute vs. host
//! marshalling vs. host<->device transfer — and can measure under an
//! explicit `CacheMode` for device-resident vs. host-round-trip A/Bs
//! (`benches/step_latency.rs --ab`).

use crate::data::batcher::PretrainBatcher;
use crate::runtime::artifact::{artifacts_root, load_named};
use crate::runtime::client::Client;
use crate::runtime::session::{CacheMode, Session};
use crate::util::bench;
use anyhow::Result;
use std::cell::Cell;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct Latency {
    pub artifact: String,
    /// Mean forward-pass seconds per batch (None if no forward HLO).
    pub forward_s: Option<f64>,
    /// Mean train-step seconds per batch.
    pub train_s: f64,
    /// Examples per second per core during training (paper's speed unit).
    pub train_examples_per_sec: f64,
    /// Cache mode the train measurement ran under.
    pub mode: CacheMode,
    /// Per-train-step wall-clock split, in seconds (§Perf L4).
    pub train_exec_s: f64,
    pub train_marshal_s: f64,
    pub train_transfer_s: f64,
}

pub fn available(name: &str) -> bool {
    artifacts_root().join(name).join("meta.json").exists()
}

/// Measure one artifact's latencies under the session's default cache
/// mode (compiles on first use, cached).
pub fn measure(client: &Client, name: &str) -> Result<Latency> {
    measure_with_mode(client, name, CacheMode::from_env())
}

/// Measure under an explicit cache mode (device-resident vs. host
/// round-trip A/B; avoids racing on process-global env vars).
pub fn measure_with_mode(client: &Client, name: &str, mode: CacheMode) -> Result<Latency> {
    let artifact = load_named(name)?;
    let cfg = artifact.config.clone();
    let mut b = PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 3);
    let batch = b.next_batch();

    let forward_s = if artifact.has("forward") {
        let mut s = Session::open_eval(client, artifact.clone(), 0)?;
        s.set_cache_mode(mode)?;
        let st = bench::bench(
            &format!("{name}:fwd"),
            2,
            5,
            Duration::from_millis(400),
            || s.forward_step(client, &batch).unwrap(),
        );
        Some(st.mean.as_secs_f64())
    } else {
        None
    };

    let mut s = Session::open(client, artifact, 0)?;
    s.set_cache_mode(mode)?;
    // Warm up outside the harness (compile + the one-time cold param
    // upload land here), then zero the split counters so that the
    // exec/marshal/transfer breakdown covers exactly the measured
    // iterations — i.e. split_ms actually decomposes train_ms.
    for _ in 0..2 {
        s.train_step(client, 1e-3, 1, &batch)?;
    }
    s.exec_seconds = 0.0;
    s.marshal_seconds = 0.0;
    s.transfer_seconds = 0.0;
    let iters = Cell::new(0usize);
    let st = bench::bench(
        &format!("{name}:train"),
        0,
        5,
        Duration::from_millis(600),
        || {
            s.train_step(client, 1e-3, 1, &batch).unwrap();
            iters.set(iters.get() + 1);
        },
    );
    let train_s = st.mean.as_secs_f64();
    let n = iters.get().max(1) as f64;
    Ok(Latency {
        artifact: name.to_string(),
        forward_s,
        train_s,
        train_examples_per_sec: cfg.batch_size as f64 / train_s,
        mode,
        train_exec_s: s.exec_seconds / n,
        train_marshal_s: s.marshal_seconds / n,
        train_transfer_s: s.transfer_seconds / n,
    })
}
