//! Shared latency rig: measured CPU step time per artifact (forward and
//! train), used by Fig. 1/4/5 and Tables 2-4.

use crate::data::batcher::PretrainBatcher;
use crate::runtime::artifact::{artifacts_root, load_named};
use crate::runtime::client::Client;
use crate::runtime::session::Session;
use crate::util::bench;
use anyhow::Result;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct Latency {
    pub artifact: String,
    /// Mean forward-pass seconds per batch (None if no forward HLO).
    pub forward_s: Option<f64>,
    /// Mean train-step seconds per batch.
    pub train_s: f64,
    /// Examples per second per core during training (paper's speed unit).
    pub train_examples_per_sec: f64,
}

pub fn available(name: &str) -> bool {
    artifacts_root().join(name).join("meta.json").exists()
}

/// Measure one artifact's latencies (compiles on first use, cached).
pub fn measure(client: &Client, name: &str) -> Result<Latency> {
    let artifact = load_named(name)?;
    let cfg = artifact.config.clone();
    let mut b = PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 3);
    let batch = b.next_batch();

    let forward_s = if artifact.has("forward") {
        let mut s = Session::open_eval(client, artifact.clone(), 0)?;
        let st = bench::bench(
            &format!("{name}:fwd"),
            2,
            5,
            Duration::from_millis(400),
            || s.forward_step(client, &batch).unwrap(),
        );
        Some(st.mean.as_secs_f64())
    } else {
        None
    };

    let mut s = Session::open(client, artifact, 0)?;
    let st = bench::bench(
        &format!("{name}:train"),
        2,
        5,
        Duration::from_millis(600),
        || {
            s.train_step(1e-3, 1, &batch).unwrap();
        },
    );
    let train_s = st.mean.as_secs_f64();
    Ok(Latency {
        artifact: name.to_string(),
        forward_s,
        train_s,
        train_examples_per_sec: cfg.batch_size as f64 / train_s,
    })
}
