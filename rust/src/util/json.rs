//! Minimal JSON parser + serializer (offline build: no serde).
//!
//! Supports the full JSON grammar we produce/consume: objects, arrays,
//! strings with escapes, numbers, booleans, null. Numbers are kept as
//! f64 (adequate: meta.json only carries shapes/counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // -- accessors ----------------------------------------------------
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    // -- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- parse ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }
    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }
    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }
    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

// -- serialize ---------------------------------------------------------

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => esc(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    esc(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"x",true,null],"m":{"n":-3}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"a\" :\t1 } ").unwrap();
        assert_eq!(v.get("a").as_i64(), Some(1));
    }
}
