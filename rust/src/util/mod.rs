//! Small self-contained substrates (offline build: no serde/clap/
//! criterion/proptest/tokio — see DESIGN.md §4 substitutions).

pub mod bench;
pub mod cli;
pub mod env;
pub mod json;
pub mod lru;
pub mod prop;
pub mod rng;
pub mod threadpool;
