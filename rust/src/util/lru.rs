//! Shared least-recently-used eviction policy (§Perf L9).
//!
//! Two caches need identical LRU bookkeeping: the session's bucketed
//! executable caches (`runtime::session::BucketLru`) and the prefix-page
//! cache over the paged decode-state pool (`runtime::pages::PrefixCache`).
//! Before L9 the ordering logic lived inline in `BucketLru`; a second
//! hand-rolled copy for prefix pages would have meant two subtly
//! divergent recency implementations guarding device memory. This module
//! extracts the ordering into one policy the two caches share.
//!
//! The policy tracks *keys only* — callers own the values (executables,
//! page ids) and decide what eviction means. `victim` takes an
//! evictability predicate so callers can pin entries (a prefix page with
//! a live slot reference must never be evicted; see `runtime::pages`).
//!
//! Capacity stays out of the policy on purpose: the executable cache
//! evicts on entry count, the prefix cache on free-page pressure.
//! Deciding *when* to evict is the cache's job; the policy only answers
//! *which* key goes next.

/// What a cache needs from an eviction policy: recency notes on
/// insert/touch/remove, and a victim query filtered by an
/// evictability predicate.
pub trait EvictionPolicy<K: Copy + PartialEq> {
    /// Record a newly inserted key (becomes most recent).
    fn note_insert(&mut self, key: K);
    /// Record a use of an existing key (moves to most recent).
    /// Unknown keys are ignored.
    fn note_touch(&mut self, key: K);
    /// Forget a key (e.g. the cache evicted or invalidated it).
    fn note_remove(&mut self, key: K);
    /// The least-desirable key for which `evictable` holds, or `None`
    /// when every tracked key is pinned.
    fn victim(&self, evictable: &dyn Fn(K) -> bool) -> Option<K>;
    /// Number of tracked keys.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Least-recently-used ordering over a small key set.
///
/// Backed by a `Vec` kept in recency order (front = least recent), the
/// same representation the pre-L9 `BucketLru` used: both client caches
/// hold at most a handful of buckets / a few hundred pages, so linear
/// scans beat pointer-chased list nodes and keep the code obviously
/// correct.
#[derive(Debug, Default)]
pub struct LruPolicy<K> {
    /// Keys in recency order: `order[0]` is the LRU candidate.
    order: Vec<K>,
}

impl<K: Copy + PartialEq> LruPolicy<K> {
    pub fn new() -> LruPolicy<K> {
        LruPolicy { order: Vec::new() }
    }

    /// Keys least-recent first (the executable cache exposes this for
    /// tests and debugging).
    pub fn keys(&self) -> impl Iterator<Item = &K> {
        self.order.iter()
    }
}

/// A bounded value-carrying cache over `LruPolicy`: the policy's key
/// ordering plus what every capacity-evicting cache needs on top —
/// value storage, a hard entry cap, and exactly-once hand-back of
/// evicted entries.
///
/// Extracted in §L10 from `runtime::session::BucketLru` (now a type
/// alias over this) so the next cap-bounded cache doesn't re-derive
/// the same insert/evict loop. Callers that evict on external pressure
/// instead of entry count (the prefix-page cache) keep composing
/// `LruPolicy` directly.
pub struct LruCache<K, V> {
    values: Vec<(K, V)>,
    order: LruPolicy<K>,
    cap: usize,
}

impl<K: Copy + PartialEq, V> LruCache<K, V> {
    /// A cache holding at most `cap` entries (clamped to >= 1).
    pub fn new(cap: usize) -> LruCache<K, V> {
        LruCache { values: Vec::new(), order: LruPolicy::new(), cap: cap.max(1) }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Look up `key`, marking it most-recently-used on a hit.
    pub fn get(&mut self, key: K) -> Option<&V> {
        let pos = self.values.iter().position(|(k, _)| *k == key)?;
        self.order.note_touch(key);
        self.values.get(pos).map(|(_, v)| v)
    }

    /// Insert a new entry (the key must not be present) and return
    /// everything evicted to respect `cap`, least-recently-used first.
    /// Each evicted entry is returned exactly once — the caller owns
    /// releasing its backing resource (e.g. `Client::evict`).
    pub fn insert(&mut self, key: K, value: V) -> Vec<(K, V)> {
        debug_assert!(
            self.values.iter().all(|(k, _)| *k != key),
            "LruCache::insert on a present key"
        );
        self.values.push((key, value));
        self.order.note_insert(key);
        let mut evicted = Vec::new();
        while self.values.len() > self.cap {
            // Entries are never pinned here: the LRU key always goes.
            let victim = self.order.victim(&|_| true).expect("non-empty over-cap cache");
            self.order.note_remove(victim);
            let pos = self
                .values
                .iter()
                .position(|(k, _)| *k == victim)
                .expect("policy key backed by a value");
            evicted.push(self.values.remove(pos));
        }
        evicted
    }

    /// Keys currently cached, least-recently-used first.
    pub fn keys(&self) -> Vec<K> {
        self.order.keys().copied().collect()
    }
}

impl<K: Copy + PartialEq> EvictionPolicy<K> for LruPolicy<K> {
    fn note_insert(&mut self, key: K) {
        debug_assert!(
            !self.order.contains(&key),
            "LruPolicy::note_insert on an already-tracked key"
        );
        self.order.push(key);
    }

    fn note_touch(&mut self, key: K) {
        if let Some(pos) = self.order.iter().position(|k| *k == key) {
            let k = self.order.remove(pos);
            self.order.push(k);
        }
    }

    fn note_remove(&mut self, key: K) {
        if let Some(pos) = self.order.iter().position(|k| *k == key) {
            self.order.remove(pos);
        }
    }

    fn victim(&self, evictable: &dyn Fn(K) -> bool) -> Option<K> {
        self.order.iter().copied().find(|&k| evictable(k))
    }

    fn len(&self) -> usize {
        self.order.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_order_is_least_recent_first() {
        let mut p = LruPolicy::new();
        for k in [1usize, 2, 3] {
            p.note_insert(k);
        }
        assert_eq!(p.victim(&|_| true), Some(1));
        p.note_touch(1); // 1 becomes most recent; 2 is now LRU
        assert_eq!(p.victim(&|_| true), Some(2));
        p.note_remove(2);
        assert_eq!(p.victim(&|_| true), Some(3));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn touch_on_unknown_key_is_a_noop() {
        let mut p = LruPolicy::new();
        p.note_insert(7usize);
        p.note_touch(99);
        p.note_remove(99);
        assert_eq!(p.victim(&|_| true), Some(7));
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn pinned_keys_are_skipped_not_evicted() {
        // The prefix cache pins pages whose refcount shows a live slot
        // reference; the policy must pass over them to the next LRU key.
        let mut p = LruPolicy::new();
        for k in [10usize, 20, 30] {
            p.note_insert(k);
        }
        assert_eq!(p.victim(&|k| k != 10), Some(20), "pinned LRU head skipped");
        assert_eq!(p.victim(&|k| k == 30), Some(30));
        assert_eq!(p.victim(&|_| false), None, "all pinned -> no victim");
        assert_eq!(p.len(), 3, "victim() never mutates");
    }

    #[test]
    fn keys_iterate_lru_first() {
        let mut p = LruPolicy::new();
        for k in [4usize, 5, 6] {
            p.note_insert(k);
        }
        p.note_touch(4);
        let keys: Vec<usize> = p.keys().copied().collect();
        assert_eq!(keys, vec![5, 6, 4]);
    }
}
