//! Deterministic SplitMix64 RNG (no external rand crates in the offline
//! build). Used for data generation, parameter init, and property tests.

/// SplitMix64: tiny, fast, and good enough for synthetic data and init.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fork a child RNG with a label (stable streams per component).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
