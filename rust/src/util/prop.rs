//! Mini property-testing harness (offline build: no proptest).
//!
//! `forall(seed, cases, gen, check)` draws `cases` random inputs from
//! `gen` and asserts `check`. On failure it performs greedy shrinking
//! via the generator's `shrink` candidates and panics with the minimal
//! failing case. Deterministic per seed.

use super::rng::Rng;
use std::fmt::Debug;

/// A generator: draws a value and can propose smaller variants.
pub trait Gen {
    type Value: Clone + Debug;
    fn draw(&self, rng: &mut Rng) -> Self::Value;
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run the property; panics with the minimal counterexample.
pub fn forall<G: Gen>(seed: u64, cases: usize, gen: &G, check: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.draw(&mut rng);
        if !check(&v) {
            // Greedy shrink.
            let mut cur = v.clone();
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 1000 {
                improved = false;
                rounds += 1;
                for cand in gen.shrink(&cur) {
                    if !check(&cand) {
                        cur = cand;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}):\n  original: {v:?}\n  shrunk:   {cur:?}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------

/// usize in [lo, hi] inclusive; shrinks toward lo.
pub struct UsizeIn(pub usize, pub usize);
impl Gen for UsizeIn {
    type Value = usize;
    fn draw(&self, rng: &mut Rng) -> usize {
        rng.range(self.0, self.1 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Vec of u32 token ids in [1, vocab); shrinks by halving length.
pub struct TokenSeq {
    pub vocab: u32,
    pub min_len: usize,
    pub max_len: usize,
}
impl Gen for TokenSeq {
    type Value = Vec<u32>;
    fn draw(&self, rng: &mut Rng) -> Vec<u32> {
        let len = rng.range(self.min_len, self.max_len + 1);
        (0..len).map(|_| 1 + rng.next_below(self.vocab as u64 - 1) as u32).collect()
    }
    fn shrink(&self, v: &Vec<u32>) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..(v.len() / 2).max(self.min_len)].to_vec());
            let mut w = v.clone();
            w.pop();
            out.push(w);
        }
        // simplify values toward 1
        if v.iter().any(|&t| t > 1) {
            out.push(v.iter().map(|_| 1).collect());
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);
impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn draw(&self, rng: &mut Rng) -> Self::Value {
        (self.0.draw(rng), self.1.draw(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(1, 200, &UsizeIn(0, 100), |&x| x <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_shrinks() {
        forall(1, 500, &UsizeIn(0, 1000), |&x| x < 500);
    }

    #[test]
    fn token_seq_in_range() {
        forall(2, 100, &TokenSeq { vocab: 50, min_len: 1, max_len: 32 }, |v| {
            !v.is_empty() && v.iter().all(|&t| t >= 1 && t < 50)
        });
    }

    #[test]
    fn pair_draws_both() {
        let gen = Pair(UsizeIn(1, 5), UsizeIn(10, 20));
        forall(3, 100, &gen, |(a, b)| *a <= 5 && *b >= 10);
    }
}
