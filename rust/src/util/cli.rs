//! Tiny CLI argument parser (offline build: no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args,
//! and generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    known: Vec<(String, String, bool)>, // (name, help, takes_value)
}

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.insert(rest.to_string(), String::new());
                }
            } else {
                a.positional.push(arg.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).map(|s| s.to_string()).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Register an option for usage text (purely documentary).
    pub fn describe(&mut self, name: &str, help: &str, takes_value: bool) {
        self.known.push((name.to_string(), help.to_string(), takes_value));
    }

    pub fn usage(&self, prog: &str, summary: &str) -> String {
        let mut s = format!("{prog} — {summary}\n\noptions:\n");
        for (name, help, tv) in &self.known {
            let arg = if *tv { format!("--{name} <v>") } else { format!("--{name}") };
            s.push_str(&format!("  {arg:24} {help}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(&argv("cmd --steps 100 --quick --name=x pos2"));
        assert_eq!(a.positional, vec!["cmd", "pos2"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!(a.has("quick"));
        assert_eq!(a.get("name"), Some("x"));
        assert_eq!(a.str_or("missing", "d"), "d");
    }

    #[test]
    fn flag_before_flag() {
        let a = Args::parse(&argv("--quick --steps 5"));
        assert!(a.has("quick"));
        assert_eq!(a.usize_or("steps", 0), 5);
    }

    #[test]
    fn numeric_defaults() {
        let a = Args::parse(&argv("--lr 0.5"));
        assert_eq!(a.f64_or("lr", 1.0), 0.5);
        assert_eq!(a.u64_or("seed", 42), 42);
    }
}
