//! Micro-benchmark harness (offline build: no criterion).
//!
//! Measures wall-clock with warmup, reports mean/p50/p95/min and a
//! simple throughput figure. Used by `rust/benches/*` (cargo bench with
//! `harness = false`) and by the experiment harnesses for latency
//! measurements.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }
    pub fn report(&self) -> String {
        format!(
            "{:40} {:>10.3} ms/iter  (p50 {:.3}, p95 {:.3}, min {:.3}, n={})",
            self.name,
            self.mean_ms(),
            self.p50.as_secs_f64() * 1e3,
            self.p95.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iters, then until both
/// `min_iters` and `min_time` are satisfied (bounded by `max_iters`).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize, min_time: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    let max_iters = min_iters.max(10_000);
    while (samples.len() < min_iters || start.elapsed() < min_time) && samples.len() < max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    stats_from(name, samples)
}

/// Convenience: 2 warmup iters, >=5 iters, >=300ms.
pub fn quick<F: FnMut()>(name: &str, f: F) -> Stats {
    bench(name, 2, 5, Duration::from_millis(300), f)
}

pub fn stats_from(name: &str, mut samples: Vec<Duration>) -> Stats {
    assert!(!samples.is_empty());
    samples.sort();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    Stats {
        name: name.to_string(),
        iters: n,
        mean: sum / n as u32,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        min: samples[0],
        max: samples[n - 1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop", 1, 10, Duration::from_millis(1), || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.iters >= 10);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
    }

    #[test]
    fn stats_percentiles() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = stats_from("x", samples);
        assert_eq!(s.min, Duration::from_micros(1));
        assert_eq!(s.p50, Duration::from_micros(51));
        assert!(s.p95 >= Duration::from_micros(95));
    }
}
