//! Typed `ALTUP_*` environment parsing: one parse-with-default helper
//! instead of a hand-rolled `std::env::var(..).ok().and_then(parse)`
//! chain per knob (the pattern had been copied into `ServerOptions`,
//! `Session`, `SimSpec`, and the prefetcher before the §L8 knobs would
//! have added a fourth copy).
//!
//! Semantics shared by every helper: an unset variable, an unparsable
//! value, or a value outside the helper's validity filter all fall back
//! to the default — a typo'd knob degrades to stock behavior instead of
//! crashing a server at startup. Values are trimmed before parsing so
//! `ALTUP_SPEC_GAMMA="4 "` (a common shell-quoting artifact) works.
//!
//! Each public helper is a thin env read over a pure parsing/filter
//! function; the pure layer is what the unit tests exercise (mutating
//! the process environment from the parallel test runner would race
//! `getenv` on other test threads).

/// Trim-then-parse, shared by every typed helper.
fn parse_trimmed<T: std::str::FromStr>(raw: Option<String>) -> Option<T> {
    raw.and_then(|s| s.trim().parse::<T>().ok())
}

fn at_least(v: Option<usize>, min: usize, default: usize) -> usize {
    v.filter(|&n| n >= min).unwrap_or(default)
}

fn finite_or(v: Option<f64>, default: f64) -> f64 {
    v.filter(|x| x.is_finite()).unwrap_or(default)
}

fn nonzero(v: Option<u64>) -> Option<u64> {
    v.filter(|&x| x > 0)
}

/// Presence flag (`ALTUP_NO_*` style): set at all — even to the empty
/// string — means true.
pub fn flag(key: &str) -> bool {
    std::env::var_os(key).is_some()
}

pub fn usize_or(key: &str, default: usize) -> usize {
    usize_or_from(std::env::var(key).ok(), default)
}

/// `usize` with a validity floor: values below `min` fall back to the
/// default (e.g. replica counts must be >= 1).
pub fn usize_at_least(key: &str, min: usize, default: usize) -> usize {
    usize_at_least_from(std::env::var(key).ok(), min, default)
}

pub fn u64_or(key: &str, default: u64) -> u64 {
    u64_or_from(std::env::var(key).ok(), default)
}

pub fn f64_or(key: &str, default: f64) -> f64 {
    f64_or_from(std::env::var(key).ok(), default)
}

/// Optional knob where 0 (or unset / unparsable) means "off" — e.g.
/// `ALTUP_REQUEST_TIMEOUT_MS`.
pub fn opt_u64_nonzero(key: &str) -> Option<u64> {
    opt_u64_nonzero_from(std::env::var(key).ok())
}

// Pure cores behind each typed accessor: the public helpers above are
// one env read plus one of these, so the fallback contract per
// accessor is testable without touching the process environment.

fn usize_or_from(raw: Option<String>, default: usize) -> usize {
    parse_trimmed(raw).unwrap_or(default)
}

fn usize_at_least_from(raw: Option<String>, min: usize, default: usize) -> usize {
    at_least(parse_trimmed(raw), min, default)
}

fn u64_or_from(raw: Option<String>, default: u64) -> u64 {
    parse_trimmed(raw).unwrap_or(default)
}

fn f64_or_from(raw: Option<String>, default: f64) -> f64 {
    finite_or(parse_trimmed(raw), default)
}

fn opt_u64_nonzero_from(raw: Option<String>) -> Option<u64> {
    nonzero(parse_trimmed(raw))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The parsing/filter layer is tested as pure functions; the only
    // real env reads are against keys guaranteed unset (reading the
    // environment is safe — mutating it from parallel test threads is
    // the getenv/setenv race these tests deliberately avoid).

    fn s(v: &str) -> Option<String> {
        Some(v.to_string())
    }

    #[test]
    fn parse_with_default_and_trim() {
        assert_eq!(parse_trimmed::<usize>(s("17")), Some(17));
        assert_eq!(parse_trimmed::<usize>(s("  42 ")), Some(42), "whitespace trimmed");
        assert_eq!(parse_trimmed::<usize>(s("not-a-number")), None, "garbage -> None");
        assert_eq!(parse_trimmed::<usize>(s("")), None);
        assert_eq!(parse_trimmed::<usize>(None), None);
        assert_eq!(parse_trimmed::<u64>(s("9000000000")), Some(9_000_000_000));
        assert_eq!(parse_trimmed::<f64>(s("0.5")), Some(0.5));
        assert_eq!(parse_trimmed::<usize>(s("-3")), None, "negative usize rejected");
    }

    #[test]
    fn validity_floor_and_nonzero_opt() {
        assert_eq!(at_least(Some(0), 1, 2), 2, "below floor -> default");
        assert_eq!(at_least(Some(5), 1, 2), 5);
        assert_eq!(at_least(None, 1, 2), 2);
        assert_eq!(nonzero(Some(0)), None, "0 means off");
        assert_eq!(nonzero(Some(5)), Some(5));
        assert_eq!(nonzero(None), None);
    }

    #[test]
    fn float_knob_rejects_non_finite() {
        assert_eq!(finite_or(parse_trimmed(s("NaN")), 0.75), 0.75, "NaN falls back");
        assert_eq!(finite_or(parse_trimmed(s("inf")), 0.75), 0.75);
        assert_eq!(finite_or(parse_trimmed(s("0.5")), 0.75), 0.5);
        assert_eq!(finite_or(None, 0.8), 0.8);
    }

    /// §L10 satellite: every malformed shape an operator can type into
    /// an `ALTUP_*` knob — garbage text, negatives, overflow past the
    /// integer width, scientific notation, blank values — must fall
    /// back to the accessor's default without panicking, pinned per
    /// typed accessor (not just for the shared parse layer).
    #[test]
    fn malformed_values_fall_back_per_accessor() {
        let bad = [
            "abc",                      // non-numeric
            "-3",                       // negative into unsigned
            "1e3",                      // scientific notation (ints reject)
            "99999999999999999999999",  // overflows u64/usize
            "",                         // set-but-empty
            "   ",                      // whitespace only
            "4.5",                      // fractional into an int knob
            "0x10",                     // hex prefix (FromStr rejects)
        ];
        for raw in bad {
            assert_eq!(usize_or_from(s(raw), 7), 7, "usize_or({raw:?})");
            assert_eq!(usize_at_least_from(s(raw), 1, 8), 8, "usize_at_least({raw:?})");
            assert_eq!(u64_or_from(s(raw), 9), 9, "u64_or({raw:?})");
            assert_eq!(opt_u64_nonzero_from(s(raw)), None, "opt_u64_nonzero({raw:?})");
        }
        // f64 parses more shapes ("1e3", "4.5", "-3" are valid floats);
        // its malformed set is the truly unparsable plus non-finite.
        for raw in ["abc", "", "   ", "NaN", "inf", "-inf", "0x10"] {
            assert_eq!(f64_or_from(s(raw), 0.75), 0.75, "f64_or({raw:?})");
        }
        assert_eq!(f64_or_from(s("1e3"), 0.75), 1000.0, "f64 accepts scientific");
        assert_eq!(f64_or_from(s("-3"), 0.75), -3.0, "f64 accepts negatives");
    }

    /// Well-formed values survive each accessor's validity filter.
    #[test]
    fn well_formed_values_pass_per_accessor() {
        assert_eq!(usize_or_from(s(" 12 "), 7), 12);
        assert_eq!(usize_at_least_from(s("0"), 1, 8), 8, "below floor -> default");
        assert_eq!(usize_at_least_from(s("3"), 1, 8), 3);
        assert_eq!(u64_or_from(s("9000000000"), 9), 9_000_000_000);
        assert_eq!(f64_or_from(s("0.5"), 0.75), 0.5);
        assert_eq!(opt_u64_nonzero_from(s("0")), None, "0 means off");
        assert_eq!(opt_u64_nonzero_from(s("250")), Some(250));
    }

    #[test]
    fn unset_keys_fall_back_to_defaults() {
        // Read-only env access on keys nothing sets: exercises the
        // public helpers end-to-end without mutating the environment.
        assert_eq!(usize_or("ALTUP_ENVTEST_NEVER_SET", 3), 3);
        assert_eq!(usize_at_least("ALTUP_ENVTEST_NEVER_SET", 1, 2), 2);
        assert_eq!(u64_or("ALTUP_ENVTEST_NEVER_SET", 7), 7);
        assert_eq!(f64_or("ALTUP_ENVTEST_NEVER_SET", 0.8), 0.8);
        assert_eq!(opt_u64_nonzero("ALTUP_ENVTEST_NEVER_SET"), None);
        assert!(!flag("ALTUP_ENVTEST_NEVER_SET"));
    }
}
