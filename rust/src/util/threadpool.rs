//! Minimal fixed-size thread pool (offline build: no tokio/rayon).
//!
//! Used by the coordinator's eval server for request handling and by
//! the data pipeline for background batch preparation. Plain
//! std::thread + mpsc; jobs are boxed closures.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        Self::named("altup-worker", size)
    }

    /// Pool whose worker threads carry `prefix-<i>` names (the batch
    /// prefetcher and server use this so thread dumps stay readable).
    pub fn named(prefix: &str, size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("{prefix}-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(tx) }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run `f` over `items` on the pool, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker died")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }
}
