//! AltUp: Alternating Updates for Efficient Transformers (NeurIPS 2023).
//!
//! Three-layer reproduction stack:
//! - Layer 1 (build-time python): Pallas kernels for the AltUp
//!   predict/correct steps and the transformer hot paths.
//! - Layer 2 (build-time python): config-driven T5-style encoder/decoder
//!   in JAX with every paper variant, AOT-lowered to HLO text.
//! - Layer 3 (this crate): the training/serving coordinator. Owns the
//!   event loop, data pipeline, batching, metrics, checkpoints, and the
//!   PJRT runtime that executes the AOT artifacts. Python never runs on
//!   the request path.

pub mod config;
pub mod coordinator;
pub mod model;
pub mod sim;
pub mod data;
pub mod experiments;
pub mod runtime;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
