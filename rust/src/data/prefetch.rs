//! Overlapped batch prefetch (§Perf L5): a background worker prepares
//! batch N+1 (corpus sampling, span corruption, padding) while batch N
//! executes on the device, hiding host data-preparation time behind
//! `exec_seconds`. Double-buffered by default via a bounded channel.
//!
//! The worker produces a fixed number of batches and then hands the
//! source back, so the consumer can reclaim it (stream position intact)
//! and resume direct iteration — e.g. for eval after a training run.

use crate::data::batcher::{Batch, BatchSource};
use crate::util::threadpool::ThreadPool;
use std::sync::mpsc;
use std::time::Instant;

/// How many prepared batches may sit ready ahead of the consumer.
/// `ALTUP_PREFETCH_DEPTH` overrides (min 1); default 2 = double buffer.
pub fn depth_from_env() -> usize {
    crate::util::env::usize_at_least("ALTUP_PREFETCH_DEPTH", 1, 2)
}

/// Whether the trainer should prefetch at all (`ALTUP_NO_PREFETCH=1`
/// restores the synchronous prepare-then-execute baseline for A/Bs).
pub fn enabled_from_env() -> bool {
    !crate::util::env::flag("ALTUP_NO_PREFETCH")
}

pub struct Prefetcher<S: BatchSource + Send + 'static> {
    /// `Option` so `Drop`/`finish` can release the channel first — the
    /// stop signal a worker parked on a full buffer is waiting for.
    rx: Option<mpsc::Receiver<Batch>>,
    done: Option<mpsc::Receiver<S>>,
    pool: Option<ThreadPool>,
    /// Seconds the consumer spent blocked waiting on the worker — the
    /// residual data-preparation time prefetch could not hide.
    pub wait_seconds: f64,
}

impl<S: BatchSource + Send + 'static> Prefetcher<S> {
    /// Move `source` onto a background worker that produces exactly
    /// `steps` batches, keeping at most `depth` ready at a time.
    pub fn spawn(mut source: S, steps: usize, depth: usize) -> Prefetcher<S> {
        let (tx, rx) = mpsc::sync_channel::<Batch>(depth.max(1));
        let (done_tx, done) = mpsc::channel::<S>();
        let pool = ThreadPool::named("altup-prefetch", 1);
        pool.execute(move || {
            for _ in 0..steps {
                let batch = source.next_batch();
                if tx.send(batch).is_err() {
                    break; // consumer went away early
                }
            }
            let _ = done_tx.send(source);
        });
        Prefetcher { rx: Some(rx), done: Some(done), pool: Some(pool), wait_seconds: 0.0 }
    }

    /// The next prepared batch; `None` once all `steps` batches have
    /// been consumed.
    pub fn next(&mut self) -> Option<Batch> {
        let rx = self.rx.as_ref()?;
        let t0 = Instant::now();
        let batch = rx.recv().ok();
        self.wait_seconds += t0.elapsed().as_secs_f64();
        batch
    }

    /// Stop consuming and reclaim the source plus the accumulated wait
    /// time. Safe to call mid-stream (the worker unblocks and exits).
    /// Returns `None` for the source if the worker thread panicked
    /// mid-production — callers should surface their own error rather
    /// than panic on the cleanup path.
    pub fn finish(mut self) -> (Option<S>, f64) {
        let wait = self.wait_seconds;
        self.rx.take(); // unblock a worker parked on a full buffer
        let source = self.done.take().and_then(|done| done.recv().ok());
        self.pool.take(); // ThreadPool::drop joins the worker
        (source, wait)
    }
}

/// Dropping a prefetcher mid-stream must not leak its worker thread:
/// release the batch channel (the stop signal), then join the worker
/// via the pool. Field-order drop would do the same for `rx`/`pool`,
/// but only by coincidence of declaration order — this makes the
/// signal-then-join sequence explicit and keeps it ahead of any future
/// field reshuffle. (After `finish` the fields are already `None` and
/// this is a no-op.)
impl<S: BatchSource + Send + 'static> Drop for Prefetcher<S> {
    fn drop(&mut self) {
        self.rx.take(); // signal: worker's next send fails and it exits
        self.done.take();
        self.pool.take(); // join
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::PretrainBatcher;

    fn batcher(seed: u64) -> PretrainBatcher {
        PretrainBatcher::new(2048, 2, 32, 16, seed)
    }

    #[test]
    fn prefetched_stream_matches_direct_iteration() {
        let mut direct = batcher(11);
        let expected: Vec<Vec<i32>> = (0..6).map(|_| direct.next_batch().enc_tokens).collect();
        let mut p = Prefetcher::spawn(batcher(11), 6, 2);
        for exp in &expected {
            assert_eq!(&p.next().unwrap().enc_tokens, exp);
        }
        assert!(p.next().is_none(), "exactly `steps` batches are produced");
    }

    #[test]
    fn finish_returns_source_at_produced_position() {
        // The worker produces all 4 batches; the reclaimed source must
        // continue where the worker left off.
        let mut p = Prefetcher::spawn(batcher(7), 4, 2);
        for _ in 0..4 {
            assert!(p.next().is_some());
        }
        let (source, wait) = p.finish();
        let mut source = source.expect("worker healthy");
        assert!(wait >= 0.0);
        let mut reference = batcher(7);
        for _ in 0..4 {
            reference.next_batch();
        }
        assert_eq!(source.next_batch().enc_tokens, reference.next_batch().enc_tokens);
    }

    /// Dropping the prefetcher mid-stream (without `finish`) must
    /// promptly terminate the worker: the source comes back through
    /// the dropped `done` channel and is destroyed by the exiting
    /// worker, and `Drop` joins the thread before returning.
    #[test]
    fn drop_mid_stream_joins_worker_promptly() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        struct FlaggedSource {
            inner: PretrainBatcher,
            dropped: Arc<AtomicBool>,
        }
        impl crate::data::batcher::BatchSource for FlaggedSource {
            fn next_batch(&mut self) -> Batch {
                self.inner.next_batch()
            }
        }
        impl Drop for FlaggedSource {
            fn drop(&mut self) {
                self.dropped.store(true, Ordering::SeqCst);
            }
        }

        let dropped = Arc::new(AtomicBool::new(false));
        let source = FlaggedSource { inner: batcher(5), dropped: Arc::clone(&dropped) };
        // Far more steps than will ever be consumed: without the drop
        // signal the worker would grind through all of them.
        let mut p = Prefetcher::spawn(source, 1_000_000, 1);
        assert!(p.next().is_some());
        let t0 = std::time::Instant::now();
        drop(p);
        // Drop returned == worker joined == source destroyed.
        assert!(dropped.load(Ordering::SeqCst), "worker exited and dropped the source");
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "drop must terminate the stream promptly, not run out the steps"
        );
    }

    #[test]
    fn early_finish_does_not_deadlock() {
        // Consumer takes one batch of many, then bails; the worker may
        // be parked on the bounded buffer and must still shut down.
        let mut p = Prefetcher::spawn(batcher(3), 100, 1);
        let _ = p.next();
        let (source, _wait) = p.finish();
        assert!(source.is_some());
    }
}
