//! Batcher: pads/truncates examples into the fixed (B, enc_len) /
//! (B, dec_len) geometry the AOT executables were lowered with.

use crate::data::corpus::Corpus;
use crate::data::span::{corrupt, SpanConfig};
use crate::data::tasks::{Example, Task};
use crate::data::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// A dense, padded batch matching the artifact geometry.
#[derive(Debug, Clone)]
pub struct Batch {
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    pub enc_tokens: Vec<i32>,
    pub dec_input: Vec<i32>,
    pub dec_targets: Vec<i32>,
    /// Reference answers for EM/F1 (empty for pretrain batches).
    pub answers: Vec<Vec<u32>>,
}

fn pad_into(dst: &mut Vec<i32>, src: &[i32], len: usize) {
    let n = src.len().min(len);
    dst.extend_from_slice(&src[..n]);
    dst.resize(dst.len() + (len - n), 0);
}

impl Batch {
    pub fn from_examples(examples: &[Example], b: usize, enc_len: usize, dec_len: usize) -> Batch {
        assert_eq!(examples.len(), b);
        let mut enc = Vec::with_capacity(b * enc_len);
        let mut di = Vec::with_capacity(b * dec_len);
        let mut dt = Vec::with_capacity(b * dec_len);
        let mut answers = Vec::with_capacity(b);
        for ex in examples {
            pad_into(&mut enc, &ex.enc, enc_len);
            pad_into(&mut di, &ex.dec_input, dec_len);
            pad_into(&mut dt, &ex.dec_targets, dec_len);
            answers.push(ex.answer.clone());
        }
        Batch {
            batch_size: b,
            enc_len,
            dec_len,
            enc_tokens: enc,
            dec_input: di,
            dec_targets: dt,
            answers,
        }
    }

    /// Row `i`'s encoder tokens.
    pub fn enc_row(&self, i: usize) -> &[i32] {
        &self.enc_tokens[i * self.enc_len..(i + 1) * self.enc_len]
    }
}

/// Anything that can produce the next dense batch. Implemented by the
/// concrete batchers and the trainer's `DataSource`, and what the
/// overlapped prefetcher (`data::prefetch`) is generic over.
pub trait BatchSource {
    fn next_batch(&mut self) -> Batch;
}

impl BatchSource for PretrainBatcher {
    fn next_batch(&mut self) -> Batch {
        PretrainBatcher::next_batch(self)
    }
}

impl BatchSource for TaskBatcher {
    fn next_batch(&mut self) -> Batch {
        TaskBatcher::next_batch(self)
    }
}

/// Streaming pretrain batch source: corpus -> span corruption -> pad.
pub struct PretrainBatcher {
    corpus: Corpus,
    tk: Tokenizer,
    span_cfg: SpanConfig,
    rng: Rng,
    next_doc: u64,
    seed: u64,
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
}

impl PretrainBatcher {
    pub fn new(
        vocab_size: usize,
        batch_size: usize,
        enc_len: usize,
        dec_len: usize,
        seed: u64,
    ) -> PretrainBatcher {
        let tk = Tokenizer::new(vocab_size).expect("vocab");
        PretrainBatcher {
            corpus: Corpus::new(tk.content_slots().saturating_sub(8), seed),
            tk,
            span_cfg: SpanConfig::default(),
            rng: Rng::new(seed ^ 0xBA7C_4E5),
            next_doc: 0,
            seed,
            batch_size,
            enc_len,
            dec_len,
        }
    }

    /// Held-out stream: the *same* corpus distribution (same seed), but
    /// document indices from a disjoint high range the trainer never
    /// reaches — a proper validation split.
    pub fn validation(&self) -> PretrainBatcher {
        let mut v = PretrainBatcher::new(
            self.tk.vocab_size,
            self.batch_size,
            self.enc_len,
            self.dec_len,
            self.seed,
        );
        v.next_doc = 1 << 40;
        v
    }

    /// Override the span-corruption parameters (e.g. mean_span=1.0
    /// turns the objective into BERT-style single-token MLM — used by
    /// the Appendix-E experiment).
    pub fn set_span_config(&mut self, cfg: SpanConfig) {
        self.span_cfg = cfg;
    }

    pub fn next_batch(&mut self) -> Batch {
        // Documents sized to roughly fill enc_len after corruption.
        let doc_len_max = self.enc_len.saturating_sub(6).max(12);
        let doc_len_min = (doc_len_max * 3 / 4).max(8);
        let mut examples = Vec::with_capacity(self.batch_size);
        for _ in 0..self.batch_size {
            let doc = self.corpus.document(self.next_doc, doc_len_min, doc_len_max);
            self.next_doc += 1;
            let tokens = self.tk.encode_doc(&doc);
            let ex = corrupt(&tokens, self.span_cfg, &self.tk, &mut self.rng);
            examples.push(Example {
                enc: ex.enc,
                dec_input: ex.dec_input,
                dec_targets: ex.dec_targets,
                answer: Vec::new(),
            });
        }
        Batch::from_examples(&examples, self.batch_size, self.enc_len, self.dec_len)
    }
}

/// Finetune batch source over a synthetic benchmark task.
pub struct TaskBatcher {
    pub task: Task,
    next_index: u64,
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
}

impl TaskBatcher {
    pub fn new(task: Task, batch_size: usize, enc_len: usize, dec_len: usize) -> TaskBatcher {
        TaskBatcher { task, next_index: 0, batch_size, enc_len, dec_len }
    }

    /// Eval split: indices from a disjoint high range.
    pub fn eval_split(&mut self) {
        self.next_index = 1 << 40;
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut examples = Vec::with_capacity(self.batch_size);
        for _ in 0..self.batch_size {
            examples.push(self.task.example(self.next_index, self.enc_len.saturating_sub(2)));
            self.next_index += 1;
        }
        Batch::from_examples(&examples, self.batch_size, self.enc_len, self.dec_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskKind;

    #[test]
    fn batch_geometry() {
        let mut b = PretrainBatcher::new(2048, 4, 64, 32, 1);
        let batch = b.next_batch();
        assert_eq!(batch.enc_tokens.len(), 4 * 64);
        assert_eq!(batch.dec_input.len(), 4 * 32);
        assert_eq!(batch.dec_targets.len(), 4 * 32);
    }

    #[test]
    fn batches_advance() {
        let mut b = PretrainBatcher::new(2048, 4, 64, 32, 1);
        let b1 = b.next_batch();
        let b2 = b.next_batch();
        assert_ne!(b1.enc_tokens, b2.enc_tokens);
    }

    #[test]
    fn deterministic_stream() {
        let mut a = PretrainBatcher::new(2048, 4, 64, 32, 9);
        let mut b = PretrainBatcher::new(2048, 4, 64, 32, 9);
        assert_eq!(a.next_batch().enc_tokens, b.next_batch().enc_tokens);
    }

    #[test]
    fn validation_disjoint() {
        let mut train = PretrainBatcher::new(2048, 4, 64, 32, 9);
        let mut val = train.validation();
        assert_ne!(train.next_batch().enc_tokens, val.next_batch().enc_tokens);
    }

    #[test]
    fn task_batches_carry_answers() {
        let task = Task::new(TaskKind::Squad, 2048, 5);
        let mut tb = TaskBatcher::new(task, 4, 64, 32);
        let batch = tb.next_batch();
        assert_eq!(batch.answers.len(), 4);
        assert!(batch.answers.iter().all(|a| !a.is_empty()));
    }

    #[test]
    fn truncation_is_safe() {
        // Examples longer than enc_len are truncated, not panicking.
        let task = Task::new(TaskKind::Glue, 2048, 5);
        let mut tb = TaskBatcher::new(task, 2, 8, 4);
        let batch = tb.next_batch();
        assert_eq!(batch.enc_tokens.len(), 16);
    }

    #[test]
    fn enc_row_slices() {
        let mut b = PretrainBatcher::new(2048, 3, 16, 8, 2);
        let batch = b.next_batch();
        assert_eq!(batch.enc_row(1), &batch.enc_tokens[16..32]);
    }
}
