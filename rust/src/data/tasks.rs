//! Synthetic finetune benchmark tasks, recast text-to-text (paper Sec. 5
//! setting). Substitutes for GLUE / SuperGLUE / SQuAD / TriviaQA with
//! tasks of matching I/O shape and increasing difficulty (DESIGN.md §4):
//!
//!  - `glue`:      single-sentence classification — does the sequence
//!                 contain more "positive"-lexicon words than negative?
//!  - `superglue`: entailment-like — given a premise and a query pair
//!                 (a, b), answer whether `b` ever directly follows `a`
//!                 in the premise (relational, harder).
//!  - `squad`:     extractive QA — given a context and a query word,
//!                 produce the two words that follow its first
//!                 occurrence (span extraction; EM/F1).
//!  - `triviaqa`:  closed-book QA — a fixed seeded key->value map; the
//!                 input is only the key (memorization; EM/F1).
//!
//! All tasks emit the same Example shape as pretraining, so the
//! trainer/eval/decode paths are identical across benchmarks.

use crate::data::corpus::Corpus;
use crate::data::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Example {
    pub enc: Vec<i32>,
    pub dec_input: Vec<i32>,
    pub dec_targets: Vec<i32>,
    /// Reference answer (content word ids) for EM/F1 via greedy decode.
    pub answer: Vec<u32>,
}

fn finish(enc: Vec<i32>, mut dec: Vec<i32>, answer: Vec<u32>) -> Example {
    dec.push(EOS);
    let mut dec_input = Vec::with_capacity(dec.len());
    dec_input.push(PAD);
    dec_input.extend_from_slice(&dec[..dec.len() - 1]);
    Example { enc, dec_input, dec_targets: dec, answer }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Glue,
    SuperGlue,
    Squad,
    TriviaQa,
}

impl TaskKind {
    pub fn from_str(s: &str) -> Option<TaskKind> {
        Some(match s {
            "glue" => TaskKind::Glue,
            "superglue" | "sg" => TaskKind::SuperGlue,
            "squad" => TaskKind::Squad,
            "triviaqa" | "trivia" => TaskKind::TriviaQa,
            _ => return None,
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Glue => "glue",
            TaskKind::SuperGlue => "superglue",
            TaskKind::Squad => "squad",
            TaskKind::TriviaQa => "triviaqa",
        }
    }
    /// Is the headline metric EM/F1 (vs accuracy)?
    pub fn is_generative(&self) -> bool {
        matches!(self, TaskKind::Squad | TaskKind::TriviaQa)
    }
}

/// Generator for one benchmark task over a corpus + tokenizer.
pub struct Task {
    pub kind: TaskKind,
    corpus: Corpus,
    tk: Tokenizer,
    seed: u64,
    /// Class-label words (content ids) for classification tasks.
    label_words: [u32; 2],
    /// Query-marker word separating context from question.
    marker: u32,
}

impl Task {
    pub fn new(kind: TaskKind, vocab_size: usize, seed: u64) -> Task {
        let tk = Tokenizer::new(vocab_size).expect("vocab");
        let slots = tk.content_slots();
        // Reserve the last few content words as labels/markers.
        let label_words = [(slots - 1) as u32, (slots - 2) as u32];
        let marker = (slots - 3) as u32;
        let corpus = Corpus::new(slots.saturating_sub(8).min(slots), seed ^ 0x7A5C);
        Task { kind, corpus, tk, seed, label_words, marker }
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tk
    }

    /// The task's generation seed (eval twins must share it: the glue
    /// lexicon and the triviaqa key->value map are seed-derived).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// A twin task with identical distribution (same seed); pair with
    /// `TaskBatcher::eval_split()` for held-out example indices.
    pub fn eval_twin(&self) -> Task {
        Task::new(self.kind, self.tk.vocab_size, self.seed)
    }

    /// Deterministic example `index` (train/eval split by index range).
    pub fn example(&self, index: u64, max_ctx: usize) -> Example {
        let mut rng = Rng::new(self.seed ^ index.wrapping_mul(0xD1B5_4A32_D192_ED03));
        match self.kind {
            TaskKind::Glue => self.glue(&mut rng, index, max_ctx),
            TaskKind::SuperGlue => self.superglue(&mut rng, index, max_ctx),
            TaskKind::Squad => self.squad(&mut rng, index, max_ctx),
            TaskKind::TriviaQa => self.triviaqa(&mut rng),
        }
    }

    /// "Positive lexicon": words whose (seeded) hash is even.
    fn is_positive(&self, w: u32) -> bool {
        (w.wrapping_mul(0x9E37_79B9) ^ (self.seed as u32)).count_ones() % 2 == 0
    }

    fn glue(&self, rng: &mut Rng, index: u64, max_ctx: usize) -> Example {
        let doc = self.corpus.document(index, 16, max_ctx.saturating_sub(2).max(17));
        let pos = doc.iter().filter(|&&w| self.is_positive(w)).count();
        let label = if 2 * pos > doc.len() { 0 } else { 1 };
        let _ = rng;
        let mut enc = self.tk.encode_doc(&doc);
        enc.push(EOS);
        let ans = self.label_words[label];
        finish(enc, vec![self.tk.encode_word(ans)], vec![ans])
    }

    fn superglue(&self, rng: &mut Rng, index: u64, max_ctx: usize) -> Example {
        let doc = self.corpus.document(index, 16, max_ctx.saturating_sub(5).max(17));
        // Pick the query pair: 50% a real adjacent pair, 50% a random one.
        let (a, b, label) = if rng.next_f64() < 0.5 {
            let i = rng.range(0, doc.len() - 1);
            (doc[i], doc[i + 1], 0usize)
        } else {
            let a = doc[rng.range(0, doc.len())];
            let b = doc[rng.range(0, doc.len())];
            let holds = doc.windows(2).any(|w| w[0] == a && w[1] == b);
            (a, b, if holds { 0 } else { 1 })
        };
        let mut enc = self.tk.encode_doc(&doc);
        enc.push(self.tk.encode_word(self.marker));
        enc.push(self.tk.encode_word(a));
        enc.push(self.tk.encode_word(b));
        enc.push(EOS);
        let ans = self.label_words[label];
        finish(enc, vec![self.tk.encode_word(ans)], vec![ans])
    }

    fn squad(&self, rng: &mut Rng, index: u64, max_ctx: usize) -> Example {
        let doc = self.corpus.document(index, 20, max_ctx.saturating_sub(4).max(21));
        // Query: a word with at least 2 successors; answer = next 2 words
        // after its FIRST occurrence.
        let qpos = rng.range(0, doc.len() - 2);
        let q = doc[qpos];
        let first = doc.iter().position(|&w| w == q).unwrap();
        let mut answer = Vec::new();
        for off in 1..=2 {
            if first + off < doc.len() {
                answer.push(doc[first + off]);
            }
        }
        let mut enc = self.tk.encode_doc(&doc);
        enc.push(self.tk.encode_word(self.marker));
        enc.push(self.tk.encode_word(q));
        enc.push(EOS);
        let dec: Vec<i32> = answer.iter().map(|&w| self.tk.encode_word(w)).collect();
        finish(enc, dec, answer)
    }

    fn triviaqa(&self, rng: &mut Rng) -> Example {
        // Closed-book: key in [0, 512), value pair derived by seeded hash.
        let nkeys = 512.min(self.tk.content_slots() as u64 / 4);
        let key = rng.next_below(nkeys) as u32;
        let v1 = ((key as u64).wrapping_mul(self.seed | 1) >> 7) as u32 % (nkeys as u32);
        let v2 = ((key as u64).wrapping_mul((self.seed | 1).rotate_left(17)) >> 9) as u32
            % (nkeys as u32);
        let answer = vec![v1, v2];
        let enc = vec![
            self.tk.encode_word(self.marker),
            self.tk.encode_word(key),
            EOS,
        ];
        let dec: Vec<i32> = answer.iter().map(|&w| self.tk.encode_word(w)).collect();
        finish(enc, dec, answer)
    }
}

// ---------------------------------------------------------------------
// Metrics: EM / F1 over content words (SQuAD-style)
// ---------------------------------------------------------------------

pub fn exact_match(pred: &[u32], gold: &[u32]) -> f64 {
    if pred == gold {
        1.0
    } else {
        0.0
    }
}

pub fn f1_score(pred: &[u32], gold: &[u32]) -> f64 {
    if pred.is_empty() && gold.is_empty() {
        return 1.0;
    }
    if pred.is_empty() || gold.is_empty() {
        return 0.0;
    }
    let mut gold_counts = std::collections::HashMap::new();
    for &g in gold {
        *gold_counts.entry(g).or_insert(0usize) += 1;
    }
    let mut overlap = 0usize;
    for &p in pred {
        if let Some(c) = gold_counts.get_mut(&p) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / pred.len() as f64;
    let recall = overlap as f64 / gold.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_deterministic() {
        let t = Task::new(TaskKind::Glue, 2048, 1);
        let a = t.example(5, 48);
        let b = t.example(5, 48);
        assert_eq!(a.enc, b.enc);
        assert_eq!(a.dec_targets, b.dec_targets);
    }

    #[test]
    fn all_tasks_wellformed() {
        for kind in [TaskKind::Glue, TaskKind::SuperGlue, TaskKind::Squad, TaskKind::TriviaQa] {
            let t = Task::new(kind, 2048, 3);
            for i in 0..20 {
                let ex = t.example(i, 48);
                assert!(!ex.enc.is_empty(), "{kind:?}");
                assert_eq!(*ex.dec_targets.last().unwrap(), EOS);
                assert_eq!(ex.dec_input[0], PAD);
                assert_eq!(
                    &ex.dec_input[1..],
                    &ex.dec_targets[..ex.dec_targets.len() - 1]
                );
                assert!(!ex.answer.is_empty(), "{kind:?}");
                // answer words appear in the decoder targets
                let tk = t.tokenizer();
                let content = tk.content_of(tk.until_eos(&ex.dec_targets));
                assert_eq!(content, ex.answer, "{kind:?}");
            }
        }
    }

    #[test]
    fn glue_labels_balancedish() {
        let t = Task::new(TaskKind::Glue, 2048, 7);
        let mut counts = [0usize; 2];
        for i in 0..200 {
            let ex = t.example(i, 48);
            let w = ex.answer[0];
            if w == t.label_words[0] {
                counts[0] += 1;
            } else {
                counts[1] += 1;
            }
        }
        assert!(counts[0] > 30 && counts[1] > 30, "{counts:?}");
    }

    #[test]
    fn squad_answer_follows_query() {
        let t = Task::new(TaskKind::Squad, 2048, 9);
        for i in 0..30 {
            let ex = t.example(i, 48);
            let tk = t.tokenizer();
            // last content word before EOS in enc (after marker) is the query
            let body = tk.until_eos(&ex.enc);
            let q = tk.decode_token(body[body.len() - 1]).unwrap();
            let ctx: Vec<u32> = tk.content_of(&body[..body.len() - 2]);
            let first = ctx.iter().position(|&w| w == q).unwrap();
            assert_eq!(ex.answer[0], ctx[first + 1]);
        }
    }

    #[test]
    fn triviaqa_is_functional() {
        // same key -> same answer
        let t = Task::new(TaskKind::TriviaQa, 2048, 11);
        let mut map = std::collections::HashMap::new();
        for i in 0..300 {
            let ex = t.example(i, 48);
            let key = ex.enc[1];
            if let Some(prev) = map.insert(key, ex.answer.clone()) {
                assert_eq!(prev, ex.answer);
            }
        }
    }

    #[test]
    fn metrics() {
        assert_eq!(exact_match(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(exact_match(&[1], &[1, 2]), 0.0);
        assert_eq!(f1_score(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(f1_score(&[1, 3], &[1, 2]), 0.5);
        assert_eq!(f1_score(&[], &[]), 1.0);
        assert_eq!(f1_score(&[], &[1]), 0.0);
    }
}
