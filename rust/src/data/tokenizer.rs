//! Tokenizer: maps corpus word ids / task strings onto the model's
//! vocabulary, reserving the special ids T5-style span corruption needs.
//!
//! Vocabulary layout (model vocab of size V):
//!   0              PAD (also decoder BOS)
//!   1              EOS
//!   2              UNK
//!   3..3+S         sentinels <extra_id_0> .. <extra_id_{S-1}> (S = 32)
//!   3+S..V         content ids (corpus words / task symbols)

use anyhow::{bail, Result};

pub const PAD: i32 = 0;
pub const EOS: i32 = 1;
pub const UNK: i32 = 2;
pub const NUM_SENTINELS: usize = 32;
pub const FIRST_SENTINEL: i32 = 3;
pub const FIRST_CONTENT: i32 = FIRST_SENTINEL + NUM_SENTINELS as i32;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Result<Tokenizer> {
        if vocab_size <= FIRST_CONTENT as usize + 16 {
            bail!("vocab too small: {vocab_size}");
        }
        Ok(Tokenizer { vocab_size })
    }

    /// Number of content slots available for corpus words.
    pub fn content_slots(&self) -> usize {
        self.vocab_size - FIRST_CONTENT as usize
    }

    /// Sentinel id for span i (T5's <extra_id_i>).
    pub fn sentinel(&self, i: usize) -> i32 {
        assert!(i < NUM_SENTINELS, "sentinel overflow");
        FIRST_SENTINEL + i as i32
    }

    pub fn is_sentinel(&self, id: i32) -> bool {
        (FIRST_SENTINEL..FIRST_CONTENT).contains(&id)
    }

    /// Encode a corpus word id to a token id (UNK if out of range).
    pub fn encode_word(&self, word: u32) -> i32 {
        let id = FIRST_CONTENT as i64 + word as i64;
        if (id as usize) < self.vocab_size {
            id as i32
        } else {
            UNK
        }
    }

    pub fn encode_doc(&self, doc: &[u32]) -> Vec<i32> {
        doc.iter().map(|&w| self.encode_word(w)).collect()
    }

    /// Decode a token id back to a word id (None for specials).
    pub fn decode_token(&self, id: i32) -> Option<u32> {
        if id >= FIRST_CONTENT && (id as usize) < self.vocab_size {
            Some((id - FIRST_CONTENT) as u32)
        } else {
            None
        }
    }

    /// Strip specials and return content word ids (used by EM/F1).
    pub fn content_of(&self, ids: &[i32]) -> Vec<u32> {
        ids.iter().filter_map(|&t| self.decode_token(t)).collect()
    }

    /// Truncate at the first EOS (exclusive).
    pub fn until_eos<'a>(&self, ids: &'a [i32]) -> &'a [i32] {
        match ids.iter().position(|&t| t == EOS) {
            Some(p) => &ids[..p],
            None => ids,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_words() {
        let tk = Tokenizer::new(2048).unwrap();
        for w in [0u32, 1, 100, 2000] {
            let id = tk.encode_word(w);
            if (w as usize) < tk.content_slots() {
                assert_eq!(tk.decode_token(id), Some(w));
            } else {
                assert_eq!(id, UNK);
            }
        }
    }

    #[test]
    fn sentinels_distinct_and_flagged() {
        let tk = Tokenizer::new(2048).unwrap();
        let mut seen = std::collections::HashSet::new();
        for i in 0..NUM_SENTINELS {
            let s = tk.sentinel(i);
            assert!(tk.is_sentinel(s));
            assert!(seen.insert(s));
        }
        assert!(!tk.is_sentinel(PAD));
        assert!(!tk.is_sentinel(FIRST_CONTENT));
    }

    #[test]
    fn until_eos_truncates() {
        let tk = Tokenizer::new(2048).unwrap();
        assert_eq!(tk.until_eos(&[5, 6, EOS, 7]), &[5, 6]);
        assert_eq!(tk.until_eos(&[5, 6]), &[5, 6]);
    }

    #[test]
    fn vocab_too_small_rejected() {
        assert!(Tokenizer::new(30).is_err());
    }
}
