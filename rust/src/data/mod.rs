//! Data pipeline: synthetic corpus -> tokenizer -> span corruption /
//! benchmark tasks -> padded batches (DESIGN.md S9-S11).

pub mod batcher;
pub mod corpus;
pub mod prefetch;
pub mod span;
pub mod tasks;
pub mod tokenizer;
