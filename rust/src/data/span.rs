//! T5 span corruption: the pretraining objective.
//!
//! Given a token sequence, sample spans (mean length 3, 15% corruption
//! rate as in T5), replace each span in the input with a fresh sentinel,
//! and build the target as `<s0> span0 <s1> span1 ... EOS`. Pretrain
//! "span prediction accuracy" (the paper's metric) is token accuracy on
//! these targets.

use crate::data::tokenizer::{Tokenizer, EOS};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SpanExample {
    pub enc: Vec<i32>,
    /// Decoder input (BOS-shifted) and targets, aligned.
    pub dec_input: Vec<i32>,
    pub dec_targets: Vec<i32>,
}

#[derive(Debug, Clone, Copy)]
pub struct SpanConfig {
    pub corrupt_rate: f64,
    pub mean_span: f64,
}

impl Default for SpanConfig {
    fn default() -> Self {
        SpanConfig { corrupt_rate: 0.15, mean_span: 3.0 }
    }
}

/// Corrupt one tokenized document into an (encoder, decoder) pair.
pub fn corrupt(tokens: &[i32], cfg: SpanConfig, tk: &Tokenizer, rng: &mut Rng) -> SpanExample {
    let n = tokens.len();
    // Decide span starts: expected corrupted tokens = rate * n, spans of
    // geometric-ish length around mean_span.
    let target_corrupt = ((n as f64) * cfg.corrupt_rate).round().max(1.0) as usize;
    let mut spans: Vec<(usize, usize)> = Vec::new(); // (start, len)
    let mut corrupted = 0usize;
    let mut guard = 0;
    while corrupted < target_corrupt && spans.len() < crate::data::tokenizer::NUM_SENTINELS && guard < 10 * n {
        guard += 1;
        let len = 1 + (rng.next_f64() * (2.0 * cfg.mean_span - 1.0)) as usize;
        if n <= len + 1 {
            break;
        }
        let start = rng.range(0, n - len);
        // Reject overlaps (with 1-token separation so sentinels don't
        // become adjacent, mirroring T5's merging behavior).
        if spans
            .iter()
            .any(|&(s, l)| start < s + l + 1 && s < start + len + 1)
        {
            continue;
        }
        spans.push((start, len));
        corrupted += len;
    }
    spans.sort();

    let mut enc = Vec::with_capacity(n);
    let mut dec = Vec::new();
    let mut pos = 0usize;
    for (i, &(start, len)) in spans.iter().enumerate() {
        enc.extend_from_slice(&tokens[pos..start]);
        enc.push(tk.sentinel(i));
        dec.push(tk.sentinel(i));
        dec.extend_from_slice(&tokens[start..start + len]);
        pos = start + len;
    }
    enc.extend_from_slice(&tokens[pos..]);
    enc.push(EOS);
    dec.push(EOS);

    let mut dec_input = Vec::with_capacity(dec.len());
    dec_input.push(crate::data::tokenizer::PAD); // BOS
    dec_input.extend_from_slice(&dec[..dec.len() - 1]);
    SpanExample { enc, dec_input, dec_targets: dec }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::{FIRST_CONTENT, PAD};

    fn tk() -> Tokenizer {
        Tokenizer::new(2048).unwrap()
    }

    fn doc(n: usize) -> Vec<i32> {
        (0..n).map(|i| FIRST_CONTENT + (i % 100) as i32).collect()
    }

    #[test]
    fn reconstruction_invariant() {
        // Replacing sentinels in enc by their target spans reconstructs
        // the original document.
        let tk = tk();
        let tokens = doc(120);
        let mut rng = Rng::new(1);
        let ex = corrupt(&tokens, SpanConfig::default(), &tk, &mut rng);

        // Parse target spans.
        let mut spans: Vec<(i32, Vec<i32>)> = Vec::new();
        let body = tk.until_eos(&ex.dec_targets);
        for &t in body {
            if tk.is_sentinel(t) {
                spans.push((t, Vec::new()));
            } else {
                spans.last_mut().expect("target starts with sentinel").1.push(t);
            }
        }
        let mut rebuilt = Vec::new();
        for &t in tk.until_eos(&ex.enc) {
            if tk.is_sentinel(t) {
                let (_, ref span) = spans.iter().find(|(s, _)| *s == t).expect("sentinel in target");
                rebuilt.extend_from_slice(span);
            } else {
                rebuilt.push(t);
            }
        }
        assert_eq!(rebuilt, tokens);
    }

    #[test]
    fn corruption_rate_respected() {
        let tk = tk();
        let tokens = doc(160);
        let mut rng = Rng::new(2);
        let mut total_corrupted = 0usize;
        let trials = 50;
        for _ in 0..trials {
            let ex = corrupt(&tokens, SpanConfig::default(), &tk, &mut rng);
            let corrupted: usize = tk
                .until_eos(&ex.dec_targets)
                .iter()
                .filter(|&&t| !tk.is_sentinel(t))
                .count();
            total_corrupted += corrupted;
        }
        let rate = total_corrupted as f64 / (trials * 160) as f64;
        assert!((0.10..=0.20).contains(&rate), "rate={rate}");
    }

    #[test]
    fn dec_input_is_shifted_targets() {
        let tk = tk();
        let mut rng = Rng::new(3);
        let ex = corrupt(&doc(80), SpanConfig::default(), &tk, &mut rng);
        assert_eq!(ex.dec_input[0], PAD);
        assert_eq!(&ex.dec_input[1..], &ex.dec_targets[..ex.dec_targets.len() - 1]);
        assert_eq!(*ex.dec_targets.last().unwrap(), EOS);
    }

    #[test]
    fn sentinels_ordered_in_encoder() {
        let tk = tk();
        let mut rng = Rng::new(4);
        let ex = corrupt(&doc(150), SpanConfig::default(), &tk, &mut rng);
        let sentinels: Vec<i32> = ex.enc.iter().copied().filter(|&t| tk.is_sentinel(t)).collect();
        let mut sorted = sentinels.clone();
        sorted.sort();
        assert_eq!(sentinels, sorted);
        assert!(!sentinels.is_empty());
    }

    #[test]
    fn tiny_docs_dont_panic() {
        let tk = tk();
        let mut rng = Rng::new(5);
        for n in 2..12 {
            let ex = corrupt(&doc(n), SpanConfig::default(), &tk, &mut rng);
            assert!(!ex.enc.is_empty());
            assert_eq!(*ex.enc.last().unwrap(), EOS);
        }
    }
}
