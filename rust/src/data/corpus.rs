//! Synthetic C4 substitute: a deterministic Zipf-bigram document
//! generator.
//!
//! Design goals (DESIGN.md §4): the generator must produce text whose
//! *statistical* structure rewards model capacity the way natural text
//! does — a Zipfian unigram distribution plus bigram (topic-conditioned
//! Markov) structure, so span-corruption prediction is learnable but
//! not trivial, and larger/wider models fit it measurably better.

use crate::util::rng::Rng;

/// Word-level synthetic corpus over a closed vocabulary of `vocab_words`
/// surface words (the tokenizer maps them 1:1 onto ids).
pub struct Corpus {
    pub vocab_words: usize,
    topics: usize,
    /// Per-topic permutation used to derive bigram successors.
    topic_perm: Vec<Vec<u32>>,
    zipf_cdf: Vec<f64>,
    seed: u64,
}

/// A generated document: word ids in [0, vocab_words).
pub type Doc = Vec<u32>;

impl Corpus {
    pub fn new(vocab_words: usize, seed: u64) -> Corpus {
        let topics = 16;
        let mut rng = Rng::new(seed ^ 0xC0_4B05);
        // Zipf(1.0) CDF over word ranks.
        let mut weights: Vec<f64> = (1..=vocab_words).map(|r| 1.0 / r as f64).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in &mut weights {
            acc += *w / total;
            *w = acc;
        }
        // Per-topic successor permutations (bigram structure).
        let topic_perm = (0..topics)
            .map(|_| {
                let mut p: Vec<u32> = (0..vocab_words as u32).collect();
                rng.shuffle(&mut p);
                p
            })
            .collect();
        Corpus { vocab_words, topics, topic_perm, zipf_cdf: weights, seed }
    }

    fn sample_zipf(&self, rng: &mut Rng) -> u32 {
        let u = rng.next_f64();
        // Binary search the CDF.
        let mut lo = 0usize;
        let mut hi = self.zipf_cdf.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.zipf_cdf[mid] < u {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo.min(self.vocab_words - 1) as u32
    }

    /// Generate document `index` (deterministic per (seed, index)).
    ///
    /// Each document has a latent topic; with probability 0.7 the next
    /// word is the topic-bigram successor of the previous word, else an
    /// independent Zipf draw. This yields locally predictable spans —
    /// exactly what span corruption trains on.
    pub fn document(&self, index: u64, min_len: usize, max_len: usize) -> Doc {
        let mut rng = Rng::new(self.seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let len = rng.range(min_len, max_len + 1);
        let topic = rng.next_below(self.topics as u64) as usize;
        let perm = &self.topic_perm[topic];
        let mut doc = Vec::with_capacity(len);
        let mut prev = self.sample_zipf(&mut rng);
        doc.push(prev);
        for _ in 1..len {
            let next = if rng.next_f64() < 0.7 {
                perm[prev as usize]
            } else {
                self.sample_zipf(&mut rng)
            };
            doc.push(next);
            prev = next;
        }
        doc
    }

    /// Infinite deterministic document stream.
    pub fn stream(&self, start_index: u64) -> CorpusStream<'_> {
        CorpusStream { corpus: self, next: start_index }
    }
}

pub struct CorpusStream<'a> {
    corpus: &'a Corpus,
    next: u64,
}

impl<'a> Iterator for CorpusStream<'a> {
    type Item = Doc;
    fn next(&mut self) -> Option<Doc> {
        let doc = self.corpus.document(self.next, 48, 192);
        self.next += 1;
        Some(doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_documents() {
        let c1 = Corpus::new(1000, 7);
        let c2 = Corpus::new(1000, 7);
        assert_eq!(c1.document(3, 48, 192), c2.document(3, 48, 192));
        assert_ne!(c1.document(3, 48, 192), c1.document(4, 48, 192));
    }

    #[test]
    fn words_in_range() {
        let c = Corpus::new(500, 1);
        for i in 0..20 {
            for &w in &c.document(i, 48, 192) {
                assert!((w as usize) < 500);
            }
        }
    }

    #[test]
    fn zipf_head_is_heavy() {
        let c = Corpus::new(1000, 2);
        let mut counts = vec![0usize; 1000];
        for i in 0..200 {
            for &w in &c.document(i, 48, 192) {
                counts[w as usize] += 1;
            }
        }
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[500..510].iter().sum();
        assert!(head > 10 * tail.max(1), "head={head} tail={tail}");
    }

    #[test]
    fn bigram_structure_present() {
        // successor entropy must be far below unigram entropy
        let c = Corpus::new(200, 3);
        let mut succ = std::collections::HashMap::new();
        for i in 0..300 {
            let d = c.document(i, 48, 192);
            for w in d.windows(2) {
                *succ.entry((w[0], w[1])).or_insert(0usize) += 1;
            }
        }
        // top bigram count should dominate uniform expectation
        let max = succ.values().max().copied().unwrap_or(0);
        let total: usize = succ.values().sum();
        assert!(max as f64 > 8.0 * total as f64 / (200.0 * 200.0), "max={max} total={total}");
    }

    #[test]
    fn stream_advances() {
        let c = Corpus::new(100, 5);
        let docs: Vec<Doc> = c.stream(0).take(3).collect();
        assert_eq!(docs.len(), 3);
        assert_ne!(docs[0], docs[1]);
    }
}
