//! Two-resource roofline: step latency = max(FLOPs/peak_flops,
//! bytes/peak_bw) summed over layer-granularity phases.

use crate::config::ModelConfig;
use crate::model::counting::{count_params, forward_flops, train_flops};

/// TPUv3 single-core peaks (per the public spec: 123 TFLOP/s bf16 per
/// chip / 2 cores, ~900 GB/s HBM per chip).
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub name: &'static str,
    pub peak_flops: f64,
    pub peak_bw: f64,
}

pub const TPU_V3_CORE: Device =
    Device { name: "tpuv3-core", peak_flops: 61.5e12, peak_bw: 450e9 };

/// A generic single CPU core (used to sanity-check measured numbers).
pub const CPU_CORE: Device = Device { name: "cpu-core", peak_flops: 5.0e10, peak_bw: 2.0e10 };

#[derive(Debug, Clone, Copy)]
pub struct Estimate {
    /// Seconds per training step (fwd+bwd) for one batch.
    pub train_step_seconds: f64,
    /// Seconds per forward pass for one batch.
    pub forward_seconds: f64,
    /// Fraction of time the step is compute-bound (vs bandwidth).
    pub compute_bound_frac: f64,
}

/// Roofline latency estimate for one batch on one device core.
pub fn estimate(cfg: &ModelConfig, dev: &Device) -> Estimate {
    let b = cfg.batch_size as f64;
    let fwd_flops = forward_flops(cfg) * b;
    let trn_flops = train_flops(cfg) * b;

    // Bytes: weights read once per step + activations streamed.
    let params = count_params(cfg).total() as f64;
    let weight_bytes = params * 4.0;
    let act_elems = {
        let layers = (cfg.enc_layers + cfg.dec_layers) as f64;
        let tokens = b * (cfg.enc_len + cfg.dec_len) as f64;
        // repr + ffn hidden + attention heads, per layer
        tokens * (cfg.repr_width() as f64 + cfg.d_ff as f64 + (cfg.num_heads * cfg.d_head) as f64)
            * layers
    };
    let act_bytes = act_elems * 4.0;
    // AltUp streams K blocks through predict/correct: 2 reads + 1 write.
    let altup_bytes = if cfg.variant.is_block_widened() {
        let tokens = b * (cfg.enc_len + cfg.dec_len) as f64;
        3.0 * tokens * cfg.repr_width() as f64 * 4.0 * (cfg.enc_layers + cfg.dec_layers) as f64
    } else {
        0.0
    };

    let fwd_bytes = weight_bytes + act_bytes + altup_bytes;
    let trn_bytes = 3.0 * weight_bytes + 2.0 * (act_bytes + altup_bytes); // params+grads+opt

    let t_fwd_c = fwd_flops / dev.peak_flops;
    let t_fwd_m = fwd_bytes / dev.peak_bw;
    let t_trn_c = trn_flops / dev.peak_flops;
    let t_trn_m = trn_bytes / dev.peak_bw;
    Estimate {
        forward_seconds: t_fwd_c.max(t_fwd_m),
        train_step_seconds: t_trn_c.max(t_trn_m),
        compute_bound_frac: t_trn_c / (t_trn_c + t_trn_m),
    }
}

/// Relative speed of `a` vs `b` (a_speed / b_speed), per roofline.
pub fn speed_ratio(a: &ModelConfig, b: &ModelConfig, dev: &Device) -> f64 {
    estimate(b, dev).train_step_seconds / estimate(a, dev).train_step_seconds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{paper_preset, Variant};

    #[test]
    fn altup_is_nearly_free_dense_is_not() {
        // The paper's headline shape: AltUp ~ baseline speed; Dense2X
        // costs ~2-4x. (Table 4's measured ratios: 52.4 -> 42.3 AltUp,
        // -> 32.9 Dense2X, -> 12.6 Dense4X examples/s.)
        let base = paper_preset("B", Variant::Baseline, 2);
        let alt = paper_preset("B", Variant::AltUp, 2);
        let d2 = paper_preset("B", Variant::DenseWide, 2);
        let d4 = paper_preset("B", Variant::DenseWide, 4);
        let r_alt = speed_ratio(&alt, &base, &TPU_V3_CORE);
        let r_d2 = speed_ratio(&d2, &base, &TPU_V3_CORE);
        let r_d4 = speed_ratio(&d4, &base, &TPU_V3_CORE);
        assert!(r_alt > 0.70, "altup ratio {r_alt}");
        assert!(r_d2 < 0.62, "dense2x ratio {r_d2}");
        assert!(r_d4 < 0.30, "dense4x ratio {r_d4}");
        // Paper Table 4 measured: alt 0.81x, d2 0.63x, d4 0.24x of baseline.
    }

    #[test]
    fn recycled_at_least_as_fast_as_altup() {
        let alt = paper_preset("B", Variant::AltUp, 2);
        let rec = paper_preset("B", Variant::Recycled, 2);
        let r = speed_ratio(&rec, &alt, &TPU_V3_CORE);
        assert!(r >= 1.0, "recycled ratio {r}");
    }

    #[test]
    fn seq_altup_faster_than_baseline() {
        let base = paper_preset("B", Variant::Baseline, 2);
        let seq = paper_preset("B", Variant::SeqAltUp, 2);
        let r = speed_ratio(&seq, &base, &TPU_V3_CORE);
        assert!(r > 1.2, "seq ratio {r}");
    }

    #[test]
    fn estimates_positive_and_ordered() {
        let cfg = paper_preset("L", Variant::Baseline, 2);
        let e = estimate(&cfg, &TPU_V3_CORE);
        assert!(e.forward_seconds > 0.0);
        assert!(e.train_step_seconds > e.forward_seconds);
        assert!((0.0..=1.0).contains(&e.compute_bound_frac));
    }
}
