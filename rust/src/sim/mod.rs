//! TPUv3 performance model (DESIGN.md S13, §Hardware-Adaptation).
//!
//! The paper measures latency on TPUv3-8; this testbed is a 1-core CPU.
//! Speed *ratios* between variants are architecture-determined, but for
//! the paper-scale rows of Tables 3-5 we additionally estimate absolute
//! TPUv3 step time with a two-resource roofline (MXU FLOP/s vs HBM
//! bytes/s), plus the VMEM footprint of the L1 kernels' BlockSpecs.

pub mod roofline;
pub mod vmem;
