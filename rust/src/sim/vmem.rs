//! VMEM footprint + MXU engagement estimates for the L1 Pallas kernels'
//! BlockSpecs (the interpret=True CPU path gives no TPU timing, so this
//! is the §Perf evidence for the kernel layer — see DESIGN.md).

/// TPUv3 VMEM per core: 16 MiB.
pub const VMEM_BYTES: usize = 16 * 1024 * 1024;

#[derive(Debug, Clone)]
pub struct KernelFootprint {
    pub name: String,
    /// Resident VMEM bytes per grid step (single-buffered).
    pub vmem_bytes: usize,
    /// With double buffering (what Mosaic would allocate).
    pub vmem_double_buffered: usize,
    /// Does the kernel engage the MXU (matmuls >= 8x128ish)?
    pub uses_mxu: bool,
    /// Arithmetic intensity (FLOPs per HBM byte moved).
    pub arithmetic_intensity: f64,
}

impl KernelFootprint {
    pub fn fits(&self) -> bool {
        self.vmem_double_buffered <= VMEM_BYTES
    }
}

/// AltUp fused predict+correct over (K, bt, d) f32 tiles.
///
/// VMEM per step: x tile (K*bt*d) + xtilde (bt*d) + out (K*bt*d) +
/// scalars. FLOPs: 2*K^2*bt*d (mixture) + 2*K*bt*d (correction);
/// bytes: (2K+2)*bt*d*4 (read x + xtilde, write out).
pub fn altup_predict_correct(k: usize, bt: usize, d: usize) -> KernelFootprint {
    let tile = bt * d * 4;
    let vmem = k * tile + tile + k * tile + (k * k + k) * 4;
    let flops = (2 * k * k * bt * d + 2 * k * bt * d) as f64;
    let bytes = ((2 * k + 2) * bt * d * 4) as f64;
    KernelFootprint {
        name: format!("altup_predict_correct(K={k},bt={bt},d={d})"),
        vmem_bytes: vmem,
        vmem_double_buffered: 2 * vmem,
        uses_mxu: false, // K x K mixing stays on the VPU by design
        arithmetic_intensity: flops / bytes,
    }
}

/// Gated FFN kernel over (bt, d) x (d, bf) panels.
pub fn gated_ffn(bt: usize, d: usize, f: usize, bf: usize) -> KernelFootprint {
    let vmem = (bt * d + 2 * d * bf + bt * bf + bt * d) * 4;
    let flops = (2 * bt * d * f * 3) as f64; // wi0, wi1, wo per full row
    let bytes = ((bt * d + 3 * d * f.min(bf) * (f / bf.max(1)) + bt * d) * 4) as f64;
    KernelFootprint {
        name: format!("gated_ffn(bt={bt},d={d},f={f},bf={bf})"),
        vmem_bytes: vmem,
        vmem_double_buffered: 2 * vmem,
        uses_mxu: d >= 128 && bf >= 128,
        arithmetic_intensity: flops / bytes.max(1.0),
    }
}

/// Flash attention kernel: (bq, dh) queries vs (bk, dh) K/V tiles.
pub fn flash_attention(bq: usize, bk: usize, tk: usize, dh: usize) -> KernelFootprint {
    let vmem = (bq * dh + 2 * bk * dh + bq * tk + bq * dh + 3 * bq) * 4;
    let flops = (2 * bq * tk * dh * 2) as f64;
    let bytes = ((bq * dh + 2 * tk * dh + bq * tk + bq * dh) * 4) as f64;
    KernelFootprint {
        name: format!("flash_attention(bq={bq},bk={bk},tk={tk},dh={dh})"),
        vmem_bytes: vmem,
        vmem_double_buffered: 2 * vmem,
        uses_mxu: dh >= 64 && bq >= 8,
        arithmetic_intensity: flops / bytes,
    }
}

/// Largest power-of-two row-block for the AltUp kernel that fits VMEM
/// double-buffered at width d, expansion K (the block the compile path
/// should pick for a real-TPU build).
pub fn altup_max_rows(k: usize, d: usize) -> usize {
    let mut bt = 1024;
    while bt > 8 && !altup_predict_correct(k, bt, d).fits() {
        bt /= 2;
    }
    bt
}

/// Largest hidden-panel width for the FFN kernel that fits VMEM.
pub fn ffn_max_panel(bt: usize, d: usize, f: usize) -> usize {
    let mut bf = 512.min(f);
    while bf > 16 && !gated_ffn(bt, d, f, bf).fits() {
        bf /= 2;
    }
    bf
}

/// Report the standard kernel set at a given model scale, with blocks
/// auto-shrunk to fit VMEM (what a real-TPU compile would pick).
pub fn report(d: usize, f: usize, k: usize) -> Vec<KernelFootprint> {
    vec![
        altup_predict_correct(k, altup_max_rows(k, d).min(256), d),
        gated_ffn(128, d, f, ffn_max_panel(128, d, f)),
        flash_attention(128, 128, 512, 64),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_blocks_fit_vmem() {
        // Even at the paper's XL width the chosen BlockSpecs fit VMEM.
        for fp in report(2048, 5120, 4) {
            assert!(fp.fits(), "{} = {} bytes", fp.name, fp.vmem_double_buffered);
        }
    }

    #[test]
    fn altup_kernel_is_vpu_work() {
        let fp = altup_predict_correct(2, 256, 512);
        assert!(!fp.uses_mxu);
        // Pure vector mixing: low arithmetic intensity, bandwidth-bound.
        assert!(fp.arithmetic_intensity < 4.0);
    }

    #[test]
    fn ffn_kernel_is_mxu_work() {
        let fp = gated_ffn(128, 512, 1024, 512);
        assert!(fp.uses_mxu);
        assert!(fp.arithmetic_intensity > 10.0);
    }

    #[test]
    fn footprint_scales_with_block() {
        let a = altup_predict_correct(2, 128, 512);
        let b = altup_predict_correct(2, 256, 512);
        assert!(b.vmem_bytes > a.vmem_bytes);
    }
}
