//! `altup` CLI — leader entrypoint for the AltUp reproduction stack.
//!
//! Subcommands:
//!   pretrain    --artifact <name> --steps N [--ckpt path] [--log path]
//!   finetune    --artifact <name> --task glue|superglue|squad|triviaqa
//!               --ckpt <pretrained> --steps N
//!   eval        --artifact <name> [--ckpt path] --batches N [--task t]
//!   serve       --artifact <name> [--ckpt path] [--slots S] [--no-cont]
//!               [--queue-cap N] [--timeout-ms T] [--retries R]
//!               [--restarts N] [--spec-gamma G] [--trace-sample F]
//!               [--trace-out path.jsonl] --requests N
//!   params      [--size S|B|L|XL] — analytic parameter table
//!   latency     --artifact <name> [--kind forward|train_step]
//!   bench-table <fig4|tab1|tab2|tab3|tab4|tab6|tab7|fig5|bert> [--quick]
//!   trace-report --in trace.jsonl [--top N] — §L13 waterfall + phase
//!               attribution from a serve/bench trace export

use altup::coordinator::metrics::MetricsLog;
use altup::coordinator::pipeline::{self, PipelineOptions};
use altup::coordinator::server::{ServerHandle, ServerOptions};
use altup::coordinator::trace;
use altup::coordinator::trainer::{DataSource, TrainOptions, Trainer};
use altup::data::batcher::{PretrainBatcher, TaskBatcher};
use altup::data::tasks::{Task, TaskKind};
use altup::experiments;
use altup::runtime::artifact::load_named;
use altup::runtime::client::Client;
use altup::runtime::params::ParamStore;
use altup::runtime::session::Session;
use altup::util::bench;
use altup::util::cli::Args;
use anyhow::{bail, Context, Result};

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "pretrain" => cmd_pretrain(&args),
        "finetune" => cmd_finetune(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "params" => cmd_params(&args),
        "latency" => cmd_latency(&args),
        "bench-table" => cmd_bench_table(&args),
        "trace-report" => cmd_trace_report(&args),
        "help" | _ => {
            println!(
                "altup — Alternating Updates for Efficient Transformers (NeurIPS 2023)\n\
                 commands: pretrain finetune eval serve params latency bench-table \
                 trace-report\n\
                 see README.md for usage"
            );
            Ok(())
        }
    }
}

fn open_session(args: &Args, client: &Client, train: bool) -> Result<Session> {
    let name = args.get("artifact").context("--artifact <name> required")?;
    let artifact = load_named(name)?;
    let seed = args.u64_or("seed", 0);
    let mut session = if train {
        Session::open(client, artifact, seed)?
    } else {
        Session::open_eval(client, artifact, seed)?
    };
    if let Some(ckpt) = args.get("ckpt") {
        if std::path::Path::new(ckpt).exists() {
            session.store = ParamStore::load(ckpt, &session.artifact)?;
            session.invalidate_state();
            // Re-upload once so the first step doesn't pay a cold
            // host->device copy (§Perf L4).
            session.warm_device_cache(client)?;
            println!("loaded checkpoint {ckpt} @ step {}", session.store.step);
        }
    }
    Ok(session)
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let client = Client::cpu()?;
    let session = open_session(args, &client, true)?;
    let cfg = session.artifact.config.clone();
    println!(
        "pretraining {} ({} params, variant={}, K={})",
        session.artifact.name,
        session.store.num_params(),
        cfg.variant.as_str(),
        cfg.k
    );
    let batcher = PretrainBatcher::new(
        cfg.vocab_size,
        cfg.batch_size,
        cfg.enc_len,
        cfg.dec_len,
        args.u64_or("data-seed", 1),
    );
    let log = match args.get("log") {
        Some(p) => MetricsLog::to_file(p)?,
        None => MetricsLog::in_memory(),
    };
    let mut trainer = Trainer::new(session, DataSource::Pretrain(batcher), log);
    let opts = TrainOptions {
        steps: args.u64_or("steps", 200),
        warmup: args.u64_or("warmup", 1000),
        base_lr: args.f64_or("lr", 1.0),
        log_every: args.u64_or("log-every", 10),
        eval_every: args.u64_or("eval-every", 0),
        checkpoint_path: args.get("ckpt").map(Into::into),
        verbose: true,
        ..Default::default()
    };
    let (ema, sps) = trainer.run(&client, &opts)?;
    let ev = trainer.eval(&client, args.usize_or("eval-batches", 8))?;
    println!("done: loss_ema={ema:.4} steps/sec={sps:.3} | validation {}", ev.summary());
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let client = Client::cpu()?;
    let session = open_session(args, &client, true)?;
    let cfg = session.artifact.config.clone();
    let kind = TaskKind::from_str(&args.str_or("task", "glue")).context("bad --task")?;
    let task = Task::new(kind, cfg.vocab_size, args.u64_or("task-seed", 0x7A58));
    let batcher = TaskBatcher::new(task, cfg.batch_size, cfg.enc_len, cfg.dec_len);
    let mut trainer = Trainer::new(session, DataSource::Task(batcher), MetricsLog::in_memory());
    let opts = TrainOptions {
        steps: args.u64_or("steps", 100),
        constant_lr: Some(args.f64_or("lr", 1e-3)),
        log_every: args.u64_or("log-every", 10),
        verbose: true,
        ..Default::default()
    };
    trainer.run(&client, &opts)?;
    let mut ev = trainer.eval(&client, args.usize_or("eval-batches", 8))?;
    if kind.is_generative() {
        let gen = trainer.eval_generative(&client, 4)?;
        ev.em = gen.em;
        ev.f1 = gen.f1;
    }
    println!("finetune {} on {}: {}", trainer.session.artifact.name, kind.name(), ev.summary());
    if let Some(out) = args.get("save") {
        trainer.session.checkpoint(out)?;
        println!("saved {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let client = Client::cpu()?;
    let mut session = open_session(args, &client, false)?;
    let cfg = session.artifact.config.clone();
    let batches = args.usize_or("batches", 8);
    match args.get("task").and_then(TaskKind::from_str) {
        None => {
            let mut b = PretrainBatcher::new(
                cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 0xE0A1,
            );
            let mut loss = 0.0f64;
            let mut correct = 0.0f64;
            let mut ntok = 0.0f64;
            for _ in 0..batches {
                let m = session.eval_step(&client, &b.next_batch())?;
                loss += m.loss as f64;
                correct += m.correct as f64;
                ntok += m.ntok as f64;
            }
            println!(
                "pretrain-style eval: loss={:.4} acc={:.2}%",
                loss / ntok.max(1.0),
                100.0 * correct / ntok.max(1.0)
            );
        }
        Some(kind) => {
            let task = Task::new(kind, cfg.vocab_size, args.u64_or("task-seed", 0x7A58));
            let mut tb = TaskBatcher::new(task, cfg.batch_size, cfg.enc_len, cfg.dec_len);
            tb.eval_split();
            let tk = altup::data::tokenizer::Tokenizer::new(cfg.vocab_size)?;
            let mut em = 0.0;
            let mut f1 = 0.0;
            let mut n = 0usize;
            for _ in 0..batches {
                let batch = tb.next_batch();
                let rows = session.decode(&client, &batch.enc_tokens)?;
                for (row, gold) in rows.iter().zip(batch.answers.iter()) {
                    let pred = tk.content_of(tk.until_eos(row));
                    em += altup::data::tasks::exact_match(&pred, gold);
                    f1 += altup::data::tasks::f1_score(&pred, gold);
                    n += 1;
                }
            }
            println!(
                "{}: EM={:.2} F1={:.2} (n={n})",
                kind.name(),
                100.0 * em / n as f64,
                100.0 * f1 / n as f64
            );
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.get("artifact").context("--artifact required")?.to_string();
    let defaults = ServerOptions::default();
    let opts = ServerOptions {
        batch_window: std::time::Duration::from_millis(args.u64_or("window-ms", 5)),
        seed: args.u64_or("seed", 0),
        checkpoint: args.get("ckpt").map(Into::into),
        replicas: args.usize_or("replicas", defaults.replicas),
        bucketed: !args.has("no-buckets") && defaults.bucketed,
        slots: args.usize_or("slots", defaults.slots),
        continuous: !args.has("no-cont") && defaults.continuous,
        queue_cap: args.usize_or("queue-cap", defaults.queue_cap),
        // 0 falls through to the ALTUP_REQUEST_TIMEOUT_MS default.
        request_timeout_ms: match args.u64_or("timeout-ms", 0) {
            0 => defaults.request_timeout_ms,
            ms => Some(ms),
        },
        max_retries: args.usize_or("retries", defaults.max_retries as usize) as u32,
        replica_restarts: args.usize_or("restarts", defaults.replica_restarts),
        // §L8: draft length for speculative decoding (0 = off; falls
        // back to plain decode when the artifact ships no draft).
        spec_gamma: args.usize_or("spec-gamma", defaults.spec_gamma),
        // §L12: tensor-parallel group width (0/1 = whole-model units).
        tp: args.usize_or("tp", defaults.tp),
        // §L13: per-request span tracing (0 = off; 1 = trace all).
        trace_sample: args.f64_or("trace-sample", defaults.trace_sample).clamp(0.0, 1.0),
        // Tenancy (§L10), deploy gates (§L11), and the §L12 group
        // count keep their ALTUP_*-derived defaults.
        ..defaults
    };
    let n = args.usize_or("requests", 64);
    let server = ServerHandle::spawn(&name, opts);
    // Demo client load: send n requests from a task stream. Explicit
    // failures (deadline sheds, crashed-replica retries exhausted) are
    // terminal responses, not client errors — count them.
    let artifact = load_named(&name)?;
    let cfg = artifact.config;
    let task = Task::new(TaskKind::Squad, cfg.vocab_size, 1);
    let t0 = std::time::Instant::now();
    let mut latencies = Vec::new();
    let mut failed = 0usize;
    for i in 0..n {
        let ex = task.example(i as u64, cfg.enc_len - 2);
        let resp = server.infer_response(ex.enc)?;
        match resp.failure {
            Some(reason) => {
                failed += 1;
                eprintln!("request {i} failed: {reason}");
            }
            None => latencies.push(resp.latency),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    let s = bench::stats_from("serve", latencies);
    println!(
        "served {n} requests ({failed} failed) in {wall:.2}s ({:.1} req/s), \
         mean latency {:.1} ms",
        n as f64 / wall,
        s.mean_ms(),
    );
    println!("{}", stats.summary());
    // §L13: export the merged trace for `altup trace-report`.
    if let Some(out) = args.get("trace-out") {
        let sample = args.f64_or("trace-sample", 0.0);
        trace::write_jsonl(std::path::Path::new(out), &stats.trace, sample)?;
        println!(
            "trace: wrote {} spans + {} windows to {out}",
            stats.trace.span_count(),
            stats.trace.timeline.windows.len()
        );
    }
    Ok(())
}

/// §L13: render the per-request waterfall and phase-attribution tables
/// from a `--trace-out` / `--trace-jsonl` export.
fn cmd_trace_report(args: &Args) -> Result<()> {
    let path = args.get("in").context("--in <trace.jsonl> required")?;
    let tf = trace::read_jsonl(std::path::Path::new(path))?;
    print!("{}", trace::render_report(&tf, args.usize_or("top", 8)));
    Ok(())
}

fn cmd_params(args: &Args) -> Result<()> {
    let _ = args;
    experiments::table3_params::print_table()
}

fn cmd_latency(args: &Args) -> Result<()> {
    let client = Client::cpu()?;
    let name = args.get("artifact").context("--artifact required")?;
    let kind = args.str_or("kind", "forward");
    let artifact = load_named(name)?;
    let cfg = artifact.config.clone();
    let mut session = Session::open_eval(&client, artifact, 0)?;
    let mut b = PretrainBatcher::new(cfg.vocab_size, cfg.batch_size, cfg.enc_len, cfg.dec_len, 5);
    let batch = b.next_batch();
    let stats = match kind.as_str() {
        "forward" => bench::quick(&format!("{name}:forward"), || {
            session.forward_step(&client, &batch).unwrap()
        }),
        "train_step" => {
            let mut s2 = Session::open(&client, load_named(name)?, 0)?;
            bench::quick(&format!("{name}:train"), || {
                s2.train_step(&client, 1e-3, 1, &batch).unwrap();
            })
        }
        _ => bail!("--kind forward|train_step"),
    };
    println!("{}", stats.report());
    Ok(())
}

fn cmd_bench_table(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let quick = args.has("quick");
    let opts = if quick {
        PipelineOptions {
            pretrain_steps: args.u64_or("pretrain-steps", 60),
            finetune_steps: args.u64_or("finetune-steps", 30),
            warmup: 1000,
            eval_batches: 4,
            ..Default::default()
        }
    } else {
        PipelineOptions {
            pretrain_steps: args.u64_or("pretrain-steps", 300),
            finetune_steps: args.u64_or("finetune-steps", 120),
            ..Default::default()
        }
    };
    experiments::run(which, &opts)
}
