//! §L13 request-lifecycle tracing + time-series telemetry.
//!
//! Two complementary views of a serving run:
//!
//! 1. **Spans** — per-request phase intervals (admission-queue, qos-queue,
//!    router-dispatch, prefill, decode) collected into per-worker ring
//!    buffers. Each worker thread records into the [`TraceStats`] embedded
//!    in its own `ServerStats` (no shared locks on the hot path); the
//!    supervisor's existing merge-at-exit path folds worker rings into the
//!    aggregate. The five *top-level* phases partition `[t0, done]`
//!    contiguously, so per request: `sum(phase spans) == e2e latency` by
//!    construction — the invariant the tests pin within 5%.
//! 2. **Timeline** — gauges (queue depth, ladder level, slot occupancy,
//!    pool pages) and per-tenant completions/latency sampled into fixed
//!    100 ms windows ([`TimelineRegistry`]), merged across workers by
//!    window index.
//!
//! Nested phases (decode-iteration, spec-draft/verify, allreduce,
//! deploy-drain) are *attributed* aggregate time inside the top-level
//! phases — they live in the [`PhaseBreakdown`] and as event spans, and
//! are excluded from the per-request top-level sum.
//!
//! Sampling is deterministic by request content hash (`ALTUP_TRACE_SAMPLE`
//! × [`trace_hash`]): the same workload replayed samples the same request
//! set, and an unsampled run records nothing on the per-token path.
//!
//! Export: JSONL (`meta` / `span` / `window` lines) via [`write_jsonl`],
//! rendered by `altup trace-report` ([`render_report`]).

use std::collections::{BTreeMap, VecDeque};
use std::io::Write as IoWrite;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::LatencyHistogram;
use crate::util::json::Json;

/// Default per-worker span ring capacity (`ALTUP_TRACE_RING`).
pub const DEFAULT_RING: usize = 4096;
/// Default timeline window width in ms (`ALTUP_TRACE_WINDOW_MS`).
pub const DEFAULT_WINDOW_MS: u64 = 100;

// ---------------------------------------------------------------------------
// Phases
// ---------------------------------------------------------------------------

/// Lifecycle phases. The first five are **top-level**: for one request
/// they tile `[t0, retirement]` with no gaps or overlap. The rest are
/// nested attributions or instantaneous events.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Client `infer()` send → router pops the request channel.
    AdmissionQueue = 0,
    /// Router pop → §L10 admission release (≈0 in passthrough mode).
    QosQueue = 1,
    /// Admission release → worker starts prefill (job queue + slot wait).
    RouterDispatch = 2,
    /// The `prefill@bucket` call the request rode in on.
    Prefill = 3,
    /// Prefill end → slot retirement (all decode iterations).
    Decode = 4,
    /// Nested: one fused decode/spec-round step (aggregate).
    DecodeIter = 5,
    /// Nested: §L8 draft-model step inside a spec round.
    SpecDraft = 6,
    /// Nested: §L8 fused verify step inside a spec round.
    SpecVerify = 7,
    /// Nested: §L12 ring all-reduce wait inside prefill/decode.
    Allreduce = 8,
    /// Event: §L11 drain lever taken → worker exit.
    DeployDrain = 9,
    /// Event: §L10 overload-ladder level change (`value` = new level).
    LadderLevel = 10,
}

/// Number of distinct phases (array sizing for [`PhaseBreakdown`]).
pub const N_PHASES: usize = 11;

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [
        Phase::AdmissionQueue,
        Phase::QosQueue,
        Phase::RouterDispatch,
        Phase::Prefill,
        Phase::Decode,
        Phase::DecodeIter,
        Phase::SpecDraft,
        Phase::SpecVerify,
        Phase::Allreduce,
        Phase::DeployDrain,
        Phase::LadderLevel,
    ];

    /// The contiguous per-request partition of e2e latency.
    pub const TOP_LEVEL: [Phase; 5] = [
        Phase::AdmissionQueue,
        Phase::QosQueue,
        Phase::RouterDispatch,
        Phase::Prefill,
        Phase::Decode,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Phase::AdmissionQueue => "admission-queue",
            Phase::QosQueue => "qos-queue",
            Phase::RouterDispatch => "router-dispatch",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::DecodeIter => "decode-iteration",
            Phase::SpecDraft => "spec-draft",
            Phase::SpecVerify => "spec-verify",
            Phase::Allreduce => "allreduce",
            Phase::DeployDrain => "deploy-drain",
            Phase::LadderLevel => "ladder-level",
        }
    }

    pub fn from_name(s: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.as_str() == s)
    }

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn is_top_level(self) -> bool {
        Phase::TOP_LEVEL.contains(&self)
    }
}

// ---------------------------------------------------------------------------
// Spans + sampling
// ---------------------------------------------------------------------------

/// One phase interval. Timestamps are ns since the server's shared epoch
/// (the `QosShared` spawn instant), so router- and worker-recorded spans
/// of one request compose on a single clock. `req == 0` marks
/// request-less events (ladder level changes, drains); `value` carries
/// phase-specific payload (new ladder level, tokens moved, ...).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub req: u64,
    pub tenant: u32,
    pub group: u32,
    pub phase: Phase,
    pub start_ns: u64,
    pub end_ns: u64,
    pub value: i64,
}

impl Span {
    pub fn dur_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// ns since the shared epoch (saturating: pre-epoch instants clamp to 0).
pub fn ns_since(epoch: Instant, t: Instant) -> u64 {
    t.saturating_duration_since(epoch).as_nanos() as u64
}

/// FNV-1a over a token sequence — the deterministic sampling key. Same
/// prompt ⇒ same hash ⇒ same sampling decision across runs and replays.
pub fn trace_hash(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for b in (t as u32).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
    }
    h
}

fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

/// Sampling decision: `sample` fraction of requests, chosen by content
/// hash mixed with a salt (the server seed) — not by arrival order.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    pub sample: f64,
    pub salt: u64,
}

impl TraceConfig {
    pub fn new(sample: f64, salt: u64) -> Self {
        Self { sample: sample.clamp(0.0, 1.0), salt }
    }

    pub fn enabled(&self) -> bool {
        self.sample > 0.0
    }

    pub fn sampled(&self, hash: u64) -> bool {
        if self.sample <= 0.0 {
            return false;
        }
        if self.sample >= 1.0 {
            return true;
        }
        let u = mix64(hash ^ self.salt.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        ((u >> 11) as f64 / (1u64 << 53) as f64) < self.sample
    }
}

// ---------------------------------------------------------------------------
// Phase breakdown (aggregate ns ledger)
// ---------------------------------------------------------------------------

/// Aggregate per-phase time + event counts. Mergeable like every other
/// meter in `ServerStats`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub ns: [u64; N_PHASES],
    pub count: [u64; N_PHASES],
}

impl PhaseBreakdown {
    pub fn add(&mut self, phase: Phase, ns: u64) {
        self.add_n(phase, ns, 1);
    }

    pub fn add_n(&mut self, phase: Phase, ns: u64, n: u64) {
        self.ns[phase.index()] += ns;
        self.count[phase.index()] += n;
    }

    pub fn merge(&mut self, other: &PhaseBreakdown) {
        for i in 0..N_PHASES {
            self.ns[i] += other.ns[i];
            self.count[i] += other.count[i];
        }
    }

    pub fn get(&self, phase: Phase) -> (u64, u64) {
        (self.ns[phase.index()], self.count[phase.index()])
    }

    pub fn total_ns(&self, phases: &[Phase]) -> u64 {
        phases.iter().map(|p| self.ns[p.index()]).sum()
    }

    pub fn active(&self) -> bool {
        self.count.iter().any(|&c| c > 0)
    }

    /// Share of `phase` within the given denominator phase set.
    pub fn share(&self, phase: Phase, denom: &[Phase]) -> f64 {
        let d = self.total_ns(denom);
        if d == 0 {
            return 0.0;
        }
        self.ns[phase.index()] as f64 / d as f64
    }
}

// ---------------------------------------------------------------------------
// Timeline
// ---------------------------------------------------------------------------

/// Sampled run-state gauges (issue §L13 taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    QueueDepth = 0,
    LadderLevel = 1,
    SlotOccupancy = 2,
    PoolPages = 3,
}

/// Number of distinct gauges (array sizing for [`WindowAgg`]).
pub const N_GAUGES: usize = 4;

impl Gauge {
    pub const ALL: [Gauge; N_GAUGES] =
        [Gauge::QueueDepth, Gauge::LadderLevel, Gauge::SlotOccupancy, Gauge::PoolPages];

    pub fn as_str(self) -> &'static str {
        match self {
            Gauge::QueueDepth => "queue_depth",
            Gauge::LadderLevel => "ladder",
            Gauge::SlotOccupancy => "occupancy",
            Gauge::PoolPages => "pool_pages",
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

/// One fixed-width window: mean/max per gauge plus per-tenant
/// completions and a latency histogram (p95 without raw samples —
/// satellite: `LatencyHistogram::to_buckets`).
#[derive(Debug, Clone, Default)]
pub struct WindowAgg {
    pub sum: [f64; N_GAUGES],
    pub n: [u64; N_GAUGES],
    pub max: [f64; N_GAUGES],
    pub done: u64,
    pub lat: LatencyHistogram,
    pub tenant_done: Vec<u64>,
}

impl WindowAgg {
    pub fn mean(&self, g: Gauge) -> f64 {
        let i = g.index();
        if self.n[i] == 0 {
            0.0
        } else {
            self.sum[i] / self.n[i] as f64
        }
    }

    fn merge(&mut self, other: &WindowAgg) {
        for i in 0..N_GAUGES {
            self.sum[i] += other.sum[i];
            self.n[i] += other.n[i];
            self.max[i] = self.max[i].max(other.max[i]);
        }
        self.done += other.done;
        self.lat.merge(&other.lat);
        if self.tenant_done.len() < other.tenant_done.len() {
            self.tenant_done.resize(other.tenant_done.len(), 0);
        }
        for (i, &d) in other.tenant_done.iter().enumerate() {
            self.tenant_done[i] += d;
        }
    }
}

/// Fixed-window time series keyed by `ns / window_ns`. Each worker owns
/// one (inside its `TraceStats`); merge is by window index, so the
/// aggregate view lines up across threads sharing the epoch clock.
#[derive(Debug, Clone)]
pub struct TimelineRegistry {
    pub window_ns: u64,
    pub windows: BTreeMap<u64, WindowAgg>,
}

impl Default for TimelineRegistry {
    fn default() -> Self {
        Self::new(DEFAULT_WINDOW_MS)
    }
}

impl TimelineRegistry {
    pub fn new(window_ms: u64) -> Self {
        Self { window_ns: window_ms.max(1) * 1_000_000, windows: BTreeMap::new() }
    }

    fn agg(&mut self, at_ns: u64) -> &mut WindowAgg {
        let idx = at_ns / self.window_ns;
        self.windows.entry(idx).or_default()
    }

    pub fn gauge(&mut self, g: Gauge, v: f64, at_ns: u64) {
        let w = self.agg(at_ns);
        let i = g.index();
        w.sum[i] += v;
        w.n[i] += 1;
        w.max[i] = w.max[i].max(v);
    }

    pub fn note_done(&mut self, tenant: usize, latency_ms: f64, at_ns: u64) {
        let w = self.agg(at_ns);
        w.done += 1;
        w.lat.record(latency_ms);
        if w.tenant_done.len() <= tenant {
            w.tenant_done.resize(tenant + 1, 0);
        }
        w.tenant_done[tenant] += 1;
    }

    pub fn merge(&mut self, other: &TimelineRegistry) {
        for (idx, agg) in &other.windows {
            self.windows.entry(*idx).or_default().merge(agg);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }
}

// ---------------------------------------------------------------------------
// TraceStats: the per-worker collector + mergeable aggregate
// ---------------------------------------------------------------------------

/// Span ring + phase ledger + timeline for one worker thread (embedded
/// in its `ServerStats`), and — after the supervisor's merge-at-exit —
/// the fleet aggregate. `record` drops the *oldest* span when the ring
/// is full and counts the drop; `merge` concatenates without dropping
/// (per-worker rings already bounded collection at the source).
#[derive(Debug, Clone)]
pub struct TraceStats {
    pub phases: PhaseBreakdown,
    ring: VecDeque<Span>,
    cap: usize,
    pub dropped_spans: u64,
    pub timeline: TimelineRegistry,
}

impl Default for TraceStats {
    fn default() -> Self {
        Self {
            phases: PhaseBreakdown::default(),
            ring: VecDeque::new(),
            cap: DEFAULT_RING,
            dropped_spans: 0,
            timeline: TimelineRegistry::default(),
        }
    }
}

impl TraceStats {
    pub fn set_limits(&mut self, ring_cap: usize, window_ms: u64) {
        self.cap = ring_cap.max(1);
        if self.timeline.is_empty() {
            self.timeline = TimelineRegistry::new(window_ms);
        }
    }

    pub fn record(&mut self, span: Span) {
        if self.ring.len() >= self.cap {
            self.ring.pop_front();
            self.dropped_spans += 1;
        }
        self.ring.push_back(span);
    }

    pub fn spans(&self) -> impl Iterator<Item = &Span> {
        self.ring.iter()
    }

    pub fn span_count(&self) -> usize {
        self.ring.len()
    }

    pub fn merge(&mut self, other: &TraceStats) {
        self.phases.merge(&other.phases);
        self.ring.extend(other.ring.iter().copied());
        self.dropped_spans += other.dropped_spans;
        self.timeline.merge(&other.timeline);
    }

    pub fn active(&self) -> bool {
        !self.ring.is_empty() || self.phases.active() || !self.timeline.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Per-request attribution
// ---------------------------------------------------------------------------

/// One request's phase ledger rebuilt from its spans. `e2e_ns` spans
/// first top-level start → last top-level end.
#[derive(Debug, Clone)]
pub struct ReqAttr {
    pub req: u64,
    pub tenant: u32,
    pub start_ns: u64,
    pub end_ns: u64,
    pub phase_ns: [u64; N_PHASES],
}

impl ReqAttr {
    pub fn e2e_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    pub fn top_level_ns(&self) -> u64 {
        Phase::TOP_LEVEL.iter().map(|p| self.phase_ns[p.index()]).sum()
    }
}

/// Group spans by request id (skipping request-less events). Returned
/// sorted by request id.
pub fn per_request<'a>(spans: impl Iterator<Item = &'a Span>) -> Vec<ReqAttr> {
    let mut by_req: BTreeMap<u64, ReqAttr> = BTreeMap::new();
    for s in spans {
        if s.req == 0 {
            continue;
        }
        let a = by_req.entry(s.req).or_insert_with(|| ReqAttr {
            req: s.req,
            tenant: s.tenant,
            start_ns: u64::MAX,
            end_ns: 0,
            phase_ns: [0; N_PHASES],
        });
        a.phase_ns[s.phase.index()] += s.dur_ns();
        if s.phase.is_top_level() {
            a.start_ns = a.start_ns.min(s.start_ns);
            a.end_ns = a.end_ns.max(s.end_ns);
        }
    }
    by_req.into_values().filter(|a| a.end_ns > 0 && a.start_ns < u64::MAX).collect()
}

/// Summed phase ledger over a request subset (e.g. the slowest 5% — the
/// "where does p95 go" question).
#[derive(Debug, Clone, Default)]
pub struct Attribution {
    pub requests: usize,
    pub e2e_ns: u64,
    pub phase_ns: [u64; N_PHASES],
}

impl Attribution {
    /// Top-level phase shares; sums to ~1.0 whenever any time was
    /// recorded (the top-level phases partition each request's e2e).
    pub fn shares(&self) -> [f64; N_PHASES] {
        let total: u64 = Phase::TOP_LEVEL.iter().map(|p| self.phase_ns[p.index()]).sum();
        let mut out = [0.0; N_PHASES];
        if total == 0 {
            return out;
        }
        for (i, ns) in self.phase_ns.iter().enumerate() {
            out[i] = *ns as f64 / total as f64;
        }
        out
    }
}

/// Attribute the slowest `top_frac` of requests (by e2e), e.g. 0.05 for
/// "the p95 tail". `top_frac >= 1.0` attributes every request.
pub fn attribute(attrs: &[ReqAttr], top_frac: f64) -> Attribution {
    if attrs.is_empty() {
        return Attribution::default();
    }
    let mut sorted: Vec<&ReqAttr> = attrs.iter().collect();
    sorted.sort_by(|a, b| b.e2e_ns().cmp(&a.e2e_ns()).then(a.req.cmp(&b.req)));
    let take = ((attrs.len() as f64 * top_frac.clamp(0.0, 1.0)).ceil() as usize)
        .clamp(1, attrs.len());
    let mut out = Attribution::default();
    for a in sorted.into_iter().take(take) {
        out.requests += 1;
        out.e2e_ns += a.e2e_ns();
        for i in 0..N_PHASES {
            out.phase_ns[i] += a.phase_ns[i];
        }
    }
    out
}

// ---------------------------------------------------------------------------
// JSONL export + report
// ---------------------------------------------------------------------------

fn window_row(idx: u64, window_ns: u64, w: &WindowAgg) -> Json {
    let tenant_done =
        Json::Arr(w.tenant_done.iter().map(|&d| Json::num(d as f64)).collect());
    Json::obj(vec![
        ("kind", Json::str("window")),
        ("index", Json::num(idx as f64)),
        ("start_ms", Json::num((idx * window_ns) as f64 / 1e6)),
        ("queue_depth", Json::num(w.mean(Gauge::QueueDepth))),
        ("ladder", Json::num(w.max[Gauge::LadderLevel.index()])),
        ("occupancy", Json::num(w.mean(Gauge::SlotOccupancy))),
        ("pool_pages", Json::num(w.mean(Gauge::PoolPages))),
        ("done", Json::num(w.done as f64)),
        ("p95_ms", Json::num(w.lat.percentile_ms(95.0))),
        ("tenant_done", tenant_done),
    ])
}

fn span_row(s: &Span) -> Json {
    Json::obj(vec![
        ("kind", Json::str("span")),
        ("req", Json::num(s.req as f64)),
        ("tenant", Json::num(s.tenant as f64)),
        ("group", Json::num(s.group as f64)),
        ("phase", Json::str(s.phase.as_str())),
        ("start_ns", Json::num(s.start_ns as f64)),
        ("end_ns", Json::num(s.end_ns as f64)),
        ("value", Json::num(s.value as f64)),
    ])
}

/// JSONL contract: one `meta` line, then `span` lines, then `window`
/// lines. Everything the CI smoke and `trace-report` consume.
pub fn write_jsonl(path: &Path, trace: &TraceStats, sample: f64) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let phases = Json::Arr(Phase::ALL.iter().map(|p| Json::str(p.as_str())).collect());
    let meta = Json::obj(vec![
        ("kind", Json::str("meta")),
        ("version", Json::num(1.0)),
        ("sample", Json::num(sample)),
        ("window_ms", Json::num(trace.timeline.window_ns as f64 / 1e6)),
        ("dropped_spans", Json::num(trace.dropped_spans as f64)),
        ("spans", Json::num(trace.span_count() as f64)),
        ("phases", phases),
    ]);
    writeln!(f, "{meta}")?;
    for s in trace.spans() {
        writeln!(f, "{}", span_row(s))?;
    }
    for (idx, w) in &trace.timeline.windows {
        writeln!(f, "{}", window_row(*idx, trace.timeline.window_ns, w))?;
    }
    f.flush()
}

/// A parsed `window` line (reader-side view of [`WindowAgg`]).
#[derive(Debug, Clone)]
pub struct WindowRow {
    pub index: u64,
    pub start_ms: f64,
    pub queue_depth: f64,
    pub ladder: f64,
    pub occupancy: f64,
    pub pool_pages: f64,
    pub done: u64,
    pub p95_ms: f64,
}

/// A parsed trace file.
#[derive(Debug, Clone, Default)]
pub struct TraceFile {
    pub sample: f64,
    pub window_ms: f64,
    pub dropped_spans: u64,
    pub spans: Vec<Span>,
    pub windows: Vec<WindowRow>,
}

pub fn read_jsonl(path: &Path) -> Result<TraceFile> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut out = TraceFile::default();
    let mut saw_meta = false;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {}", lineno + 1, e))?;
        match v.get("kind").as_str() {
            Some("meta") => {
                saw_meta = true;
                out.sample = v.get("sample").as_f64().unwrap_or(0.0);
                out.window_ms = v.get("window_ms").as_f64().unwrap_or(0.0);
                out.dropped_spans = v.get("dropped_spans").as_f64().unwrap_or(0.0) as u64;
            }
            Some("span") => {
                let name = v.get("phase").as_str().unwrap_or("");
                let phase = Phase::from_name(name)
                    .with_context(|| format!("trace line {}: unknown phase {name:?}", lineno + 1))?;
                out.spans.push(Span {
                    req: v.get("req").as_f64().unwrap_or(0.0) as u64,
                    tenant: v.get("tenant").as_f64().unwrap_or(0.0) as u32,
                    group: v.get("group").as_f64().unwrap_or(0.0) as u32,
                    phase,
                    start_ns: v.get("start_ns").as_f64().unwrap_or(0.0) as u64,
                    end_ns: v.get("end_ns").as_f64().unwrap_or(0.0) as u64,
                    value: v.get("value").as_i64().unwrap_or(0),
                });
            }
            Some("window") => out.windows.push(WindowRow {
                index: v.get("index").as_f64().unwrap_or(0.0) as u64,
                start_ms: v.get("start_ms").as_f64().unwrap_or(0.0),
                queue_depth: v.get("queue_depth").as_f64().unwrap_or(0.0),
                ladder: v.get("ladder").as_f64().unwrap_or(0.0),
                occupancy: v.get("occupancy").as_f64().unwrap_or(0.0),
                pool_pages: v.get("pool_pages").as_f64().unwrap_or(0.0),
                done: v.get("done").as_f64().unwrap_or(0.0) as u64,
                p95_ms: v.get("p95_ms").as_f64().unwrap_or(0.0),
            }),
            other => bail!("trace line {}: unknown kind {other:?}", lineno + 1),
        }
    }
    if !saw_meta {
        bail!("{}: no meta line — not a trace file", path.display());
    }
    Ok(out)
}

const BAR_WIDTH: usize = 48;
const PHASE_GLYPH: [char; 5] = ['a', 'q', 'r', 'P', 'D'];

fn waterfall_bar(a: &ReqAttr) -> String {
    let total = a.top_level_ns().max(1);
    let mut bar = String::new();
    for (pi, p) in Phase::TOP_LEVEL.iter().enumerate() {
        let cells =
            ((a.phase_ns[p.index()] as f64 / total as f64) * BAR_WIDTH as f64).round() as usize;
        for _ in 0..cells {
            bar.push(PHASE_GLYPH[pi]);
        }
    }
    bar
}

/// Text waterfall + phase attribution + timeline summary.
pub fn render_report(tf: &TraceFile, top: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "trace: {} spans ({} dropped at source), sample {:.2}, window {:.0} ms\n\n",
        tf.spans.len(),
        tf.dropped_spans,
        tf.sample,
        tf.window_ms,
    ));

    let attrs = per_request(tf.spans.iter());
    let all = attribute(&attrs, 1.0);
    let tail = attribute(&attrs, 0.05);
    out.push_str(&format!("phase attribution ({} requests; tail = slowest 5%)\n", all.requests));
    out.push_str("  phase              total ms    share   tail share\n");
    let shares = all.shares();
    let tail_shares = tail.shares();
    for p in Phase::TOP_LEVEL {
        out.push_str(&format!(
            "  {:<18} {:>9.2}  {:>6.1}%    {:>6.1}%\n",
            p.as_str(),
            all.phase_ns[p.index()] as f64 / 1e6,
            100.0 * shares[p.index()],
            100.0 * tail_shares[p.index()],
        ));
    }
    let nested: Vec<Phase> =
        vec![Phase::DecodeIter, Phase::SpecDraft, Phase::SpecVerify, Phase::Allreduce];
    let mut breakdown = PhaseBreakdown::default();
    for s in &tf.spans {
        breakdown.add(s.phase, s.dur_ns());
    }
    if nested.iter().any(|p| breakdown.ns[p.index()] > 0) {
        out.push_str("  nested (attributed inside prefill/decode):\n");
        for p in nested {
            let (ns, count) = breakdown.get(p);
            if count > 0 {
                out.push_str(&format!(
                    "    {:<16} {:>9.2} ms over {count} spans\n",
                    p.as_str(),
                    ns as f64 / 1e6,
                ));
            }
        }
    }

    if !attrs.is_empty() && top > 0 {
        let mut slow: Vec<&ReqAttr> = attrs.iter().collect();
        slow.sort_by(|a, b| b.e2e_ns().cmp(&a.e2e_ns()).then(a.req.cmp(&b.req)));
        out.push_str(&format!("\nslowest requests (top {})\n", top.min(slow.len())));
        out.push_str("  [a]dmission [q]os [r]outer-dispatch [P]refill [D]ecode\n");
        for a in slow.into_iter().take(top) {
            out.push_str(&format!(
                "  req {:>6} tenant {} e2e {:>8.2} ms |{}|\n",
                a.req,
                a.tenant,
                a.e2e_ns() as f64 / 1e6,
                waterfall_bar(a),
            ));
        }
    }

    let ladder: Vec<&Span> =
        tf.spans.iter().filter(|s| s.phase == Phase::LadderLevel).collect();
    if !ladder.is_empty() {
        out.push_str("\noverload-ladder transitions\n");
        for s in ladder {
            out.push_str(&format!(
                "  t={:>9.2} ms -> level {}\n",
                s.start_ns as f64 / 1e6,
                s.value,
            ));
        }
    }

    if !tf.windows.is_empty() {
        out.push_str("\ntimeline\n");
        out.push_str("  start_ms   depth  ladder   occ  pool   done  p95_ms\n");
        for w in &tf.windows {
            out.push_str(&format!(
                "  {:>8.0} {:>7.1} {:>7.0} {:>5.1} {:>5.0} {:>6} {:>7.2}\n",
                w.start_ms, w.queue_depth, w.ladder, w.occupancy, w.pool_pages, w.done, w.p95_ms,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(req: u64, phase: Phase, start_ns: u64, end_ns: u64) -> Span {
        Span { req, tenant: 0, group: 0, phase, start_ns, end_ns, value: 0 }
    }

    #[test]
    fn phase_names_roundtrip() {
        for p in Phase::ALL {
            assert_eq!(Phase::from_name(p.as_str()), Some(p));
        }
        assert_eq!(Phase::from_name("nope"), None);
        assert!(Phase::Prefill.is_top_level());
        assert!(!Phase::Allreduce.is_top_level());
    }

    /// Ring overflow drops the *oldest* span and surfaces the count.
    #[test]
    fn ring_overflow_drops_oldest() {
        let mut t = TraceStats::default();
        t.set_limits(3, 100);
        for i in 1..=5u64 {
            t.record(span(i, Phase::Decode, i * 10, i * 10 + 5));
        }
        assert_eq!(t.dropped_spans, 2);
        let reqs: Vec<u64> = t.spans().map(|s| s.req).collect();
        assert_eq!(reqs, vec![3, 4, 5], "oldest (1, 2) dropped first");
    }

    /// Same (sample, salt) ⇒ same sampled set; salt changes the set;
    /// rate lands near the target on a large population.
    #[test]
    fn sampling_is_deterministic_and_calibrated() {
        let cfg = TraceConfig::new(0.25, 42);
        let again = TraceConfig::new(0.25, 42);
        let other_salt = TraceConfig::new(0.25, 43);
        let mut hits = 0usize;
        let mut diff = 0usize;
        let n = 20_000u64;
        for i in 0..n {
            let h = trace_hash(&[i as i32, (i >> 8) as i32, 7]);
            assert_eq!(cfg.sampled(h), again.sampled(h), "deterministic per hash");
            hits += cfg.sampled(h) as usize;
            diff += (cfg.sampled(h) != other_salt.sampled(h)) as usize;
        }
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "sampling rate {rate} far from 0.25");
        assert!(diff > 0, "salt must perturb the sampled set");
        assert!(TraceConfig::new(1.0, 0).sampled(123));
        assert!(!TraceConfig::new(0.0, 0).sampled(123));
    }

    #[test]
    fn trace_hash_is_content_keyed() {
        assert_eq!(trace_hash(&[1, 2, 3]), trace_hash(&[1, 2, 3]));
        assert_ne!(trace_hash(&[1, 2, 3]), trace_hash(&[3, 2, 1]));
        assert_ne!(trace_hash(&[]), trace_hash(&[0]));
    }

    /// Top-level spans tile e2e; per_request + attribute rebuild it.
    #[test]
    fn per_request_attribution_partitions_e2e() {
        let spans = vec![
            span(7, Phase::AdmissionQueue, 100, 200),
            span(7, Phase::QosQueue, 200, 250),
            span(7, Phase::RouterDispatch, 250, 400),
            span(7, Phase::Prefill, 400, 900),
            span(7, Phase::Decode, 900, 2100),
            // Nested attribution must not perturb the top-level sum.
            span(7, Phase::DecodeIter, 900, 2000),
            span(0, Phase::LadderLevel, 500, 500),
        ];
        let attrs = per_request(spans.iter());
        assert_eq!(attrs.len(), 1, "event spans (req=0) excluded");
        let a = &attrs[0];
        assert_eq!(a.e2e_ns(), 2000);
        assert_eq!(a.top_level_ns(), 2000, "phases partition e2e exactly");
        let at = attribute(&attrs, 1.0);
        let shares = at.shares();
        let total: f64 = Phase::TOP_LEVEL.iter().map(|p| shares[p.index()]).sum();
        assert!((total - 1.0).abs() < 1e-9, "top-level shares sum to 1.0");
        assert!((shares[Phase::Decode.index()] - 0.6).abs() < 1e-9);
    }

    #[test]
    fn attribute_tail_takes_slowest() {
        let mut spans = Vec::new();
        for r in 1..=20u64 {
            // Request r has e2e = r*100 ns, all in decode.
            spans.push(span(r, Phase::Decode, 0, r * 100));
        }
        let attrs = per_request(spans.iter());
        let tail = attribute(&attrs, 0.05);
        assert_eq!(tail.requests, 1);
        assert_eq!(tail.e2e_ns, 2000, "slowest request only");
        let all = attribute(&attrs, 1.0);
        assert_eq!(all.requests, 20);
    }

    #[test]
    fn timeline_bins_and_merges_by_window() {
        let mut a = TimelineRegistry::new(100);
        a.gauge(Gauge::QueueDepth, 4.0, 50_000_000); // window 0
        a.gauge(Gauge::QueueDepth, 8.0, 150_000_000); // window 1
        a.note_done(1, 12.0, 150_000_000);
        let mut b = TimelineRegistry::new(100);
        b.gauge(Gauge::QueueDepth, 2.0, 160_000_000); // window 1
        b.note_done(0, 20.0, 10_000_000); // window 0
        a.merge(&b);
        assert_eq!(a.windows.len(), 2);
        let w1 = &a.windows[&1];
        assert!((w1.mean(Gauge::QueueDepth) - 5.0).abs() < 1e-9);
        assert_eq!(w1.max[Gauge::QueueDepth.index()], 8.0);
        assert_eq!(w1.tenant_done, vec![0, 1]);
        assert_eq!(a.windows[&0].done, 1);
    }

    #[test]
    fn merge_concatenates_without_dropping() {
        let mut a = TraceStats::default();
        a.set_limits(2, 100);
        a.record(span(1, Phase::Decode, 0, 10));
        let mut b = TraceStats::default();
        b.set_limits(2, 100);
        for i in 2..=4u64 {
            b.record(span(i, Phase::Decode, 0, 10));
        }
        assert_eq!(b.dropped_spans, 1);
        a.merge(&b);
        assert_eq!(a.span_count(), 3, "merge keeps all surviving spans");
        assert_eq!(a.dropped_spans, 1, "source drops carried through");
        assert_eq!(a.phases.count[Phase::Decode.index()], 0, "breakdown separate from ring");
    }

    #[test]
    fn jsonl_roundtrip() {
        let mut t = TraceStats::default();
        t.set_limits(16, 100);
        t.record(span(3, Phase::Prefill, 1_000, 2_000));
        t.record(Span {
            req: 0,
            tenant: 0,
            group: 9,
            phase: Phase::LadderLevel,
            start_ns: 5_000,
            end_ns: 5_000,
            value: 2,
        });
        t.timeline.gauge(Gauge::QueueDepth, 3.0, 50_000_000);
        t.timeline.note_done(0, 7.5, 50_000_000);
        let dir = std::env::temp_dir().join("altup_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("roundtrip_{}.jsonl", std::process::id()));
        write_jsonl(&path, &t, 0.5).unwrap();
        let tf = read_jsonl(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(tf.spans.len(), 2);
        assert_eq!(tf.sample, 0.5);
        assert_eq!(tf.dropped_spans, 0);
        assert_eq!(tf.spans[0].phase, Phase::Prefill);
        assert_eq!(tf.spans[1].value, 2);
        assert_eq!(tf.windows.len(), 1);
        assert_eq!(tf.windows[0].done, 1);
        assert!(tf.windows[0].p95_ms > 0.0);
        let report = render_report(&tf, 4);
        assert!(report.contains("phase attribution"), "{report}");
        assert!(report.contains("ladder"), "{report}");
    }

    #[test]
    fn breakdown_shares() {
        let mut b = PhaseBreakdown::default();
        b.add(Phase::Prefill, 250);
        b.add(Phase::Decode, 750);
        b.add_n(Phase::Allreduce, 100, 12);
        assert_eq!(b.total_ns(&Phase::TOP_LEVEL), 1000);
        assert!((b.share(Phase::Decode, &Phase::TOP_LEVEL) - 0.75).abs() < 1e-9);
        assert!((b.share(Phase::Allreduce, &Phase::TOP_LEVEL) - 0.1).abs() < 1e-9);
        let mut c = PhaseBreakdown::default();
        c.merge(&b);
        assert_eq!(c, b);
        assert_eq!(c.get(Phase::Allreduce), (100, 12));
    }
}
