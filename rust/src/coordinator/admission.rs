//! §L10 multi-tenant QoS admission control: the layer between
//! `ServerHandle::infer` and the router's bucket groups.
//!
//! The L7 supervisor survives crashed replicas and the L9 pool survives
//! memory pressure, but nothing before this layer protected the server
//! from *traffic itself* — a burst from one tenant starved everyone
//! equally, and overload was absorbed as unbounded queueing latency
//! instead of deliberate shedding. This module adds three defenses, in
//! the order a request meets them:
//!
//! ```text
//!   infer() ──► token bucket ──► SLO wait gate ──► weighted priority
//!              (per tenant,     (estimated queue   queues (drained
//!               QueueFull)       wait vs deadline,  high priority
//!                                WouldMissDeadline) first, weighted
//!                                                   within a class)
//!                                      │
//!                 overload controller ─┴─► degradation ladder:
//!                 (sustained backlog)      1. shed lowest class early
//!                                          2. shrink spec-decode γ
//!                                          3. autoscale replicas
//! ```
//!
//! Everything here is policy — the router (`coordinator::server::route`)
//! stays the only place that touches replicas, job queues, or reply
//! channels. The controller hands back verdicts (`offer`), release
//! batches (`release`), and ladder actions (`tick`); with no tenants
//! configured every call is a passthrough and the serving path is
//! behaviorally identical to pre-L10.

use crate::coordinator::server::{FailReason, Request};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One tenant's QoS contract. Configured programmatically via
/// `ServerOptions::tenants` or via `ALTUP_TENANT_SPEC`
/// (`name:priority:weight:rate:burst:slo_ms` per tenant, `;`-separated;
/// malformed fields fall back field-wise to the defaults below, in the
/// same degrade-don't-crash spirit as `util::env`).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Scheduling class: higher drains first and sheds last.
    pub priority: u8,
    /// Share of service within a priority class (weighted dequeue).
    pub weight: u32,
    /// Token-bucket refill in requests/second; 0 = unlimited.
    pub rate: f64,
    /// Token-bucket capacity (burst allowance); 0 = `rate.max(1)`.
    pub burst: f64,
    /// Latency SLO in ms. Admission stamps `t0 + slo_ms` as the
    /// request deadline (unless the client set its own), so the whole
    /// L7 deadline machinery enforces it downstream; 0 = none.
    pub slo_ms: u64,
}

impl Default for TenantSpec {
    fn default() -> Self {
        TenantSpec {
            name: "default".to_string(),
            priority: 1,
            weight: 1,
            rate: 0.0,
            burst: 0.0,
            slo_ms: 0,
        }
    }
}

impl TenantSpec {
    fn effective_burst(&self) -> f64 {
        if self.burst > 0.0 {
            self.burst
        } else {
            self.rate.max(1.0)
        }
    }
}

/// Parse one `name:priority:weight:rate:burst:slo_ms` clause. Missing
/// or malformed fields keep their defaults — a typo'd field degrades
/// that field, not the tenant.
fn parse_tenant(clause: &str) -> Option<TenantSpec> {
    let mut fields = clause.split(':');
    let name = fields.next()?.trim();
    if name.is_empty() {
        return None;
    }
    let mut t = TenantSpec { name: name.to_string(), ..TenantSpec::default() };
    if let Some(p) = fields.next().and_then(|f| f.trim().parse::<u8>().ok()) {
        t.priority = p;
    }
    if let Some(w) = fields.next().and_then(|f| f.trim().parse::<u32>().ok()) {
        t.weight = w.max(1);
    }
    if let Some(r) = fields.next().and_then(|f| f.trim().parse::<f64>().ok()) {
        if r.is_finite() && r >= 0.0 {
            t.rate = r;
        }
    }
    if let Some(b) = fields.next().and_then(|f| f.trim().parse::<f64>().ok()) {
        if b.is_finite() && b >= 0.0 {
            t.burst = b;
        }
    }
    if let Some(s) = fields.next().and_then(|f| f.trim().parse::<u64>().ok()) {
        t.slo_ms = s;
    }
    Some(t)
}

/// Parse an `ALTUP_TENANT_SPEC`-style string into tenant specs.
/// Unparsable clauses are dropped; an empty result means "QoS off".
pub fn parse_tenant_spec(raw: &str) -> Vec<TenantSpec> {
    raw.split(';').filter_map(parse_tenant).collect()
}

/// The serving-default tenant set: `ALTUP_TENANT_SPEC` (unset or
/// unparsable = no tenants = QoS passthrough).
pub fn tenants_from_env() -> Vec<TenantSpec> {
    std::env::var("ALTUP_TENANT_SPEC")
        .map(|raw| parse_tenant_spec(&raw))
        .unwrap_or_default()
}

/// Degradation-ladder actions the router executes on the controller's
/// behalf (the controller itself never touches replicas or channels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QosAction {
    /// Cap the speculative draft length γ (`usize::MAX` restores).
    GammaCap(usize),
    /// Spawn one autoscale replica (router enforces the budget).
    ScaleUp,
    /// Retire one autoscale replica.
    ScaleDown,
}

/// Sustained backlog above the high watermark for this long escalates
/// the overload ladder one level.
const OVERLOAD_HOLD: Duration = Duration::from_millis(300);
/// Sustained calm below the low watermark for this long de-escalates.
const CALM_HOLD: Duration = Duration::from_millis(500);
/// Service-rate estimator update window.
const RATE_WINDOW: Duration = Duration::from_millis(250);
/// EWMA smoothing for the service-rate estimate.
const RATE_ALPHA: f64 = 0.3;

/// A request parked in a tenant queue (deadline already stamped).
struct Queued {
    req: Request,
    priority: u8,
}

/// Per-tenant admission state + the overload controller. Owned by the
/// router thread; nothing here is shared or locked.
pub struct AdmissionController {
    tenants: Vec<TenantSpec>,
    /// Token-bucket fill per tenant (requests).
    buckets: Vec<f64>,
    queues: Vec<VecDeque<Queued>>,
    /// Weighted-dequeue bookkeeping: served[t]/weight[t] is the cost a
    /// tenant has accrued; the cheapest non-empty tenant in the top
    /// priority class drains next.
    served: Vec<u64>,
    /// Total parked requests across all tenant queues.
    queued: usize,
    /// Cap on `queued`; beyond it arrivals preempt or self-shed.
    cap: usize,
    /// The lowest configured priority — the class overload sheds first.
    lowest_priority: u8,
    base_gamma: usize,
    last_refill: Instant,
    // SLO wait estimator: EWMA of the release (== downstream service)
    // rate, measured over RATE_WINDOW. 0.0 until the first window with
    // releases completes — the gate stays open while cold.
    service_rate: f64,
    window_start: Instant,
    window_released: u64,
    // Overload ladder.
    level: u8,
    pressure_since: Option<Instant>,
    calm_since: Option<Instant>,
}

impl AdmissionController {
    pub fn new(tenants: Vec<TenantSpec>, cap: usize, base_gamma: usize, now: Instant) -> Self {
        let n = tenants.len();
        let lowest = tenants.iter().map(|t| t.priority).min().unwrap_or(0);
        AdmissionController {
            buckets: tenants.iter().map(|t| t.effective_burst()).collect(),
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            served: vec![0; n],
            queued: 0,
            cap: cap.max(1),
            lowest_priority: lowest,
            base_gamma,
            last_refill: now,
            service_rate: 0.0,
            window_start: now,
            window_released: 0,
            level: 0,
            pressure_since: None,
            calm_since: None,
            tenants,
        }
    }

    /// No tenants configured: every `offer` releases immediately and
    /// the overload ladder never engages.
    pub fn passthrough(&self) -> bool {
        self.tenants.is_empty()
    }

    pub fn queued(&self) -> usize {
        self.queued
    }

    /// Current overload-ladder level (0 = normal), for telemetry.
    pub fn level(&self) -> u8 {
        self.level
    }

    /// Estimated queue wait for a request with `depth` requests ahead
    /// of it, from the EWMA'd service rate. 0 while the estimator is
    /// cold (no shedding on a guess the controller hasn't earned).
    pub fn estimated_wait_ms(&self, depth: usize) -> f64 {
        if self.service_rate <= 0.0 {
            0.0
        } else {
            depth as f64 / self.service_rate * 1e3
        }
    }

    fn refill(&mut self, now: Instant) {
        let dt = now.saturating_duration_since(self.last_refill).as_secs_f64();
        self.last_refill = now;
        for (b, t) in self.buckets.iter_mut().zip(&self.tenants) {
            if t.rate > 0.0 {
                *b = (*b + t.rate * dt).min(t.effective_burst());
            }
        }
    }

    /// Admission verdict for one request. `downstream` is the work
    /// already released but not yet dispatched (the router's bucket
    /// groups) — it counts toward the wait a new arrival would see.
    /// `Ok(Some(req))` releases the request straight through
    /// (passthrough mode); `Ok(None)` parked it in a tenant queue;
    /// `Err` is an explicit early shed the caller must answer.
    #[allow(clippy::result_large_err)]
    pub fn offer(
        &mut self,
        mut req: Request,
        now: Instant,
        downstream: usize,
    ) -> Result<Option<Request>, (Request, FailReason)> {
        if self.passthrough() {
            return Ok(Some(req));
        }
        self.refill(now);
        let t = req.tenant.min(self.tenants.len() - 1);
        let spec = &self.tenants[t];
        let priority = req.priority.min(spec.priority);
        // SLO deadline stamp: from here on the L7 machinery (router
        // sheds, replica sheds, slot retirement) enforces the SLO as a
        // hard deadline; admission only adds the *early* sheds below.
        if req.deadline.is_none() && spec.slo_ms > 0 {
            req.deadline = Some(req.t0 + Duration::from_millis(spec.slo_ms));
        }
        // 1. Token bucket: the per-tenant rate limit. A tenant over
        // its rate is the one tenant whose burst must not queue.
        if spec.rate > 0.0 {
            if self.buckets[t] < 1.0 {
                return Err((req, FailReason::QueueFull));
            }
            self.buckets[t] -= 1.0;
        }
        // 2. Overload ladder level >= 1: the lowest class loses its
        // right to queue behind a backlog — shed at the door while
        // higher classes still park.
        let depth = self.queued + downstream;
        if self.level >= 1 && priority == self.lowest_priority && depth > self.cap / 4 {
            return Err((req, FailReason::QueueFull));
        }
        // 3. SLO-aware early shed: if the estimated queue wait alone
        // already overshoots the deadline, reject now instead of
        // letting doomed work occupy a queue slot and a prefill.
        if let Some(deadline) = req.deadline {
            let wait = Duration::from_secs_f64(self.estimated_wait_ms(depth) / 1e3);
            if now + wait >= deadline {
                return Err((req, FailReason::WouldMissDeadline));
            }
        }
        // 4. Queue cap with priority preemption: a full house sheds
        // the newest lowest-priority entry below the arrival's class
        // rather than the arrival itself. Either way the `Err` carries
        // the one request the caller must answer with a failure.
        if self.queued >= self.cap {
            if let Some(victim) = self.preempt_below(priority) {
                self.queues[t].push_back(Queued { req, priority });
                self.queued += 1;
                return Err((victim.req, FailReason::QueueFull));
            }
            return Err((req, FailReason::QueueFull));
        }
        self.queues[t].push_back(Queued { req, priority });
        self.queued += 1;
        Ok(None)
    }

    /// Drop the newest queued entry whose priority is strictly below
    /// `priority` (lowest class first), making room for a higher-class
    /// arrival.
    fn preempt_below(&mut self, priority: u8) -> Option<Queued> {
        let victim_t = self
            .queues
            .iter()
            .enumerate()
            .filter_map(|(i, q)| q.back().map(|e| (i, e.priority)))
            .filter(|&(_, p)| p < priority)
            .min_by_key(|&(_, p)| p)
            .map(|(i, _)| i)?;
        let victim = self.queues[victim_t].pop_back()?;
        self.queued -= 1;
        Some(victim)
    }

    /// Release up to `room` parked requests in weighted-priority order:
    /// strictly higher classes first; within a class, tenants drain
    /// proportionally to their weights (cheapest accrued cost first).
    pub fn release(&mut self, room: usize, out: &mut Vec<Request>) {
        for _ in 0..room {
            let Some(t) = self.next_tenant() else { break };
            let Some(entry) = self.queues[t].pop_front() else { break };
            self.queued -= 1;
            self.served[t] += 1;
            self.window_released += 1;
            out.push(entry.req);
        }
    }

    /// The tenant to drain next: highest non-empty priority class,
    /// then lowest weighted cost (`served/weight`) within it, index as
    /// the deterministic tie-break.
    fn next_tenant(&self) -> Option<usize> {
        let top = self
            .tenants
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.queues[*i].is_empty())
            .map(|(_, t)| t.priority)
            .max()?;
        self.tenants
            .iter()
            .enumerate()
            .filter(|(i, t)| t.priority == top && !self.queues[*i].is_empty())
            .min_by(|(i, a), (j, b)| {
                let ca = self.served[*i] as f64 / a.weight.max(1) as f64;
                let cb = self.served[*j] as f64 / b.weight.max(1) as f64;
                ca.partial_cmp(&cb).unwrap_or(std::cmp::Ordering::Equal).then(i.cmp(j))
            })
            .map(|(i, _)| i)
    }

    /// Expire queued requests past their deadline (the queues live
    /// outside the router's bucket groups, so `shed_expired` cannot
    /// see them). Returns the expired requests for the caller to fail.
    pub fn take_expired(&mut self, now: Instant, out: &mut Vec<Request>) {
        for q in &mut self.queues {
            let mut keep = VecDeque::with_capacity(q.len());
            for e in q.drain(..) {
                if e.req.expired(now) {
                    self.queued -= 1;
                    out.push(e.req);
                } else {
                    keep.push_back(e);
                }
            }
            *q = keep;
        }
    }

    /// Overload-controller heartbeat: update the service-rate EWMA and
    /// walk the degradation ladder on sustained pressure/calm.
    /// `downstream` as in `offer`; `capacity_hint` is one full wave of
    /// work for the current fleet (live replicas x batch_size).
    pub fn tick(
        &mut self,
        now: Instant,
        downstream: usize,
        capacity_hint: usize,
        actions: &mut Vec<QosAction>,
    ) {
        if self.passthrough() {
            return;
        }
        if now.saturating_duration_since(self.window_start) >= RATE_WINDOW {
            let dt = now.saturating_duration_since(self.window_start).as_secs_f64();
            if self.window_released > 0 || self.service_rate > 0.0 {
                let inst = self.window_released as f64 / dt.max(1e-9);
                self.service_rate = if self.service_rate > 0.0 {
                    self.service_rate * (1.0 - RATE_ALPHA) + inst * RATE_ALPHA
                } else {
                    inst
                };
            }
            self.window_start = now;
            self.window_released = 0;
        }
        let depth = self.queued + downstream;
        let hint = capacity_hint.max(1);
        let pressured = depth > 2 * hint;
        let calm = depth < hint / 2 + 1;
        if pressured {
            self.calm_since = None;
            let since = *self.pressure_since.get_or_insert(now);
            if now.saturating_duration_since(since) >= OVERLOAD_HOLD {
                self.pressure_since = Some(now);
                self.escalate(actions);
            }
        } else if calm {
            self.pressure_since = None;
            let since = *self.calm_since.get_or_insert(now);
            if now.saturating_duration_since(since) >= CALM_HOLD {
                self.calm_since = Some(now);
                self.de_escalate(actions);
            }
        } else {
            self.pressure_since = None;
            self.calm_since = None;
        }
    }

    /// The degradation ladder, one rung per sustained-pressure hold:
    /// 1 sheds the lowest class early (enforced in `offer`), 2 halves
    /// the speculative draft length, 3+ asks for an autoscale replica
    /// (the router enforces the `ServerOptions::autoscale` budget).
    fn escalate(&mut self, actions: &mut Vec<QosAction>) {
        self.level = self.level.saturating_add(1);
        match self.level {
            1 => {}
            2 if self.base_gamma > 1 => {
                actions.push(QosAction::GammaCap((self.base_gamma / 2).max(1)));
            }
            _ => actions.push(QosAction::ScaleUp),
        }
    }

    fn de_escalate(&mut self, actions: &mut Vec<QosAction>) {
        match self.level {
            0 => actions.push(QosAction::ScaleDown), // calm at level 0 retires extras
            1 => {}
            2 => {
                if self.base_gamma > 1 {
                    actions.push(QosAction::GammaCap(usize::MAX));
                }
            }
            _ => actions.push(QosAction::ScaleDown),
        }
        self.level = self.level.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn spec3() -> Vec<TenantSpec> {
        parse_tenant_spec("free:0:1:100:10:0;silver:1:2:0:0:4000;gold:2:4:0:0:1500")
    }

    fn req(tenant: usize, priority: u8) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request::for_tenant(vec![1, 2, 3], tx, tenant, priority)
    }

    #[test]
    fn tenant_spec_parsing_field_wise_defaults() {
        let ts = spec3();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0].name, "free");
        assert_eq!(ts[0].priority, 0);
        assert_eq!(ts[0].rate, 100.0);
        assert_eq!(ts[0].burst, 10.0);
        assert_eq!(ts[2].name, "gold");
        assert_eq!(ts[2].priority, 2);
        assert_eq!(ts[2].weight, 4);
        assert_eq!(ts[2].slo_ms, 1500);
        // Malformed fields degrade field-wise, not tenant-wise.
        let t = parse_tenant_spec("odd:zz:-1:NaN:inf:huge");
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].priority, TenantSpec::default().priority);
        assert_eq!(t[0].weight, TenantSpec::default().weight);
        assert_eq!(t[0].rate, 0.0);
        assert_eq!(t[0].burst, 0.0);
        assert_eq!(t[0].slo_ms, 0);
        // Short clauses keep trailing defaults; empty clauses drop.
        assert_eq!(parse_tenant_spec("solo")[0].weight, 1);
        assert!(parse_tenant_spec(";;").is_empty());
        assert!(parse_tenant_spec("").is_empty());
    }

    #[test]
    fn passthrough_releases_immediately() {
        let now = Instant::now();
        let mut ac = AdmissionController::new(Vec::new(), 8, 0, now);
        assert!(ac.passthrough());
        let r = ac.offer(req(0, 0), now, 0).expect("no shed");
        assert!(r.is_some(), "passthrough releases straight through");
        assert_eq!(ac.queued(), 0);
    }

    #[test]
    fn token_bucket_rate_limits_per_tenant() {
        let now = Instant::now();
        let mut ac = AdmissionController::new(spec3(), 1024, 0, now);
        // free has burst 10: the 11th immediate arrival is rate-shed.
        for i in 0..10 {
            assert!(ac.offer(req(0, 0), now, 0).is_ok(), "arrival {i} within burst");
        }
        let err = ac.offer(req(0, 0), now, 0).expect_err("over burst");
        assert_eq!(err.1, FailReason::QueueFull);
        // gold is unlimited: never rate-shed.
        for _ in 0..64 {
            assert!(ac.offer(req(2, 2), now, 0).is_ok());
        }
        // Refill at 100/s: 50 ms buys 5 more free tokens.
        let later = now + Duration::from_millis(50);
        for i in 0..5 {
            assert!(ac.offer(req(0, 0), later, 0).is_ok(), "refilled token {i}");
        }
        assert!(ac.offer(req(0, 0), later, 0).is_err(), "refill is bounded");
    }

    #[test]
    fn release_orders_by_priority_then_weight() {
        let now = Instant::now();
        // No rate limits so ordering is isolated.
        let ts = parse_tenant_spec("free:0:1:0:0:0;silver:1:2:0:0:0;gold:2:4:0:0:0");
        let mut ac = AdmissionController::new(ts, 1024, 0, now);
        for _ in 0..3 {
            ac.offer(req(0, 0), now, 0).unwrap();
        }
        for _ in 0..2 {
            ac.offer(req(1, 1), now, 0).unwrap();
            ac.offer(req(2, 2), now, 0).unwrap();
        }
        let mut out = Vec::new();
        ac.release(16, &mut out);
        let tenants: Vec<usize> = out.iter().map(|r| r.tenant).collect();
        // Gold (priority 2) fully drains before silver, silver before
        // free — weights only matter within one class.
        assert_eq!(tenants, vec![2, 2, 1, 1, 0, 0, 0]);
        assert_eq!(ac.queued(), 0);
    }

    #[test]
    fn weighted_share_within_a_priority_class() {
        let now = Instant::now();
        let ts = parse_tenant_spec("a:1:1:0:0:0;b:1:3:0:0:0");
        let mut ac = AdmissionController::new(ts, 1024, 0, now);
        for _ in 0..20 {
            ac.offer(req(0, 1), now, 0).unwrap();
            ac.offer(req(1, 1), now, 0).unwrap();
        }
        let mut out = Vec::new();
        ac.release(8, &mut out);
        let b_share =
            out.iter().filter(|r| r.tenant == 1).count() as f64 / out.len() as f64;
        assert!(b_share >= 0.6, "weight-3 tenant under-served: {b_share}");
    }

    #[test]
    fn queue_cap_preempts_lowest_class_first() {
        let now = Instant::now();
        let ts = parse_tenant_spec("free:0:1:0:0:0;gold:2:1:0:0:0");
        let mut ac = AdmissionController::new(ts, 4, 0, now);
        for _ in 0..4 {
            ac.offer(req(0, 0), now, 0).unwrap();
        }
        // A gold arrival at a full house displaces a queued free
        // request (the returned shed victim), not itself — the gold
        // request is parked in the victim's place.
        let (victim, reason) = ac.offer(req(2, 2), now, 0).expect_err("victim returned");
        assert_eq!(reason, FailReason::QueueFull);
        assert_eq!(victim.tenant, 0, "lowest class absorbed the shed");
        assert_eq!(ac.queued(), 4);
        // A free arrival at a full house of peers sheds itself.
        let (victim, _) = ac.offer(req(0, 0), now, 0).expect_err("self-shed");
        assert_eq!(victim.tenant, 0);
    }

    #[test]
    fn slo_deadline_stamp_and_wait_gate() {
        let now = Instant::now();
        let mut ac = AdmissionController::new(spec3(), 1024, 0, now);
        // Cold estimator: gold (1500 ms SLO) parks and gets a deadline.
        ac.offer(req(2, 2), now, 0).unwrap();
        let mut out = Vec::new();
        ac.release(1, &mut out);
        let d = out[0].deadline.expect("SLO stamped as deadline");
        let slack = d.saturating_duration_since(out[0].t0);
        assert!(slack >= Duration::from_millis(1400) && slack <= Duration::from_millis(1600));
        // Warm the estimator to ~10 req/s, then a deep backlog makes
        // the estimated wait overshoot the SLO: early shed.
        ac.service_rate = 10.0;
        assert!(ac.estimated_wait_ms(20) > 1900.0);
        let (_, reason) = ac.offer(req(2, 2), now, 40).expect_err("doomed arrival");
        assert_eq!(reason, FailReason::WouldMissDeadline);
        // free has no SLO: the same backlog does not shed it.
        assert!(ac.offer(req(0, 0), now, 40).is_ok());
    }

    #[test]
    fn overload_ladder_escalates_and_recovers() {
        let now = Instant::now();
        let mut ac = AdmissionController::new(spec3(), 1024, 4, now);
        let mut actions = Vec::new();
        // Sustained pressure: depth 100 against a hint of 8.
        let mut t = now;
        for _ in 0..4 {
            t += OVERLOAD_HOLD + Duration::from_millis(10);
            ac.tick(t, 100, 8, &mut actions);
        }
        assert!(ac.level() >= 3, "ladder climbed: level {}", ac.level());
        assert!(actions.contains(&QosAction::GammaCap(2)), "γ halved: {actions:?}");
        assert!(actions.contains(&QosAction::ScaleUp), "autoscale asked: {actions:?}");
        // Level >= 1 sheds lowest-class arrivals at the door once a
        // backlog exists.
        let (_, reason) = ac.offer(req(0, 0), t, 600).expect_err("early shed");
        assert_eq!(reason, FailReason::QueueFull);
        assert!(ac.offer(req(2, 2), t, 600).is_ok(), "gold still admits");
        // Sustained calm walks back down and restores γ.
        actions.clear();
        for _ in 0..6 {
            t += CALM_HOLD + Duration::from_millis(10);
            ac.tick(t, 0, 8, &mut actions);
        }
        assert_eq!(ac.level(), 0);
        assert!(actions.contains(&QosAction::GammaCap(usize::MAX)), "{actions:?}");
        assert!(actions.contains(&QosAction::ScaleDown), "{actions:?}");
    }

    #[test]
    fn take_expired_sheds_parked_requests() {
        let now = Instant::now();
        let mut ac = AdmissionController::new(spec3(), 1024, 0, now);
        ac.offer(req(2, 2), now, 0).unwrap(); // gold: 1500 ms SLO
        ac.offer(req(0, 0), now, 0).unwrap(); // free: no deadline
        let mut expired = Vec::new();
        ac.take_expired(now + Duration::from_secs(2), &mut expired);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].tenant, 2);
        assert_eq!(ac.queued(), 1, "deadline-free request still parked");
    }
}
