//! Training/eval metrics: loss curves, accuracies, EM/F1, latency, with
//! JSONL logging for post-hoc analysis.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// A single logged record: step + named scalar values.
#[derive(Debug, Clone)]
pub struct Record {
    pub step: u64,
    pub values: BTreeMap<String, f64>,
}

/// How many records `MetricsLog` buffers before forcing the JSONL
/// writer to disk (`ALTUP_METRICS_FLUSH_EVERY`). Flushing every record
/// showed up in step-loop profiles once the steps themselves got cheap;
/// the tail is never lost — `Drop` flushes whatever is pending.
pub const DEFAULT_METRICS_FLUSH_EVERY: usize = 64;

/// Accumulates records, keeps moving averages, writes JSONL.
pub struct MetricsLog {
    pub records: Vec<Record>,
    file: Option<std::io::BufWriter<std::fs::File>>,
    started: Instant,
    /// Records written since the last explicit flush.
    pending: usize,
    /// Flush cadence in records (≥ 1).
    flush_every: usize,
}

impl MetricsLog {
    pub fn in_memory() -> MetricsLog {
        MetricsLog {
            records: Vec::new(),
            file: None,
            started: Instant::now(),
            pending: 0,
            flush_every: DEFAULT_METRICS_FLUSH_EVERY,
        }
    }

    pub fn to_file(path: impl AsRef<Path>) -> anyhow::Result<MetricsLog> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = std::fs::File::create(path)?;
        Ok(MetricsLog {
            records: Vec::new(),
            file: Some(std::io::BufWriter::new(file)),
            started: Instant::now(),
            pending: 0,
            flush_every: crate::util::env::usize_at_least(
                "ALTUP_METRICS_FLUSH_EVERY",
                1,
                DEFAULT_METRICS_FLUSH_EVERY,
            ),
        })
    }

    /// Override the flush cadence (tests use this instead of env vars;
    /// clamped to ≥ 1).
    pub fn set_flush_every(&mut self, every: usize) {
        self.flush_every = every.max(1);
    }

    /// Force pending JSONL records to the OS. Called automatically
    /// every `flush_every` records and on drop.
    pub fn flush(&mut self) {
        if let Some(f) = &mut self.file {
            let _ = f.flush();
        }
        self.pending = 0;
    }

    pub fn log(&mut self, step: u64, pairs: &[(&str, f64)]) {
        let mut values = BTreeMap::new();
        for (k, v) in pairs {
            values.insert(k.to_string(), *v);
        }
        values.insert("wall_seconds".into(), self.started.elapsed().as_secs_f64());
        let rec = Record { step, values };
        if let Some(f) = &mut self.file {
            let mut obj = BTreeMap::new();
            obj.insert("step".to_string(), Json::Num(step as f64));
            for (k, v) in &rec.values {
                obj.insert(k.clone(), Json::Num(*v));
            }
            let _ = writeln!(f, "{}", Json::Obj(obj));
            self.pending += 1;
            if self.pending >= self.flush_every {
                self.flush();
            }
        }
        self.records.push(rec);
    }

    /// Mean of a metric over the last `n` records that contain it.
    pub fn recent_mean(&self, key: &str, n: usize) -> Option<f64> {
        let vals: Vec<f64> = self
            .records
            .iter()
            .rev()
            .filter_map(|r| r.values.get(key).copied())
            .take(n)
            .collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    pub fn last(&self, key: &str) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.values.get(key).copied())
    }

    /// (step, value) series for plotting/reporting.
    pub fn series(&self, key: &str) -> Vec<(u64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.values.get(key).map(|v| (r.step, *v)))
            .collect()
    }
}

impl Drop for MetricsLog {
    fn drop(&mut self) {
        // Batched flushing must not cost the tail of a run: whatever
        // the cadence left buffered goes out with the log.
        self.flush();
    }
}

/// Aggregated evaluation result.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub em: f64,
    pub f1: f64,
    pub examples: usize,
}

impl EvalResult {
    pub fn summary(&self) -> String {
        format!(
            "loss={:.4} acc={:.2}% em={:.2} f1={:.2} (n={})",
            self.loss,
            self.accuracy * 100.0,
            self.em * 100.0,
            self.f1 * 100.0,
            self.examples
        )
    }
}

/// Reciprocal square-root LR schedule with warmup (paper App. A),
/// mirroring `python/compile/train.py::lr_schedule`.
pub fn rsqrt_lr(step: u64, warmup: u64, base: f64) -> f64 {
    base / (step.max(warmup) as f64).sqrt()
}

const LAT_SUB: usize = 8; // sub-buckets per octave (~9% relative error)
const LAT_BUCKETS: usize = 8 * 30; // 1 us .. ~18 min
const LAT_MIN_MS: f64 = 0.001;

/// Fixed-size log-bucketed latency histogram: O(1) memory no matter
/// how many requests a server lives through, mergeable across
/// replicas, with p50/p95/p99 read off the cumulative counts (bucket
/// width 2^(1/8), so estimates carry <~9% relative error — plenty for
/// serving percentiles).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { counts: vec![0; LAT_BUCKETS], total: 0 }
    }

    fn bucket(ms: f64) -> usize {
        if !(ms > LAT_MIN_MS) {
            return 0; // also catches NaN / negatives
        }
        let idx = ((ms / LAT_MIN_MS).log2() * LAT_SUB as f64).floor() as usize;
        idx.min(LAT_BUCKETS - 1)
    }

    /// Geometric midpoint of a bucket, in ms.
    fn value(idx: usize) -> f64 {
        LAT_MIN_MS * 2f64.powf((idx as f64 + 0.5) / LAT_SUB as f64)
    }

    pub fn record(&mut self, ms: f64) {
        self.counts[Self::bucket(ms)] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.total += other.total;
    }

    /// Mean over the bucketed samples (bucket-midpoint approximation,
    /// same <~9% relative error as the percentiles).
    pub fn mean_ms(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .counts
            .iter()
            .enumerate()
            .map(|(i, &c)| c as f64 * Self::value(i))
            .sum();
        sum / self.total as f64
    }

    /// Nearest-rank percentile (0..=100) over the bucketed samples.
    /// Empty histograms report 0.0 (never NaN); a non-finite `p` is
    /// treated as 0.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let p = if p.is_finite() { p } else { 0.0 };
        let rank = ((p / 100.0).clamp(0.0, 1.0) * (self.total as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return Self::value(i);
            }
        }
        Self::value(LAT_BUCKETS - 1)
    }

    /// §L13 satellite: export the non-empty buckets as (upper edge,
    /// count) pairs — the fixed-bucket wire format external dashboards
    /// consume. Upper edges are the exact bucket boundaries
    /// (`LAT_MIN_MS · 2^((i+1)/8)`), so any consumer can reconstruct
    /// percentiles to within one bucket width of this histogram's own
    /// estimate (pinned by a property test below).
    pub fn to_buckets(&self) -> Vec<LatencyBucket> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| LatencyBucket {
                upper_ms: LAT_MIN_MS * 2f64.powf((i as f64 + 1.0) / LAT_SUB as f64),
                count: c,
            })
            .collect()
    }

    /// Nearest-rank percentile recomputed from an exported bucket list
    /// (the consumer-side half of the `to_buckets` contract). Each
    /// bucket contributes at its upper edge; an empty export reports
    /// 0.0 like the histogram itself.
    pub fn percentile_from_buckets(buckets: &[LatencyBucket], p: f64) -> f64 {
        let total: u64 = buckets.iter().map(|b| b.count).sum();
        if total == 0 {
            return 0.0;
        }
        let p = if p.is_finite() { p } else { 0.0 };
        let rank = ((p / 100.0).clamp(0.0, 1.0) * (total as f64 - 1.0)).round() as u64;
        let mut seen = 0u64;
        for b in buckets {
            seen += b.count;
            if seen > rank {
                return b.upper_ms;
            }
        }
        buckets.last().map_or(0.0, |b| b.upper_ms)
    }
}

/// One exported histogram bucket: everything counted here measured
/// `<= upper_ms` (and above the previous bucket's edge).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBucket {
    pub upper_ms: f64,
    pub count: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Slot-occupancy meter for the continuous-batching decode loop: one
/// sample per fused `decode_token` iteration recording how many of the
/// replica's slots held a live request. Mean occupancy is the
/// scheduler-health number (occupancy near the slot count means the
/// admission path keeps the device fed; low occupancy means decode
/// iterations run mostly-empty geometry). Mergeable across replicas
/// like `LatencyHistogram`.
#[derive(Debug, Clone, Default)]
pub struct OccupancyMeter {
    live_sum: u64,
    steps: u64,
}

impl OccupancyMeter {
    /// Record one decode iteration that ran with `live` occupied slots.
    pub fn record(&mut self, live: usize) {
        self.live_sum += live as u64;
        self.steps += 1;
    }

    /// Number of decode iterations recorded.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Mean live slots per decode iteration.
    pub fn mean(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.live_sum as f64 / self.steps as f64
        }
    }

    /// Mean occupancy as a fraction of `slots`.
    pub fn utilization(&self, slots: usize) -> f64 {
        if slots == 0 {
            0.0
        } else {
            self.mean() / slots as f64
        }
    }

    pub fn merge(&mut self, other: &OccupancyMeter) {
        self.live_sum += other.live_sum;
        self.steps += other.steps;
    }
}

/// Speculative-decoding counters (§L8): drafted-vs-accepted tokens,
/// draft/verify step counts, and the tokens the spec path actually
/// delivered. Mergeable across replicas like the other serving meters.
///
/// - `acceptance_rate` = accepted / drafted — the draft model's
///   quality number (cf. the AltUp predictor's correction frequency);
///   counts RAW accepted prefixes, before EOS/dec_len truncation.
/// - `tokens_per_verify` = delivered tokens / fused verify steps,
///   summed over ALL live slots per round — an occupancy-confounded
///   aggregate. Divide by mean occupancy for the per-slot value, which
///   is bounded by γ+1 and is what plain decode holds at exactly 1.0
///   (so at occupancy O, plain decode's same aggregate would read O).
#[derive(Debug, Clone, Default)]
pub struct SpecMeter {
    /// Draft tokens proposed (γ per live slot per verify round).
    pub drafted: u64,
    /// Drafted tokens the fused verify accepted (longest matching
    /// prefix, before host-side EOS/dec_len truncation).
    pub accepted: u64,
    /// Draft-model decode steps executed (γ per round).
    pub draft_steps: u64,
    /// Fused full-model verify executions.
    pub verify_steps: u64,
    /// Tokens delivered to clients through the spec path (accepted
    /// prefix + correction, EOS/dec_len-truncated).
    pub spec_tokens: u64,
}

impl SpecMeter {
    /// Fraction of drafted tokens the full model accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Delivered tokens per fused verify step, summed over all live
    /// slots (per-slot value = this / mean occupancy; plain decode's
    /// per-slot value is 1.0).
    pub fn tokens_per_verify(&self) -> f64 {
        if self.verify_steps == 0 {
            0.0
        } else {
            self.spec_tokens as f64 / self.verify_steps as f64
        }
    }

    /// Record `n` tokens actually delivered to a client through the
    /// spec path. The draft/verify counters are filled by
    /// `SpecDecoder::round`; the delivered count is the one half the
    /// round cannot know — EOS/`dec_len` truncation happens in the
    /// serving loop — so the caller MUST report it here (next to slot
    /// retirement) or `tokens_per_verify` reads 0.
    pub fn note_delivered(&mut self, n: u64) {
        self.spec_tokens += n;
    }

    /// Whether any speculative round ran (summary/JSON gating).
    pub fn active(&self) -> bool {
        self.verify_steps > 0
    }

    pub fn merge(&mut self, other: &SpecMeter) {
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.draft_steps += other.draft_steps;
        self.verify_steps += other.verify_steps;
        self.spec_tokens += other.spec_tokens;
    }
}

/// Paged decode-state pool counters (§L9): page occupancy, prefix-
/// cache effectiveness, and the allocator's pressure signals.
/// Mergeable across replicas like the other serving meters — pools are
/// per-replica, so capacities/peaks merge as max (a fleet of equal
/// replicas reports one pool's geometry) while the occupancy samples
/// and event counters sum.
#[derive(Debug, Clone, Default)]
pub struct PoolMeter {
    /// Pages in one replica's pool (0 = paged serving inactive).
    pub capacity: usize,
    /// Sum of used-page samples (one per decode iteration).
    pub used_sum: u64,
    /// Number of occupancy samples taken.
    pub samples: u64,
    /// Most pages ever in use at once on any replica.
    pub peak_used: usize,
    /// Most live slots any replica sustained at once — the paged
    /// path's slots-per-replica headline (monolithic slots cap this at
    /// memory/slot_bytes; paging caps it at what the pool covers).
    pub peak_live_slots: usize,
    /// Full prompt chunks served from the prefix cache.
    pub prefix_hits: u64,
    /// Full prompt chunks probed against the prefix cache.
    pub prefix_lookups: u64,
    /// Prompt tokens whose prefill was skipped via prefix hits.
    pub prefill_tokens_saved: u64,
    /// Unpinned prefix pages evicted under pool pressure.
    pub evictions: u64,
    /// Admission passes that stalled because eviction could not free
    /// enough pages (the request stays queued, not shed).
    pub alloc_stalls: u64,
}

impl PoolMeter {
    /// Whether a paged pool served anything (summary/JSON gating).
    pub fn active(&self) -> bool {
        self.capacity > 0
    }

    /// Sample pool state after one decode iteration.
    pub fn record(&mut self, used_pages: usize, live_slots: usize) {
        self.used_sum += used_pages as u64;
        self.samples += 1;
        self.peak_used = self.peak_used.max(used_pages);
        self.peak_live_slots = self.peak_live_slots.max(live_slots);
    }

    /// Mean pages in use per decode iteration.
    pub fn mean_used(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.used_sum as f64 / self.samples as f64
        }
    }

    /// Mean page occupancy as a fraction of capacity.
    pub fn utilization(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.mean_used() / self.capacity as f64
        }
    }

    /// Fraction of probed prompt chunks served from the prefix cache.
    pub fn hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    pub fn merge(&mut self, other: &PoolMeter) {
        self.capacity = self.capacity.max(other.capacity);
        self.used_sum += other.used_sum;
        self.samples += other.samples;
        self.peak_used = self.peak_used.max(other.peak_used);
        self.peak_live_slots = self.peak_live_slots.max(other.peak_live_slots);
        self.prefix_hits += other.prefix_hits;
        self.prefix_lookups += other.prefix_lookups;
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.evictions += other.evictions;
        self.alloc_stalls += other.alloc_stalls;
    }
}

/// §L10 per-tenant QoS counters: completions, sheds, SLO attainment,
/// and a per-tenant latency histogram, indexed by `Request::tenant` in
/// `ServerStats::tenants`. Mergeable across replicas like the other
/// serving meters; names/SLOs live in the server config, not here.
#[derive(Debug, Clone, Default)]
pub struct TenantMeter {
    /// Requests answered with tokens.
    pub requests: u64,
    /// Explicit terminal failures (all reasons).
    pub failed: u64,
    /// Subset of `failed` shed by QoS/deadline machinery
    /// (`DeadlineExceeded`, `QueueFull`, `WouldMissDeadline`).
    pub sheds: u64,
    /// Completions within the tenant's SLO (== `requests` when the
    /// tenant has no SLO) — the goodput numerator.
    pub slo_hits: u64,
    /// Decoded tokens delivered to this tenant.
    pub tokens_generated: u64,
    /// Per-request latency for this tenant's completions.
    pub latency: LatencyHistogram,
}

impl TenantMeter {
    /// Whether this tenant saw any traffic (summary/JSON gating).
    pub fn active(&self) -> bool {
        self.requests + self.failed > 0
    }

    /// Record one completion. `slo_ms` 0 means no SLO: every
    /// completion counts as goodput.
    pub fn note_done(&mut self, latency_ms: f64, tokens: usize, slo_ms: u64) {
        self.requests += 1;
        self.tokens_generated += tokens as u64;
        self.latency.record(latency_ms);
        if slo_ms == 0 || latency_ms <= slo_ms as f64 {
            self.slo_hits += 1;
        }
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency.percentile_ms(50.0)
    }

    pub fn p95_ms(&self) -> f64 {
        self.latency.percentile_ms(95.0)
    }

    /// Fraction of this tenant's terminal outcomes that met the SLO —
    /// the per-tenant goodput ratio (sheds and failures count against).
    pub fn goodput_ratio(&self) -> f64 {
        let total = self.requests + self.failed;
        if total == 0 {
            0.0
        } else {
            self.slo_hits as f64 / total as f64
        }
    }

    pub fn merge(&mut self, other: &TenantMeter) {
        self.requests += other.requests;
        self.failed += other.failed;
        self.sheds += other.sheds;
        self.slo_hits += other.slo_hits;
        self.tokens_generated += other.tokens_generated;
        self.latency.merge(&other.latency);
    }
}

/// §L11 per-version deployment accounting: one `TenantMeter` row per
/// artifact version (index = version number, 0 = the version the
/// server started on) plus rollout verdict counters. Like the tenant
/// table, the version rows partition the global counters — every
/// completion and every explicit failure lands in exactly one version
/// row, so `sum(versions[i].requests) == ServerStats::requests` and
/// `sum(versions[i].failed) == ServerStats::failed` hold across swaps,
/// crashes, and rollbacks (pinned by tests and the bench harness).
#[derive(Debug, Clone, Default)]
pub struct DeployMeter {
    /// Per-version completion/failure rows, indexed by version number
    /// (grown on demand like `ServerStats::tenants`).
    pub versions: Vec<TenantMeter>,
    /// The version this meter's owner attributes new work to: a
    /// replica's artifact version, or (router-side) the rollout's
    /// decided version. A tag, not a counter — `merge` keeps the
    /// aggregate's own value.
    pub current: u32,
    /// Canaries that passed their probe + probation gate.
    pub canary_pass: u64,
    /// Canaries that failed a gate (probe mismatch, error rate,
    /// latency, or a crash during probation).
    pub canary_fail: u64,
    /// Automatic rollbacks executed (the failed replica reloaded the
    /// old version).
    pub rollbacks: u64,
    /// Rollouts that promoted every replica.
    pub completed: u64,
    /// Rollouts aborted by `shutdown()` mid-flight.
    pub aborted: u64,
}

impl DeployMeter {
    /// Whether any rollout activity (or multi-version traffic) exists —
    /// summary/JSON gating, like the other serving meters.
    pub fn active(&self) -> bool {
        self.canary_pass + self.canary_fail + self.rollbacks + self.completed + self.aborted > 0
            || self.versions.len() > 1
    }

    /// The row for version `v`, growing the table on first touch.
    pub fn version_mut(&mut self, v: u32) -> &mut TenantMeter {
        let v = v as usize;
        if self.versions.len() <= v {
            self.versions.resize_with(v + 1, TenantMeter::default);
        }
        &mut self.versions[v]
    }

    /// Record one completion against the owner's current version.
    /// Version rows carry no SLO — `slo_hits` mirrors `requests`.
    pub fn note_done(&mut self, latency_ms: f64, tokens: usize) {
        let v = self.current;
        self.version_mut(v).note_done(latency_ms, tokens, 0);
    }

    /// Record one explicit terminal failure against the owner's
    /// current version (`shed` mirrors the global sheds subset).
    pub fn note_failed(&mut self, shed: bool) {
        let v = self.current;
        let row = self.version_mut(v);
        row.failed += 1;
        if shed {
            row.sheds += 1;
        }
    }

    /// Requests completed on version `v` (0 when the row never grew).
    pub fn version_requests(&self, v: u32) -> u64 {
        self.versions.get(v as usize).map_or(0, |m| m.requests)
    }

    pub fn merge(&mut self, other: &DeployMeter) {
        for (v, row) in other.versions.iter().enumerate() {
            self.version_mut(v as u32).merge(row);
        }
        self.canary_pass += other.canary_pass;
        self.canary_fail += other.canary_fail;
        self.rollbacks += other.rollbacks;
        self.completed += other.completed;
        self.aborted += other.aborted;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_and_query() {
        let mut m = MetricsLog::in_memory();
        for s in 1..=10 {
            m.log(s, &[("loss", 10.0 / s as f64)]);
        }
        assert_eq!(m.records.len(), 10);
        assert!((m.last("loss").unwrap() - 1.0).abs() < 1e-9);
        let mean5 = m.recent_mean("loss", 5).unwrap();
        assert!(mean5 < 2.0);
        assert_eq!(m.series("loss").len(), 10);
    }

    #[test]
    fn jsonl_roundtrip() {
        let path = std::env::temp_dir().join(format!("altup-metrics-{}.jsonl", std::process::id()));
        {
            let mut m = MetricsLog::to_file(&path).unwrap();
            m.log(1, &[("loss", 3.5), ("acc", 0.25)]);
            m.log(2, &[("loss", 3.0)]);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let rec = Json::parse(lines[0]).unwrap();
        assert_eq!(rec.get("loss").as_f64(), Some(3.5));
        assert_eq!(rec.get("step").as_i64(), Some(1));
        std::fs::remove_file(path).unwrap();
    }

    /// §L13 satellite: batched flushing must never lose the tail —
    /// records buffered past the last cadence boundary hit the disk
    /// when the log drops, and an explicit `flush()` makes them
    /// readable mid-run.
    #[test]
    fn metrics_log_batched_flush_persists_tail_on_drop() {
        let path =
            std::env::temp_dir().join(format!("altup-metrics-flush-{}.jsonl", std::process::id()));
        {
            let mut m = MetricsLog::to_file(&path).unwrap();
            // Cadence far above the record count: nothing below forces
            // a flush on its own.
            m.set_flush_every(1000);
            for s in 1..=5 {
                m.log(s, &[("loss", 1.0 / s as f64)]);
            }
            // Mid-run visibility: an explicit flush surfaces what the
            // cadence is still holding.
            m.flush();
            let mid = std::fs::read_to_string(&path).unwrap();
            assert_eq!(mid.lines().count(), 5, "explicit flush must persist pending records");
            // Three more buffered records ride on Drop alone.
            for s in 6..=8 {
                m.log(s, &[("loss", 1.0 / s as f64)]);
            }
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 8, "drop must persist the buffered tail");
        let last = Json::parse(lines[7]).unwrap();
        assert_eq!(last.get("step").as_i64(), Some(8));
        std::fs::remove_file(path).unwrap();
    }

    /// §L13 satellite: the cadence itself flushes without help — once
    /// `flush_every` records accumulate they are readable while the
    /// log is still live.
    #[test]
    fn metrics_log_flush_cadence_triggers() {
        let path =
            std::env::temp_dir().join(format!("altup-metrics-cad-{}.jsonl", std::process::id()));
        let mut m = MetricsLog::to_file(&path).unwrap();
        m.set_flush_every(2);
        m.log(1, &[("a", 1.0)]);
        m.log(2, &[("a", 2.0)]);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "cadence boundary must flush");
        drop(m);
        std::fs::remove_file(path).unwrap();
    }

    /// §L13 satellite property test: percentiles reconstructed from the
    /// `to_buckets` export stay within one bucket width (a factor of
    /// 2^(1/8)) of the exact nearest-rank percentile over the raw
    /// samples, across several deterministic LCG workloads.
    #[test]
    fn percentile_from_buckets_within_one_bucket_width_of_exact() {
        for seed in [1u64, 7, 42, 1234] {
            let mut x = seed;
            let mut samples: Vec<f64> = Vec::new();
            let mut h = LatencyHistogram::new();
            for _ in 0..500 {
                // LCG over ~4 decades of latency: 0.01ms .. ~100ms.
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let u = ((x >> 11) as f64) / ((1u64 << 53) as f64);
                let ms = 0.01 * 10f64.powf(4.0 * u);
                samples.push(ms);
                h.record(ms);
            }
            let buckets = h.to_buckets();
            assert_eq!(buckets.iter().map(|b| b.count).sum::<u64>(), h.count());
            assert!(
                buckets.windows(2).all(|w| w[0].upper_ms < w[1].upper_ms),
                "bucket edges must ascend"
            );
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let width = 2f64.powf(1.0 / 8.0); // one 2^(1/8) bucket
            for p in [10.0, 50.0, 90.0, 95.0, 99.0] {
                let rank =
                    ((p / 100.0) * (samples.len() as f64 - 1.0)).round() as usize;
                let exact = samples[rank];
                let est = LatencyHistogram::percentile_from_buckets(&buckets, p);
                let ratio = est / exact;
                assert!(
                    (1.0 / width) * 0.999 <= ratio && ratio <= width * 1.001,
                    "seed {seed} p{p}: est {est} vs exact {exact} (ratio {ratio})"
                );
            }
        }
        // Empty export degrades like the histogram: 0.0, never NaN.
        assert_eq!(LatencyHistogram::percentile_from_buckets(&[], 50.0), 0.0);
    }

    #[test]
    fn latency_histogram_percentiles_and_merge() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.percentile_ms(50.0), 0.0, "empty histogram");
        for ms in [1.0f64; 90] {
            h.record(ms);
        }
        for ms in [100.0f64; 10] {
            h.record(ms);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_ms(50.0);
        assert!((p50 - 1.0).abs() / 1.0 < 0.10, "p50={p50}");
        let p99 = h.percentile_ms(99.0);
        assert!((p99 - 100.0).abs() / 100.0 < 0.10, "p99={p99}");
        assert!(h.percentile_ms(95.0) >= p50);

        let mut other = LatencyHistogram::new();
        for _ in 0..900 {
            other.record(0.5);
        }
        other.merge(&h);
        assert_eq!(other.count(), 1000);
        let p50m = other.percentile_ms(50.0);
        assert!((p50m - 0.5).abs() / 0.5 < 0.10, "merged p50={p50m}");

        // Degenerate inputs land in the floor bucket instead of panicking.
        let mut d = LatencyHistogram::new();
        d.record(0.0);
        d.record(-3.0);
        d.record(f64::NAN);
        d.record(1e12);
        assert_eq!(d.count(), 4);
        assert!(d.percentile_ms(0.0) > 0.0);
    }

    /// Empty histograms must report 0.0 everywhere (never NaN), and
    /// `merge` must be idempotent-safe on disjoint stats: merge order
    /// doesn't matter, merging an empty histogram is a no-op, and
    /// counts/percentiles stay consistent across repeated merges.
    #[test]
    fn latency_histogram_empty_and_disjoint_merge_safety() {
        let empty = LatencyHistogram::new();
        for p in [0.0, 50.0, 95.0, 99.0, 100.0, f64::NAN, f64::INFINITY] {
            let v = empty.percentile_ms(p);
            assert_eq!(v, 0.0, "empty percentile({p}) must be 0.0, got {v}");
            assert!(!v.is_nan());
        }
        assert_eq!(empty.mean_ms(), 0.0);
        // NaN p on a non-empty histogram degrades to p=0, not NaN.
        let mut one = LatencyHistogram::new();
        one.record(5.0);
        assert!(!one.percentile_ms(f64::NAN).is_nan());

        // Disjoint stats: a holds only ~1ms samples, b only ~64ms.
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..10 {
            a.record(1.0);
            b.record(64.0);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.count(), 20);
        assert_eq!(ba.count(), 20);
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(ab.percentile_ms(p), ba.percentile_ms(p), "merge order at p={p}");
        }
        // The merged extremes are the original populations' values.
        assert!((ab.percentile_ms(0.0) - 1.0).abs() / 1.0 < 0.10);
        assert!((ab.percentile_ms(100.0) - 64.0).abs() / 64.0 < 0.10);

        // Merging an empty histogram is a no-op.
        let before = (ab.count(), ab.percentile_ms(50.0), ab.mean_ms());
        ab.merge(&LatencyHistogram::new());
        assert_eq!(before, (ab.count(), ab.percentile_ms(50.0), ab.mean_ms()));

        // Repeated disjoint merges keep counts exact and percentiles
        // inside the union's range (no drift, no NaN).
        ab.merge(&b);
        assert_eq!(ab.count(), 30);
        let p50 = ab.percentile_ms(50.0);
        assert!((0.9..=70.4).contains(&p50), "p50={p50}");
    }

    #[test]
    fn latency_histogram_mean() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.mean_ms(), 0.0);
        for _ in 0..50 {
            h.record(2.0);
        }
        for _ in 0..50 {
            h.record(4.0);
        }
        let mean = h.mean_ms();
        assert!((mean - 3.0).abs() / 3.0 < 0.10, "mean={mean}");
    }

    #[test]
    fn occupancy_meter_records_and_merges() {
        let mut o = OccupancyMeter::default();
        assert_eq!(o.mean(), 0.0);
        assert_eq!(o.utilization(8), 0.0);
        o.record(8);
        o.record(4);
        assert_eq!(o.steps(), 2);
        assert!((o.mean() - 6.0).abs() < 1e-12);
        assert!((o.utilization(8) - 0.75).abs() < 1e-12);
        let mut other = OccupancyMeter::default();
        other.record(2);
        other.merge(&o);
        assert_eq!(other.steps(), 3);
        assert!((other.mean() - 14.0 / 3.0).abs() < 1e-12);
        assert_eq!(other.utilization(0), 0.0);
    }

    #[test]
    fn spec_meter_rates_and_merge() {
        let empty = SpecMeter::default();
        assert!(!empty.active());
        assert_eq!(empty.acceptance_rate(), 0.0, "no NaN on empty");
        assert_eq!(empty.tokens_per_verify(), 0.0);

        let mut a = SpecMeter {
            drafted: 40,
            accepted: 30,
            draft_steps: 40,
            verify_steps: 10,
            spec_tokens: 38,
        };
        assert!(a.active());
        assert!((a.acceptance_rate() - 0.75).abs() < 1e-12);
        assert!((a.tokens_per_verify() - 3.8).abs() < 1e-12);

        let b = SpecMeter {
            drafted: 10,
            accepted: 0,
            draft_steps: 10,
            verify_steps: 5,
            spec_tokens: 5,
        };
        a.merge(&b);
        assert_eq!(a.drafted, 50);
        assert_eq!(a.accepted, 30);
        assert_eq!(a.draft_steps, 50);
        assert_eq!(a.verify_steps, 15);
        assert_eq!(a.spec_tokens, 43);
        assert!((a.acceptance_rate() - 0.6).abs() < 1e-12);
        // Reject-all alone still delivers 1 correction per verify.
        assert!((b.tokens_per_verify() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pool_meter_rates_and_merge() {
        let empty = PoolMeter::default();
        assert!(!empty.active());
        assert_eq!(empty.mean_used(), 0.0, "no NaN on empty");
        assert_eq!(empty.utilization(), 0.0);
        assert_eq!(empty.hit_rate(), 0.0);

        let mut a = PoolMeter { capacity: 40, ..PoolMeter::default() };
        assert!(a.active());
        a.record(10, 3);
        a.record(30, 5);
        a.prefix_lookups = 8;
        a.prefix_hits = 6;
        a.prefill_tokens_saved = 96;
        assert!((a.mean_used() - 20.0).abs() < 1e-12);
        assert!((a.utilization() - 0.5).abs() < 1e-12);
        assert!((a.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(a.peak_used, 30);
        assert_eq!(a.peak_live_slots, 5);

        // Merge: per-replica geometry as max, samples/events as sums.
        let mut b = PoolMeter { capacity: 40, ..PoolMeter::default() };
        b.record(40, 8);
        b.prefix_lookups = 2;
        b.evictions = 3;
        b.alloc_stalls = 1;
        a.merge(&b);
        assert_eq!(a.capacity, 40);
        assert_eq!(a.samples, 3);
        assert_eq!(a.peak_used, 40);
        assert_eq!(a.peak_live_slots, 8);
        assert_eq!(a.prefix_lookups, 10);
        assert!((a.hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(a.evictions, 3);
        assert_eq!(a.alloc_stalls, 1);
        assert!((a.mean_used() - 80.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lr_schedule_matches_python() {
        assert!((rsqrt_lr(1, 100, 1.0) - 0.1).abs() < 1e-12);
        assert!((rsqrt_lr(100, 100, 1.0) - 0.1).abs() < 1e-12);
        assert!((rsqrt_lr(400, 100, 1.0) - 0.05).abs() < 1e-12);
    }

    /// §L11: per-version rows grow on demand, completions/failures land
    /// on the owner's `current` tag, and `merge` sums rows + verdict
    /// counters while keeping the aggregate's own `current`.
    #[test]
    fn deploy_meter_versions_and_merge() {
        let empty = DeployMeter::default();
        assert!(!empty.active(), "no rollout activity yet");

        // A replica still on version 0.
        let mut old = DeployMeter::default();
        old.note_done(10.0, 4);
        old.note_done(20.0, 6);
        old.note_failed(true);
        assert_eq!(old.version_requests(0), 2);
        assert_eq!(old.versions[0].failed, 1);
        assert_eq!(old.versions[0].sheds, 1);
        assert!(!old.active(), "single-version traffic alone is not a rollout");

        // A swapped replica serving version 1.
        let mut new = DeployMeter { current: 1, ..DeployMeter::default() };
        new.note_done(15.0, 5);
        new.note_failed(false);
        new.canary_pass = 1;
        assert_eq!(new.version_requests(0), 0, "row 0 grew but stayed empty");
        assert_eq!(new.version_requests(1), 1);
        assert!(new.active());

        let mut agg = DeployMeter::default();
        agg.merge(&old);
        agg.merge(&new);
        assert_eq!(agg.current, 0, "merge keeps the aggregate's tag");
        assert_eq!(agg.version_requests(0), 2);
        assert_eq!(agg.version_requests(1), 1);
        assert_eq!(agg.versions[1].failed, 1);
        assert_eq!(agg.versions[1].sheds, 0);
        assert_eq!(agg.canary_pass, 1);
        // Partition-of-global: version rows sum to the totals.
        let total_req: u64 = agg.versions.iter().map(|m| m.requests).sum();
        let total_failed: u64 = agg.versions.iter().map(|m| m.failed).sum();
        assert_eq!(total_req, 3);
        assert_eq!(total_failed, 2);
    }
}
