//! The router/supervisor side of the serving stack: replica
//! lifecycle bookkeeping (`Supervisor`), deadline shedding, and the
//! `route` loop that owns admission, QoS, flushing, supervision, and
//! drain. Split out of the old monolithic `coordinator/server.rs` —
//! paths are preserved via re-exports in `server/mod.rs`.

use super::*;

/// The supervisor's replica bookkeeping: what it needs to respawn a
/// replacement (specs by version, options, the shared job queue, the
/// event channel) plus the live count and restart budget. `pub(crate)`
/// so the §L11 rollout driver (coordinator/deploy.rs) can drive
/// targeted drains and version-pinned spawns through it.
pub(crate) struct Supervisor {
    /// Engine spec per artifact version; version 0 is the spec the
    /// server booted on, each §L11 rollout registers the next.
    pub(crate) specs: BTreeMap<u32, EngineSpec>,
    /// §L11: the version every *new* spawn (crash respawn, autoscale,
    /// rollout replacement) lands on. Starts at 0, flips to the new
    /// version when a rollout's first canary passes, reverts on
    /// rollback.
    pub(crate) decided: u32,
    /// §L11: which version each live replica id is serving (ids are
    /// never reused; entries are removed on exit).
    pub(crate) versions: HashMap<usize, u32>,
    /// §L12: tensor-parallel width of each live fleet unit (1 = plain
    /// whole-model replica, >=2 = ShardGroup of that many shards).
    /// Tracked here so respawns, rollout replacements, and device
    /// accounting preserve the fleet's heterogeneous shape — a crashed
    /// TP group must come back as a TP group, not a lone replica.
    pub(crate) shapes: HashMap<usize, usize>,
    pub(crate) opts: ServerOptions,
    pub(crate) jobs: Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    pub(crate) events_tx: mpsc::Sender<ReplicaExit>,
    pub(crate) handles: Vec<std::thread::JoinHandle<()>>,
    pub(crate) live: usize,
    pub(crate) restarts_left: usize,
    pub(crate) next_id: usize,
    pub(crate) last_error: Option<String>,
    /// Set when the fleet died while admissions were still open (last
    /// crash with the job queue open and no restart budget left) —
    /// recorded at event-processing time, so `shutdown()` reports it
    /// deterministically no matter how the client disconnect races
    /// the exit events.
    pub(crate) died: Option<String>,
    /// §L10 satellite: respawns scheduled but not yet due. Replacing
    /// the old spawn-on-crash with a backoff queue means a poison-pill
    /// artifact burns the restart budget over seconds, not
    /// milliseconds — `tick_respawns` drains this from the router
    /// loop. A non-empty queue counts as "fleet coming back" for the
    /// died/NoReplicas checks. §L12: each entry carries the exited
    /// unit's TP shape so the replacement has the same footprint.
    pub(crate) pending_respawns: Vec<(Instant, usize)>,
    /// Crashes that consumed restart budget — the backoff exponent.
    pub(crate) crashes: u32,
    /// §L10/§L11: the degradation + rollout levers handed to every
    /// replica this supervisor spawns (respawns and autoscale replicas
    /// included).
    pub(crate) shared: Arc<QosShared>,
}

impl Supervisor {
    /// Fold a replica exit into the aggregate: merge its stats, requeue
    /// or explicitly fail its in-flight requests, and respawn a
    /// replacement when it crashed and the budget allows. `job_open`
    /// is whether the job queue can still carry requeued work (false
    /// once the drain has closed it). `allow_respawn` is false when the
    /// §L11 rollout driver already owns this exit (it spawned the
    /// replacement itself — no restart budget is spent and a rollout
    /// lifecycle exit can never be mistaken for fleet death).
    pub(crate) fn on_exit(
        &mut self,
        ev: ReplicaExit,
        stats: &mut ServerStats,
        groups: &mut BTreeMap<usize, Vec<Admitted>>,
        job_open: bool,
        allow_respawn: bool,
    ) {
        self.live = self.live.saturating_sub(1);
        self.versions.remove(&ev.id);
        // §L12: remember the exited unit's shape — a crash respawn
        // must bring back the same footprint (group stays a group).
        let shape = self.shapes.remove(&ev.id).unwrap_or(1);
        stats.merge(&ev.stats);
        let crashed = ev.error.is_some();
        if let Some(err) = ev.error {
            self.last_error = Some(format!("replica {}: {}", ev.id, err));
        }
        for held in ev.unfinished {
            let attempts = held.attempts + 1;
            if !job_open {
                fail_request(stats, &held.req, FailReason::AbortedOnDrain, ROUTER_ID);
            } else if attempts > self.opts.max_retries {
                fail_request(stats, &held.req, FailReason::RetriesExhausted, ROUTER_ID);
            } else {
                stats.retries += 1;
                groups.entry(held.bucket).or_default().push(Admitted {
                    req: held.req,
                    admitted: Instant::now(),
                    attempts,
                });
            }
        }
        if crashed && allow_respawn && job_open && self.restarts_left > 0 {
            // §L10 satellite: schedule the replacement behind an
            // exponential backoff instead of spawning it here — a
            // persistently-failing artifact must not crash-loop
            // through its whole restart budget in one supervision
            // pass.
            self.restarts_left -= 1;
            let delay = self.backoff_delay();
            self.crashes += 1;
            self.pending_respawns.push((Instant::now() + delay, shape));
        }
        if crashed
            && allow_respawn
            && job_open
            && self.live == 0
            && self.pending_respawns.is_empty()
            && self.died.is_none()
        {
            self.died = Some(
                self.last_error.clone().unwrap_or_else(|| "replica crash".to_string()),
            );
        }
    }

    /// Exponential backoff with deterministic jitter for the next
    /// respawn: `restart_backoff_ms * 2^crashes` (exponent capped at
    /// 6), jittered into [0.75, 1.25) of nominal so a fleet of
    /// supervisors does not thundering-herd its restarts.
    pub(crate) fn backoff_delay(&self) -> Duration {
        let base = self.opts.restart_backoff_ms.max(1);
        let nominal = base.saturating_mul(1u64 << self.crashes.min(6));
        let h = sim_mix(self.opts.seed ^ 0x51C0_u64.wrapping_add(self.crashes as u64));
        let jittered = (nominal - nominal / 4).saturating_add(h % (nominal / 2 + 1));
        Duration::from_millis(jittered)
    }

    /// Spawn every scheduled respawn whose backoff has elapsed. With
    /// the job queue closed (drain) pending respawns are dropped — a
    /// replacement would only pop `Popped::Gone` and exit.
    pub(crate) fn tick_respawns(&mut self, stats: &mut ServerStats, job_open: bool) {
        if !job_open {
            self.pending_respawns.clear();
            return;
        }
        let now = Instant::now();
        let mut i = 0;
        while i < self.pending_respawns.len() {
            if self.pending_respawns[i].0 <= now {
                let (_, shape) = self.pending_respawns.swap_remove(i);
                stats.restarts += 1;
                self.spawn_shaped(self.decided, shape);
            } else {
                i += 1;
            }
        }
    }

    /// §L12: the shape a *new* fleet unit (autoscale) comes up with.
    /// Homogeneous TP fleets scale with more groups; mixed fleets add
    /// cheap whole-model replicas (a group costs `tp` devices).
    pub(crate) fn default_shape(&self) -> usize {
        if self.opts.tp >= 2 && self.opts.tp_groups >= self.opts.replicas.max(1) {
            self.opts.tp
        } else {
            1
        }
    }

    /// §L12: tensor-parallel width of a live fleet unit (1 if unknown —
    /// every non-group spawn path leaves the map untouched).
    pub(crate) fn shape_of(&self, id: usize) -> usize {
        self.shapes.get(&id).copied().unwrap_or(1)
    }

    /// Spawn one fleet unit with a fresh id (respawn or §L10
    /// autoscale) on the rollout-decided version.
    pub(crate) fn spawn_one(&mut self) {
        let v = self.decided;
        let shape = self.default_shape();
        self.spawn_shaped(v, shape);
    }

    /// §L11/§L12: spawn one fleet unit with a fresh id pinned to
    /// version `v` and TP shape `tp` (canaries and rollback
    /// replacements inherit the drained unit's shape; respawns carry
    /// the crashed unit's). Returns the new unit id.
    pub(crate) fn spawn_shaped(&mut self, v: u32, tp: usize) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        let spec = self
            .specs
            .get(&v)
            .or_else(|| self.specs.get(&self.decided))
            .expect("version spec registered")
            .clone();
        self.versions.insert(id, v);
        if tp >= 2 {
            self.shapes.insert(id, tp);
        }
        self.handles.push(spawn_replica(
            id,
            &spec,
            &self.jobs,
            &self.opts,
            &self.events_tx,
            &self.shared,
            v,
            tp,
        ));
        self.live += 1;
        id
    }

    /// §L11: the next replica a rollout to `version` should drain — the
    /// lowest-id live replica still on a different version.
    pub(crate) fn next_swap_target(&self, version: u32) -> Option<usize> {
        self.versions.iter().filter(|&(_, &v)| v != version).map(|(&id, _)| id).min()
    }

    /// Whether the fleet can still serve or come back: live replicas
    /// now, or a respawn already scheduled.
    pub(crate) fn can_serve(&self) -> bool {
        self.live > 0 || !self.pending_respawns.is_empty()
    }
}

/// §L13: record the qos-queue phase span when a traced request leaves
/// the admission layer (immediately in passthrough, or after parking in
/// a tenant queue) — the span runs router-pop → release.
fn note_qos_release(stats: &mut ServerStats, epoch: Instant, req: &Request, released: Instant) {
    if !req.traced {
        return;
    }
    let start = req.routed.unwrap_or(released);
    stats.trace.record(trace::Span {
        req: req.id,
        tenant: req.tenant as u32,
        group: u32::MAX,
        phase: trace::Phase::QosQueue,
        start_ns: trace::ns_since(epoch, start),
        end_ns: trace::ns_since(epoch, released),
        value: 0,
    });
}

/// Shed every request already past its deadline out of the router's
/// bucket groups, answering each with an explicit failure.
pub(crate) fn shed_expired(groups: &mut BTreeMap<usize, Vec<Admitted>>, stats: &mut ServerStats) {
    let now = Instant::now();
    for group in groups.values_mut() {
        group.retain(|a| {
            if a.req.expired(now) {
                fail_request(stats, &a.req, FailReason::DeadlineExceeded, ROUTER_ID);
                false
            } else {
                true
            }
        });
    }
    groups.retain(|_, g| !g.is_empty());
}

/// Router + supervisor loop (§L5 admission/bucketing + §L7 lifecycle).
///
/// Admission: group requests by bucket, ship full groups immediately
/// and window-expired partial groups best-effort, shedding anything
/// past its deadline before dispatch. Every send is a `try_send` — a
/// full queue parks the router briefly instead of blocking it, so
/// supervision (replica exits, requeues, respawns) is never starved.
///
/// Supervision: replica exit events are folded in every pass; crashed
/// replicas' in-flight requests are requeued (bounded per-request
/// retries) and replacements respawned within the restart budget. With
/// no live replicas and no budget left the router answers every
/// request with an explicit failure until clients hang up, then
/// reports the crash from `shutdown()`.
///
/// Drain: once every client sender is gone, remaining groups flush,
/// the job queue closes (replicas retire in-flight slots and exit),
/// exit events are collected, and all threads are joined.
#[allow(clippy::too_many_arguments)]
pub(crate) fn route(
    spec: &EngineSpec,
    rx: mpsc::Receiver<Request>,
    job_tx: mpsc::SyncSender<BatchJob>,
    job_rx: Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    events_rx: mpsc::Receiver<ReplicaExit>,
    events_tx: mpsc::Sender<ReplicaExit>,
    opts: &ServerOptions,
    handles: Vec<std::thread::JoinHandle<()>>,
    shared: Arc<QosShared>,
    deploy_ctl: Arc<DeployControl>,
) -> Result<ServerStats> {
    let mut sup = Supervisor {
        specs: BTreeMap::from([(0u32, spec.clone())]),
        decided: 0,
        versions: (0..handles.len()).map(|i| (i, 0u32)).collect(),
        // §L12: the initial fleet's shape map mirrors spawn_engine's
        // unit_tp split (ids 0..n in spawn order).
        shapes: (0..handles.len())
            .filter(|&i| opts.unit_tp(i) >= 2)
            .map(|i| (i, opts.unit_tp(i)))
            .collect(),
        opts: opts.clone(),
        jobs: job_rx,
        events_tx,
        live: handles.len(),
        next_id: handles.len(),
        restarts_left: opts.replica_restarts,
        last_error: None,
        died: None,
        pending_respawns: Vec::new(),
        crashes: 0,
        shared: Arc::clone(&shared),
        handles,
    };
    let mut stats = ServerStats::default();
    let mut fatal: Option<anyhow::Error> = None;

    let (batch_size, enc_len) = match engine_dims(spec) {
        Ok(dims) => dims,
        Err(e) => {
            // Without the serving geometry nothing can be dispatched:
            // stop restarts and fail every request until clients hang
            // up. The replicas hit the same load error and exit on
            // their own.
            fatal = Some(e);
            sup.restarts_left = 0;
            (1, 1)
        }
    };
    let mut job_tx = if fatal.is_none() { Some(job_tx) } else { None };
    // §L11 rollout driver: advances the swap state machine from the
    // supervision pass and intercepts rollout-owned replica exits.
    let mut rollout = RolloutDriver::new(deploy_ctl, (batch_size, enc_len));
    let timeout = opts.request_timeout_ms.map(Duration::from_millis);
    let mut groups: BTreeMap<usize, Vec<Admitted>> = BTreeMap::new();
    let mut disconnected = false;
    // §L10 QoS admission layer. With no tenants configured it is a
    // strict passthrough: `offer` hands every request straight back
    // and the overload controller never engages.
    let mut qos = AdmissionController::new(
        opts.tenants.clone(),
        opts.queue_cap.max(1),
        opts.spec_gamma,
        Instant::now(),
    );
    // Autoscale replicas currently up (bounded by `opts.autoscale`).
    let mut extra_live: usize = 0;
    let mut qos_actions: Vec<QosAction> = Vec::new();
    // §L13 tracing: deterministic request sampler + the shared epoch
    // clock. With sampling off every hook below is skipped entirely.
    let tcfg = trace::TraceConfig::new(opts.trace_sample, opts.seed);
    let trace_on = tcfg.enabled();
    let epoch = shared.epoch;
    if trace_on {
        stats.trace.set_limits(opts.trace_ring, opts.trace_window_ms);
    }

    loop {
        // Supervision pass: fold in replica exits (requeue/fail their
        // in-flight work, respawn within budget once each backoff
        // elapses). §L11 rollout-owned exits (drain target gone ->
        // spawn canary; canary gone -> rollback) are intercepted first.
        while let Ok(ev) = events_rx.try_recv() {
            let respawn =
                rollout.observe_exit(ev.id, ev.error.is_some(), &mut sup, &mut stats);
            sup.on_exit(ev, &mut stats, &mut groups, job_tx.is_some(), respawn);
        }
        sup.tick_respawns(&mut stats, job_tx.is_some());
        // §L11: advance the rollout state machine; a server that is
        // draining or has lost its fleet aborts instead.
        if disconnected || job_tx.is_none() {
            let reason = if disconnected {
                "server shut down during the rollout"
            } else {
                "no serving fleet left for the rollout"
            };
            rollout.abort_all(&mut sup, &mut stats, reason);
        } else {
            rollout.tick(&mut sup, &mut stats);
        }
        if !sup.can_serve() {
            if fatal.is_none() {
                if let Some(err) = sup.died.take() {
                    fatal = Some(anyhow!(
                        "serving stopped: no live replicas and restart budget exhausted ({err})"
                    ));
                }
            }
            job_tx = None;
            for (_, group) in std::mem::take(&mut groups) {
                for a in group {
                    fail_request(&mut stats, &a.req, FailReason::NoReplicas, ROUTER_ID);
                }
            }
            // §L10: requests still parked in tenant queues have no
            // fleet left to wait for either.
            if qos.queued() > 0 {
                let mut parked = Vec::new();
                qos.release(qos.queued(), &mut parked);
                for req in parked {
                    fail_request(&mut stats, &req, FailReason::NoReplicas, ROUTER_ID);
                }
            }
            // Strand recovery: jobs already sitting in the queue when
            // the last replica died have no consumer left — fail them
            // explicitly instead of leaving their clients blocked.
            while let Ok(Popped::Job(job)) = pop_job(&sup.jobs, false) {
                for a in job.requests {
                    fail_request(&mut stats, &a.req, FailReason::NoReplicas, ROUTER_ID);
                }
            }
            if disconnected {
                break;
            }
        }

        // Deadline pass: shed expired requests before dispatch.
        shed_expired(&mut groups, &mut stats);

        // §L13 timeline: router-side gauges, binned into fixed windows
        // (each pass is at most one SUPERVISE_TICK apart).
        if trace_on {
            let at = trace::ns_since(epoch, Instant::now());
            let depth = qos.queued() + groups.values().map(|g| g.len()).sum::<usize>();
            stats.trace.timeline.gauge(trace::Gauge::QueueDepth, depth as f64, at);
            stats.trace.timeline.gauge(trace::Gauge::LadderLevel, qos.level() as f64, at);
        }

        // §L10 QoS pass: expire parked requests, walk the overload
        // ladder on sustained pressure, execute its degradation
        // actions, and release parked work into bucket groups in
        // weighted-priority order. No-op in passthrough mode.
        if !qos.passthrough() {
            let now = Instant::now();
            let mut expired = Vec::new();
            qos.take_expired(now, &mut expired);
            for req in &expired {
                fail_request(&mut stats, req, FailReason::DeadlineExceeded, ROUTER_ID);
            }
            let downstream: usize = groups.values().map(|g| g.len()).sum();
            qos_actions.clear();
            let level_before = qos.level();
            qos.tick(now, downstream, sup.live.max(1) * batch_size, &mut qos_actions);
            // §L13 satellite: ladder escalations/de-escalations leave a
            // timestamped trace event (`value` = the new level) — the
            // ladder moves at most one rung per tick.
            let level_after = qos.level();
            if trace_on && level_after != level_before {
                let at = trace::ns_since(epoch, now);
                stats.trace.record(trace::Span {
                    req: 0,
                    tenant: 0,
                    group: u32::MAX,
                    phase: trace::Phase::LadderLevel,
                    start_ns: at,
                    end_ns: at,
                    value: level_after as i64,
                });
            }
            for action in qos_actions.drain(..) {
                match action {
                    QosAction::GammaCap(cap) => {
                        shared.gamma_cap.store(cap, Ordering::Relaxed);
                    }
                    QosAction::ScaleUp => {
                        if extra_live < opts.autoscale && job_tx.is_some() {
                            sup.spawn_one();
                            extra_live += 1;
                            stats.scale_ups += 1;
                        }
                    }
                    QosAction::ScaleDown => {
                        if extra_live > 0 {
                            if let Some(tx) = &job_tx {
                                if tx.try_send(scale_down_job()).is_ok() {
                                    extra_live -= 1;
                                    stats.scale_downs += 1;
                                }
                            }
                        }
                    }
                }
            }
            // Release bounded to ~two waves of fleet work: the backlog
            // beyond that stays in the tenant queues, where priority
            // and SLO decisions still apply, instead of FIFO-frozen in
            // bucket groups.
            if job_tx.is_some() && sup.live > 0 {
                let room = (sup.live * batch_size * 2).saturating_sub(downstream);
                if room > 0 {
                    let mut released = Vec::new();
                    qos.release(room, &mut released);
                    let admitted = Instant::now();
                    for req in released {
                        note_qos_release(&mut stats, epoch, &req, admitted);
                        let bucket = if opts.bucketed {
                            bucket_for(req.enc_tokens.len(), enc_len)
                        } else {
                            enc_len
                        };
                        groups
                            .entry(bucket)
                            .or_default()
                            .push(Admitted { req, admitted, attempts: 0 });
                    }
                }
            }
        }

        // Flush pass. Every ship is a `try_send` (a blocking send here
        // could deadlock the supervisor against a dead replica set and
        // would starve crash handling), but the pre-L7 backpressure
        // semantics are preserved: full groups ship first — fullest
        // bucket first, in batch_size chunks — and while a full group
        // cannot ship, admission pauses (below) so clients stack up in
        // the bounded request channel exactly as the old blocking send
        // made them, and due partial groups do not steal the next
        // freed queue slot.
        let mut full_unsent = false;
        let mut due_unsent = false;
        if let Some(tx) = &job_tx {
            let now = Instant::now();
            let mut buckets: Vec<usize> = groups.keys().copied().collect();
            buckets.sort_by_key(|b| std::cmp::Reverse(groups[b].len()));
            for bucket in buckets {
                let Some(group) = groups.get(&bucket) else { continue };
                if group.len() < batch_size && !disconnected {
                    continue;
                }
                let mut requests = groups.remove(&bucket).expect("group present");
                while !requests.is_empty() {
                    let take = requests.len().min(batch_size);
                    let chunk: Vec<Admitted> = requests.drain(..take).collect();
                    match tx.try_send(BatchJob { bucket, requests: chunk }) {
                        Ok(()) => {}
                        Err(mpsc::TrySendError::Full(job))
                        | Err(mpsc::TrySendError::Disconnected(job)) => {
                            // Queue full (park and retry) or every
                            // replica receiver gone (their exit events
                            // are already on the way — the supervision
                            // pass above handles them).
                            let mut back = job.requests;
                            back.append(&mut requests);
                            groups.insert(bucket, back);
                            full_unsent = true;
                            break;
                        }
                    }
                }
                if full_unsent {
                    break; // queue full: no point probing other groups
                }
            }
            // Window-expired partial groups ship best-effort, and only
            // when no full group is still waiting for capacity.
            if !full_unsent {
                let buckets: Vec<usize> = groups.keys().copied().collect();
                for bucket in buckets {
                    let Some(group) = groups.get(&bucket) else { continue };
                    let due = group
                        .first()
                        .is_some_and(|a| now >= a.admitted + opts.batch_window);
                    if !due {
                        continue;
                    }
                    let requests = groups.remove(&bucket).expect("group present");
                    match tx.try_send(BatchJob { bucket, requests }) {
                        Ok(()) => {}
                        Err(mpsc::TrySendError::Full(job))
                        | Err(mpsc::TrySendError::Disconnected(job)) => {
                            groups.insert(bucket, job.requests);
                            due_unsent = true;
                            break;
                        }
                    }
                }
            }
        }

        // Drain: admissions closed and everything flushed — close the
        // job queue so replicas retire their slots and exit, then wait
        // for their exit events.
        if disconnected {
            // §L10: every parked request must still reach a terminal
            // response — release the lot into bucket groups while a
            // fleet exists, fail it explicitly otherwise.
            if qos.queued() > 0 {
                let mut parked = Vec::new();
                qos.release(qos.queued(), &mut parked);
                if sup.can_serve() && job_tx.is_some() {
                    let admitted = Instant::now();
                    for req in parked {
                        note_qos_release(&mut stats, epoch, &req, admitted);
                        let bucket = if opts.bucketed {
                            bucket_for(req.enc_tokens.len(), enc_len)
                        } else {
                            enc_len
                        };
                        groups
                            .entry(bucket)
                            .or_default()
                            .push(Admitted { req, admitted, attempts: 0 });
                    }
                } else {
                    for req in parked {
                        fail_request(&mut stats, &req, FailReason::NoReplicas, ROUTER_ID);
                    }
                }
                continue; // flush the freshly-released groups first
            }
            if groups.is_empty() {
                job_tx = None;
            }
            if sup.live == 0 && groups.is_empty() {
                break;
            }
            if let Ok(ev) = events_rx.recv_timeout(Duration::from_millis(50)) {
                let respawn =
                    rollout.observe_exit(ev.id, ev.error.is_some(), &mut sup, &mut stats);
                sup.on_exit(ev, &mut stats, &mut groups, job_tx.is_some(), respawn);
            }
            continue;
        }

        // Admit pass: park until the next request or group deadline,
        // capped at the supervision tick so replica exits are noticed
        // promptly.
        let wait = if full_unsent || due_unsent {
            // Floor the park so a zero batch window cannot busy-spin
            // while replicas are saturated and the job queue is full.
            opts.batch_window.max(Duration::from_micros(200))
        } else if groups.is_empty() {
            SUPERVISE_TICK
        } else {
            let oldest = groups
                .values()
                .filter_map(|g| g.first())
                .map(|a| a.admitted)
                .min()
                .expect("non-empty groups");
            (oldest + opts.batch_window).saturating_duration_since(Instant::now())
        };
        let message = if wait.is_zero() {
            None // a group came due during the flush pass
        } else if full_unsent {
            // Admission paused: a full group is waiting for queue
            // capacity. Park without draining the request channel so
            // clients feel the backpressure, then retry the flush.
            std::thread::sleep(wait.min(SUPERVISE_TICK));
            None
        } else {
            match rx.recv_timeout(wait.min(SUPERVISE_TICK)) {
                Ok(r) => Some(r),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    None
                }
            }
        };
        if let Some(mut req) = message {
            if req.deadline.is_none() {
                req.deadline = timeout.map(|t| req.t0 + t);
            }
            // §L13: sampling decision at router pop, keyed on prompt
            // content (deterministic across runs/replays), and the
            // admission-queue span — client send → this pop.
            if trace_on {
                req.traced = tcfg.sampled(trace::trace_hash(&req.enc_tokens));
                let popped = Instant::now();
                req.routed = Some(popped);
                if req.traced {
                    stats.trace.record(trace::Span {
                        req: req.id,
                        tenant: req.tenant as u32,
                        group: u32::MAX,
                        phase: trace::Phase::AdmissionQueue,
                        start_ns: trace::ns_since(epoch, req.t0),
                        end_ns: trace::ns_since(epoch, popped),
                        value: 0,
                    });
                }
            }
            // Admission-time shed comes FIRST: a request already past
            // its deadline (zero timeout, client clock skew, a long
            // stall in the bounded request channel) must never enter a
            // bucket group — and the shed is reported as the
            // deterministic `DeadlineExceeded` even when the fleet is
            // simultaneously dead.
            if req.expired(Instant::now()) {
                fail_request(&mut stats, &req, FailReason::DeadlineExceeded, ROUTER_ID);
            } else if !sup.can_serve() || job_tx.is_none() {
                fail_request(&mut stats, &req, FailReason::NoReplicas, ROUTER_ID);
            } else {
                // §L10: the admission controller rules first — rate
                // limit, early SLO shed, queue cap/preemption. In
                // passthrough mode (no tenants) it hands the request
                // straight back and admission is exactly pre-L10.
                let downstream: usize = groups.values().map(|g| g.len()).sum();
                match qos.offer(req, Instant::now(), downstream) {
                    Ok(Some(req)) => {
                        let admitted = Instant::now();
                        note_qos_release(&mut stats, epoch, &req, admitted);
                        let bucket = if opts.bucketed {
                            bucket_for(req.enc_tokens.len(), enc_len)
                        } else {
                            enc_len
                        };
                        groups
                            .entry(bucket)
                            .or_default()
                            .push(Admitted { req, admitted, attempts: 0 });
                    }
                    Ok(None) => {} // parked in a tenant queue
                    Err((victim, reason)) => {
                        fail_request(&mut stats, &victim, reason, ROUTER_ID);
                    }
                }
            }
        }
    }

    // Join every replica thread (initial + respawned replacements).
    for handle in sup.handles.drain(..) {
        let _ = handle.join();
    }
    if fatal.is_none() {
        if let Some(err) = sup.died.take() {
            fatal = Some(anyhow!(
                "serving stopped: no live replicas and restart budget exhausted ({err})"
            ));
        }
    }
    match fatal {
        Some(e) => Err(e),
        None => Ok(stats),
    }
}
