//! Multi-replica inference server: shape-bucketed batching (§Perf L5),
//! slot-based **continuous batching** (§Perf L6), and a **supervised,
//! fault-tolerant serving lifecycle** (§L7).
//!
//! The PJRT session is !Send (Rc-backed FFI handles), so each replica
//! owns its client + session on a dedicated model thread. A router
//! thread admits requests continuously, groups them by sequence-length
//! bucket (`runtime::session::bucket_for`), and emits full-or-expired
//! batches onto a shared job queue; the first replica with capacity
//! picks each job up.
//!
//! Replicas run one of two decode disciplines:
//!
//! - **Continuous (default, §Perf L6):** the replica owns `S` decode
//!   slots, each holding a request's device-resident KV-cache buffers
//!   (`Session::init_decode_slots`). Between decode iterations the slot
//!   scheduler admits pending requests into free slots (one
//!   `prefill@<bucket>` per same-bucket admission group), runs one
//!   fused `decode_token` over every live slot, and retires slots the
//!   moment they emit EOS or hit `dec_len`.
//! - **Batch-level (fallback / `ALTUP_NO_CONT_BATCH=1`):** the §Perf
//!   L5 run-to-completion loop over the monolithic `decode_step`.
//!
//! §L8 — on the continuous path, **speculative decoding**
//! (`ALTUP_SPEC_GAMMA` / `--spec-gamma`, via `coordinator::spec`)
//! replaces each fused `decode_token` iteration with a draft/verify
//! round: a cheap draft session proposes γ tokens per live slot, one
//! fused full-model `verify@γ` accepts the longest greedy-identical
//! prefix and supplies a correction token, and each slot's stream
//! advances by 1..=γ+1 tokens per full-model step — token-for-token
//! identical to plain decode (parity pinned by `tests/server.rs`).
//! Artifacts opt in by shipping a `draft` entry in meta.json; the sim
//! engine models the draft with `SimDraftSpec` (per-step cost + a
//! hash-sampled per-position acceptance coin) so the subsystem tests
//! and benches without a PJRT backend. Replicas fall back to plain
//! decode when no draft is available.
//!
//! §Perf L9 — replicas with a **paged decode contract** serve KV state
//! out of a fixed page pool instead of per-slot monoliths: every slot
//! maps its KV through a page table into refcounted fixed-size pages
//! (`runtime::pages`), admission is pool-aware (a request is admitted
//! only when its pages fit — an impossible request is shed with
//! `FailReason::PoolExhausted`, a transient shortage stalls admission
//! until live slots retire), and a content-addressed **prefix cache**
//! pins page-aligned prompt chunks so shared prefixes map one physical
//! copy and skip their covered prefill work (LRU-evicted under pool
//! pressure, never while any slot still maps the page). Artifacts opt
//! in by shipping the `paged` meta entry plus the
//! `prefill_paged`/`decode_token_paged` HLOs; the sim engine models
//! the pool with [`SimPoolSpec`] (`ALTUP_POOL_PAGES` /
//! `ALTUP_PAGE_SIZE` / `ALTUP_PREFIX_CACHE`). Replicas without the
//! contract keep serving monolithic `DecodeSlots`, token-for-token
//! identical.
//!
//! §L7 — the serving lifecycle is supervised (cf. Pope et al. 2022,
//! where replica failure and load shedding are scheduler states, not
//! fatal errors):
//!
//! - Every replica runs inside a panic boundary (`catch_unwind`). Each
//!   request a replica accepts lives in a per-replica in-flight
//!   [`Ledger`] until its terminal [`Response`] is sent; when a replica
//!   crashes, the supervisor (the router thread) requeues whatever the
//!   ledger still held to surviving replicas — bounded by
//!   `ServerOptions::max_retries` per request, after which the client
//!   receives an explicit `Response::failed` instead of a dropped
//!   channel — and respawns a replacement replica from the shared
//!   `EngineSpec` up to `ServerOptions::replica_restarts`.
//! - Requests carry an optional deadline (`ServerOptions::
//!   request_timeout_ms` / `ALTUP_REQUEST_TIMEOUT_MS`). The router
//!   sheds expired requests before dispatch and the continuous decode
//!   loop retires expired slots between iterations, so one stuck
//!   generation cannot hold a slot forever.
//! - `shutdown()` is a drain, not an abort: admissions stop, partial
//!   groups flush, replicas retire their in-flight slots naturally,
//!   and only then are threads joined. Every admitted request gets a
//!   terminal response — tokens, or an explicit failure.
//!
//! Backends: `EngineSpec::Artifact` serves a compiled artifact through
//! a warmed device cache (§Perf L4); `EngineSpec::Sim` is a
//! deterministic backend-free decode with a per-token cost model,
//! hash-sampled EOS lengths, and an injectable [`FaultSpec`]
//! (deterministic replica kills, hash-sampled panics, stuck
//! generations), so supervision, retry, shedding, and drain are all
//! testable and benchable without a PJRT backend.

use crate::coordinator::admission::{self, AdmissionController, QosAction, TenantSpec};
use crate::coordinator::deploy::{self, DeployControl, DeployOptions, DeployShared, RolloutDriver};
use crate::coordinator::metrics::{
    DeployMeter, LatencyHistogram, OccupancyMeter, PoolMeter, SpecMeter, TenantMeter,
};
use crate::coordinator::spec::{self, SpecDecoder};
use crate::coordinator::trace::{self, TraceStats};
use crate::data::tokenizer::EOS;
use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use crate::runtime::pages::{chunk_hashes, pages_for, PagePool, PageTable, PrefixCache};
use crate::runtime::session::{bucket_for, DecodeSlots, Session};
use crate::util::env;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
mod options;
mod router;
mod sim;
mod worker;

pub use options::{EngineSpec, FailReason, Request, Response, ServerOptions};
pub use sim::{
    BadVersionMode, ChaosSpec, CollectiveSpec, FaultSpec, SimDraftSpec, SimPoolSpec, SimSpec,
    SimSwapSpec,
};
pub(crate) use router::{route, Supervisor};
pub(crate) use sim::{
    sim_accept_len, sim_decode, sim_gen_len, sim_mix, sim_row_hash, sim_sleep, sim_token,
    SimEngine, SimSlot,
};
pub(crate) use worker::{
    flatten_page_tables, pop_job, resolve_spec_gamma, serve_replica, truncate_at_eos, Engine,
    Popped, SlotState,
};


/// `Response::replica` value for router-side failures (deadline sheds,
/// drain aborts, dead-server rejections) that never reached a model
/// replica.
pub const ROUTER_ID: usize = usize::MAX;

/// How long the router parks at most between supervision passes, so
/// replica crash events are noticed promptly even while admission is
/// idle or mid-batch-window.
const SUPERVISE_TICK: Duration = Duration::from_millis(25);

/// §L10 scale-down sentinel: a `BatchJob` with this bucket and no
/// requests asks whichever replica pops it to finish its in-flight
/// work and exit cleanly (an autoscale retirement, not a crash — no
/// respawn, no restart-budget spend).
const SCALE_DOWN_BUCKET: usize = usize::MAX;

fn scale_down_job() -> BatchJob {
    BatchJob { bucket: SCALE_DOWN_BUCKET, requests: Vec::new() }
}

fn is_scale_down(job: &BatchJob) -> bool {
    job.bucket == SCALE_DOWN_BUCKET && job.requests.is_empty()
}

/// §L10 cross-thread degradation levers, written by the router's
/// overload controller and read by replicas between decode iterations.
pub(crate) struct QosShared {
    /// Ceiling on the speculative draft length γ; `usize::MAX` = no
    /// cap (the overload controller halves γ under sustained pressure
    /// and restores the cap when calm).
    gamma_cap: AtomicUsize,
    /// §L11 rollout levers (targeted drain, canary probe gate, canary
    /// health), written by the router's rollout driver.
    pub(crate) deploy: DeployShared,
    /// §L13 trace epoch: the server's spawn instant. Router and worker
    /// threads stamp spans as ns-since-epoch, so intervals recorded on
    /// different threads compose on one clock (and bin into the same
    /// timeline windows).
    pub(crate) epoch: Instant,
}

impl QosShared {
    fn new() -> QosShared {
        QosShared {
            gamma_cap: AtomicUsize::new(usize::MAX),
            deploy: DeployShared::new(),
            epoch: Instant::now(),
        }
    }
}

/// Aggregate serving counters; per-replica stats are merged by the
/// supervisor as replicas exit (including crashed incarnations — their
/// partial counters are recovered through the panic boundary).
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// Requests answered with tokens (explicit failures count in
    /// `failed`, not here).
    pub requests: usize,
    /// Decode batches (batch-level) or prefill admission groups
    /// (continuous) — the unit `mean_fill` averages over.
    pub batches: usize,
    pub total_fill: usize,
    /// How many replica stat sets were merged in (crashed incarnations
    /// and their replacements each count once).
    pub replicas: usize,
    /// Real prompt tokens submitted (post-truncation).
    pub prompt_tokens: usize,
    /// Prefill tokens actually executed — `batch_size * bucket` per
    /// monolithic batch, `rows * bucket` per split prefill — the
    /// denominator of the padded-waste ratio.
    pub executed_tokens: usize,
    pub truncated: usize,
    /// Decoded tokens delivered to clients (EOS-truncated rows).
    pub tokens_generated: usize,
    /// Decode tokens the continuous path did NOT run because slots
    /// retired at EOS (`dec_len - row len`, summed). Zero under
    /// batch-level decode — the monolithic step always runs `dec_len`.
    pub tokens_saved: usize,
    /// Fused full-model decode iterations (continuous path only):
    /// `decode_token` executes, or §L8 verify rounds when speculating.
    pub decode_steps: usize,
    /// Split-prefill executions (continuous path only).
    pub prefills: usize,
    /// §L7: requests shed past their deadline (router or replica side).
    /// Subset of `failed`.
    pub sheds: usize,
    /// §L7: requests requeued to another replica after a crash.
    pub retries: usize,
    /// §L7: replacement replicas the supervisor spawned.
    pub restarts: usize,
    /// §L10: autoscale replicas spawned on sustained queue pressure
    /// (beyond the configured fleet; bounded by
    /// `ServerOptions::autoscale`).
    pub scale_ups: usize,
    /// §L10: autoscale replicas retired once pressure subsided.
    pub scale_downs: usize,
    /// §L7: explicit terminal failures delivered (deadline sheds,
    /// retry exhaustion, drain aborts, dead-server rejections).
    pub failed: usize,
    /// §L7: requests completed after admissions closed (the drain
    /// window of `shutdown()`). Counted on the continuous path — the
    /// default discipline; the batch-level loop cannot observe
    /// admission closure (it only ever sees the job queue end) and
    /// reports 0 here.
    pub drained: usize,
    /// §L8 speculative-decoding counters (drafted/accepted tokens,
    /// draft/verify steps, tokens delivered per verify). All-zero when
    /// speculation is off or unsupported.
    pub spec: SpecMeter,
    /// §L9 paged decode-state counters (pool occupancy, prefix cache
    /// hit rate, prefill tokens saved, evictions, admission stalls).
    /// All-zero when the replica serves monolithic slots.
    pub pool: PoolMeter,
    /// Live-slots-per-decode-iteration meter (continuous path only).
    pub occupancy: OccupancyMeter,
    /// Per-request queued+executed latency, log-bucketed (O(1) memory
    /// over a server's lifetime, mergeable across replicas).
    pub latency: LatencyHistogram,
    /// Per-token latency (request latency / tokens delivered).
    pub token_latency: LatencyHistogram,
    /// §L10 per-tenant QoS accounting, indexed by `Request::tenant`
    /// (grown on demand; empty when no tenant ever completed or
    /// failed). Names live in `ServerOptions::tenants` — the stats
    /// carry only indices so replicas stay config-free.
    pub tenants: Vec<TenantMeter>,
    /// §L11 per-version rollout accounting (requests by artifact
    /// version, canary verdicts, rollbacks). `current` tags which
    /// version this stat set's completions/failures land on; the
    /// version rows partition the global counters the same way
    /// `tenants` does.
    pub deploy: DeployMeter,
    /// §L12: device-incarnations merged in — `tp` per execution-group
    /// incarnation, 1 per single. `replicas` counts fleet units; this
    /// counts the devices they occupied (the equal-device-budget
    /// denominator of the TP-vs-DP A/B).
    pub devices: usize,
    /// §L12: all-reduce rounds executed by execution groups (0 for a
    /// whole-model fleet). Flushed when a serving loop exits cleanly;
    /// crashed incarnations under-report.
    pub collectives: u64,
    /// §L12: simulated ns spent in those collective rounds.
    pub collective_ns: u64,
    /// §L13: per-request phase spans (ring-buffered at the worker),
    /// aggregate phase-time ledger, and the gauge timeline. Inactive
    /// (and overhead-free) unless `ServerOptions::trace_sample > 0`.
    pub trace: TraceStats,
}

impl ServerStats {
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_fill as f64 / self.batches as f64
        }
    }

    /// Fraction of executed tokens that were padding: 1 - prompt/executed.
    pub fn waste_ratio(&self) -> f64 {
        if self.executed_tokens == 0 {
            0.0
        } else {
            1.0 - self.prompt_tokens as f64 / self.executed_tokens as f64
        }
    }

    /// Fraction of the monolithic decode budget the early exit saved:
    /// saved / (saved + generated).
    pub fn early_exit_ratio(&self) -> f64 {
        let budget = self.tokens_saved + self.tokens_generated;
        if budget == 0 {
            0.0
        } else {
            self.tokens_saved as f64 / budget as f64
        }
    }

    /// Number of latency samples recorded (== requests served).
    pub fn latency_count(&self) -> u64 {
        self.latency.count()
    }

    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        self.latency.percentile_ms(p)
    }
    pub fn p50_ms(&self) -> f64 {
        self.latency_percentile_ms(50.0)
    }
    pub fn p95_ms(&self) -> f64 {
        self.latency_percentile_ms(95.0)
    }
    pub fn p99_ms(&self) -> f64 {
        self.latency_percentile_ms(99.0)
    }
    /// Mean per-token latency in ms (histogram approximation).
    pub fn token_ms(&self) -> f64 {
        self.token_latency.mean_ms()
    }

    /// Record one finished request's bookkeeping (shared by both
    /// decode disciplines).
    fn note_response(
        &mut self,
        latency: Duration,
        generated: usize,
        saved: usize,
        prompt: usize,
        truncated: bool,
    ) {
        let ms = latency.as_secs_f64() * 1e3;
        self.latency.record(ms);
        self.token_latency.record(ms / generated.max(1) as f64);
        self.tokens_generated += generated;
        self.tokens_saved += saved;
        self.prompt_tokens += prompt;
        if truncated {
            self.truncated += 1;
        }
    }

    /// Fold another replica's counters into this aggregate.
    pub fn merge(&mut self, other: &ServerStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.total_fill += other.total_fill;
        self.replicas += other.replicas;
        self.prompt_tokens += other.prompt_tokens;
        self.executed_tokens += other.executed_tokens;
        self.truncated += other.truncated;
        self.tokens_generated += other.tokens_generated;
        self.tokens_saved += other.tokens_saved;
        self.decode_steps += other.decode_steps;
        self.prefills += other.prefills;
        self.sheds += other.sheds;
        self.retries += other.retries;
        self.restarts += other.restarts;
        self.scale_ups += other.scale_ups;
        self.scale_downs += other.scale_downs;
        self.failed += other.failed;
        self.drained += other.drained;
        self.spec.merge(&other.spec);
        self.pool.merge(&other.pool);
        self.occupancy.merge(&other.occupancy);
        self.latency.merge(&other.latency);
        self.token_latency.merge(&other.token_latency);
        for (t, m) in other.tenants.iter().enumerate() {
            self.tenant_mut(t).merge(m);
        }
        self.deploy.merge(&other.deploy);
        self.devices += other.devices;
        self.collectives += other.collectives;
        self.collective_ns += other.collective_ns;
        self.trace.merge(&other.trace);
    }

    /// The meter for tenant `t`, growing the table on first touch so
    /// replicas need no tenant config to account correctly.
    pub fn tenant_mut(&mut self, t: usize) -> &mut TenantMeter {
        if self.tenants.len() <= t {
            self.tenants.resize_with(t + 1, TenantMeter::default);
        }
        &mut self.tenants[t]
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} requests / {} batches on {} replica(s), mean fill {:.2}, \
             padded waste {:.1}%, {} tokens out (early exit saved {:.1}%), \
             mean occupancy {:.2} over {} decode steps, \
             latency p50 {:.2} / p95 {:.2} / p99 {:.2} ms",
            self.requests,
            self.batches,
            self.replicas.max(1),
            self.mean_fill(),
            self.waste_ratio() * 100.0,
            self.tokens_generated,
            self.early_exit_ratio() * 100.0,
            self.occupancy.mean(),
            self.decode_steps,
            self.p50_ms(),
            self.p95_ms(),
            self.p99_ms()
        );
        if self.spec.active() {
            s.push_str(&format!(
                " | spec: {:.1}% acceptance ({}/{} drafted), {:.2} tokens/verify \
                 over {} verify steps",
                self.spec.acceptance_rate() * 100.0,
                self.spec.accepted,
                self.spec.drafted,
                self.spec.tokens_per_verify(),
                self.spec.verify_steps
            ));
        }
        if self.pool.active() {
            s.push_str(&format!(
                " | pool: {:.1}% occupancy (peak {}/{} pages), prefix hit rate {:.1}%, \
                 {} prefill tokens saved, {} evictions, {} stalls",
                self.pool.utilization() * 100.0,
                self.pool.peak_used,
                self.pool.capacity,
                self.pool.hit_rate() * 100.0,
                self.pool.prefill_tokens_saved,
                self.pool.evictions,
                self.pool.alloc_stalls
            ));
        }
        if self.failed + self.retries + self.restarts + self.drained > 0 {
            s.push_str(&format!(
                " | faults: {} shed / {} retried / {} restarts / {} failed / {} drained",
                self.sheds, self.retries, self.restarts, self.failed, self.drained
            ));
        }
        if self.deploy.active() {
            let versions: Vec<String> = self
                .deploy
                .versions
                .iter()
                .enumerate()
                .map(|(v, m)| format!("v{v}:{}", m.requests))
                .collect();
            s.push_str(&format!(
                " | deploy: {} canary pass / {} fail, {} rollback(s), {} completed, \
                 {} aborted, requests by version [{}]",
                self.deploy.canary_pass,
                self.deploy.canary_fail,
                self.deploy.rollbacks,
                self.deploy.completed,
                self.deploy.aborted,
                versions.join(" ")
            ));
        }
        if self.trace.active() {
            use trace::Phase;
            let attrs = trace::per_request(self.trace.spans());
            let at = trace::attribute(&attrs, 1.0);
            let shares = at.shares();
            let pct: Vec<String> = Phase::TOP_LEVEL
                .iter()
                .map(|p| format!("{} {:.1}%", p.as_str(), 100.0 * shares[p.index()]))
                .collect();
            s.push_str(&format!(
                " | trace: {} spans over {} requests ({} dropped), phase share [{}]",
                self.trace.span_count(),
                at.requests,
                self.trace.dropped_spans,
                pct.join(" ")
            ));
        }
        s
    }
}

/// Send an explicit terminal failure for `req` and count it. The send
/// is best-effort: a client that already gave up dropped its receiver.
fn fail_request(stats: &mut ServerStats, req: &Request, reason: FailReason, replica: usize) {
    stats.failed += 1;
    let shed = matches!(
        reason,
        FailReason::DeadlineExceeded | FailReason::QueueFull | FailReason::WouldMissDeadline
    );
    if shed {
        stats.sheds += 1;
    }
    let tm = stats.tenant_mut(req.tenant);
    tm.failed += 1;
    if shed {
        tm.sheds += 1;
    }
    stats.deploy.note_failed(shed);
    let _ = req.reply.send(Response::failed(reason, req.t0, replica));
}

/// A request the router has accepted into a bucket group. Latency is
/// reported from the client-side `Request::t0`; the batch-window
/// deadline runs from `admitted`, so a request that sat in the request
/// channel does not count that wait against its group's window (which
/// would ship burst arrivals as tiny immediately-due batches).
struct Admitted {
    req: Request,
    admitted: Instant,
    /// How many times a crashed replica already held this request (the
    /// supervisor's retry counter).
    attempts: u32,
}

/// A bucket-homogeneous batch ready for a replica.
struct BatchJob {
    bucket: usize,
    requests: Vec<Admitted>,
}

/// §L7: every request a replica has accepted but not yet terminally
/// answered, keyed by ticket. The ledger lives outside the panic
/// boundary, so the supervisor can requeue or explicitly fail whatever
/// a crashed replica was holding — no reply channel is ever silently
/// dropped with a dying thread.
struct Ledger {
    inner: Mutex<LedgerInner>,
}

struct LedgerInner {
    next_ticket: u64,
    held: HashMap<u64, Held>,
}

/// A ledger entry: the original request plus the routing state needed
/// to requeue it (bucket) and cap its retries (attempts).
struct Held {
    bucket: usize,
    attempts: u32,
    req: Request,
}

impl Ledger {
    fn new() -> Ledger {
        Ledger { inner: Mutex::new(LedgerInner { next_ticket: 0, held: HashMap::new() }) }
    }

    /// Poison-proof lock: the ledger is read after a replica panic by
    /// design, and entries are plain data — a poisoned guard is safe to
    /// recover.
    fn lock(&self) -> std::sync::MutexGuard<'_, LedgerInner> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn admit(&self, bucket: usize, attempts: u32, req: Request) -> u64 {
        let mut inner = self.lock();
        let ticket = inner.next_ticket;
        inner.next_ticket += 1;
        inner.held.insert(ticket, Held { bucket, attempts, req });
        ticket
    }

    fn take(&self, ticket: u64) -> Option<Held> {
        self.lock().held.remove(&ticket)
    }

    /// Run `f` over a held request's prompt tokens in place (§L9
    /// prefix-chunk hashing at admission) — no clone, same reasoning
    /// as `pack_rows`. `None` when the ticket was already taken.
    fn with_prompt<R>(&self, ticket: u64, f: impl FnOnce(&[i32]) -> R) -> Option<R> {
        let inner = self.lock();
        inner.held.get(&ticket).map(|h| f(&h.req.enc_tokens))
    }

    fn drain(&self) -> Vec<Held> {
        self.lock().held.drain().map(|(_, h)| h).collect()
    }

    /// Pack the held requests behind `tickets` into the (batch_size,
    /// len) geometry, borrowing their prompt rows in place — the hot
    /// path never clones a prompt just because ownership sits in the
    /// ledger. Row order follows `tickets`; a ticket already taken
    /// packs as an empty row (cannot happen on the owning replica).
    fn pack_rows(
        &self,
        tickets: &[u64],
        batch_size: usize,
        len: usize,
        enc: &mut Vec<i32>,
        truncated: &mut Vec<bool>,
    ) {
        let inner = self.lock();
        let rows: Vec<&[i32]> = tickets
            .iter()
            .map(|t| inner.held.get(t).map_or(&[][..], |h| h.req.enc_tokens.as_slice()))
            .collect();
        pack_requests_into(&rows, batch_size, len, enc, truncated);
    }
}

/// What a replica thread reports to the supervisor as its last act —
/// its stats (partial if it crashed), the crash cause if any, and every
/// in-flight request its ledger still held.
struct ReplicaExit {
    id: usize,
    stats: ServerStats,
    /// `Some` when the replica crashed (panic or error) rather than
    /// drained cleanly.
    error: Option<String>,
    unfinished: Vec<Held>,
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: <non-string payload>".to_string()
    }
}

/// Spawn one replica thread behind the §L7 panic boundary. The thread's
/// terminal `ReplicaExit` event — stats, crash cause, unfinished
/// ledger — always reaches the supervisor, panic or not.
fn spawn_replica(
    id: usize,
    spec: &EngineSpec,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
    events: &mpsc::Sender<ReplicaExit>,
    shared: &Arc<QosShared>,
    version: u32,
    tp: usize,
) -> std::thread::JoinHandle<()> {
    let spec = spec.clone();
    let jobs = Arc::clone(jobs);
    let opts = opts.clone();
    let events = events.clone();
    let shared = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("altup-replica-{id}"))
        .spawn(move || {
            let ledger = Ledger::new();
            let mut stats = ServerStats { replicas: 1, ..Default::default() };
            // §L11: everything this incarnation completes or fails is
            // accounted to its artifact version.
            stats.deploy.current = version;
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                serve_replica(id, &spec, &jobs, &opts, &ledger, &mut stats, &shared, tp)
            }));
            let error = match outcome {
                Ok(Ok(())) => None,
                Ok(Err(e)) => Some(format!("{e:#}")),
                Err(payload) => Some(panic_message(payload.as_ref())),
            };
            let unfinished = ledger.drain();
            let _ = events.send(ReplicaExit { id, stats, error, unfinished });
        })
        .expect("spawn replica")
}

pub struct ServerHandle {
    /// Bounded: `send` blocks once `ServerOptions::queue_cap` requests
    /// are in flight ahead of the router (admission backpressure).
    pub sender: mpsc::SyncSender<Request>,
    router: Option<std::thread::JoinHandle<Result<ServerStats>>>,
    /// Cleared the moment the router thread exits (even by panic), so
    /// `infer` can reject new work immediately instead of touching a
    /// channel whose receiver is gone.
    router_up: Arc<AtomicBool>,
    /// §L11 rollout mailbox shared with the router's rollout driver.
    deploy_ctl: Arc<DeployControl>,
}

/// Clears the router-liveness flag on drop — including on unwind.
struct RouterGuard(Arc<AtomicBool>);

impl Drop for RouterGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

impl ServerHandle {
    /// Spawn router + replicas serving the named artifact.
    pub fn spawn(artifact_name: &str, opts: ServerOptions) -> ServerHandle {
        ServerHandle::spawn_engine(
            EngineSpec::Artifact { name: artifact_name.to_string() },
            opts,
        )
    }

    /// Spawn supervisor/router + replicas over an explicit decode
    /// backend.
    pub fn spawn_engine(engine: EngineSpec, opts: ServerOptions) -> ServerHandle {
        let n = opts.replicas.max(1);
        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(opts.queue_cap.max(1));
        // Bounded job queue = backpressure: when every replica is busy
        // and the queue is full, the router keeps accumulating instead
        // of window-flushing tiny partial batches at a wall of busy
        // replicas (which craters fill and wastes executed tokens).
        // §L10: the job queue is sized for the autoscaled fleet, so a
        // scaled-up replica never starves the queue of slots and the
        // scale-down sentinel always has room.
        let (job_tx, job_rx) = mpsc::sync_channel::<BatchJob>(n + opts.autoscale);
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (events_tx, events_rx) = mpsc::channel::<ReplicaExit>();
        let shared = Arc::new(QosShared::new());

        // §L12: the first `tp_groups` fleet units come up as TP groups
        // of `opts.tp` shards; the rest are whole-model DP replicas.
        let handles: Vec<_> = (0..n)
            .map(|i| {
                spawn_replica(i, &engine, &job_rx, &opts, &events_tx, &shared, 0, opts.unit_tp(i))
            })
            .collect();
        let router_up = Arc::new(AtomicBool::new(true));
        let deploy_ctl = Arc::new(DeployControl::new());
        let router = {
            let spec = engine.clone();
            let ropts = opts.clone();
            let flag = Arc::clone(&router_up);
            let ctl = Arc::clone(&deploy_ctl);
            std::thread::Builder::new()
                .name("altup-router".into())
                .spawn(move || {
                    let _guard = RouterGuard(flag);
                    route(
                        &spec, req_rx, job_tx, job_rx, events_rx, events_tx, &ropts, handles,
                        shared, ctl,
                    )
                })
                .expect("spawn router")
        };
        ServerHandle { sender: req_tx, router: Some(router), router_up, deploy_ctl }
    }

    /// Submit a request and block for the response; explicit failure
    /// responses are mapped to `Err`. The latency clock starts before
    /// the (possibly blocking) send into the bounded request channel,
    /// so backpressured requests report their queueing time.
    pub fn infer(&self, enc_tokens: Vec<i32>) -> Result<Response> {
        let resp = self.infer_response(enc_tokens)?;
        match resp.failure {
            Some(reason) => Err(anyhow!("request failed: {reason}")),
            None => Ok(resp),
        }
    }

    /// Like `infer`, but returns explicit-failure responses as
    /// `Ok(Response)` so callers can inspect `Response::failure`.
    /// Errors only when the server machinery itself is gone (router
    /// dead before admission, reply channel dropped).
    pub fn infer_response(&self, enc_tokens: Vec<i32>) -> Result<Response> {
        if !self.router_up.load(Ordering::Acquire) {
            bail!("server router is down; request not admitted");
        }
        let (tx, rx) = mpsc::channel();
        self.sender
            .send(Request::new(enc_tokens, tx))
            .map_err(|_| anyhow!("server router is down; request not admitted"))?;
        rx.recv().map_err(|_| {
            anyhow!("server dropped the reply channel (shutdown() reports the cause)")
        })
    }

    /// §L11: roll the fleet onto a new engine version, one replica at a
    /// time behind the canary health gates. Blocks until the rollout
    /// reaches a terminal [`DeployStatus`] (completed, rolled back,
    /// failed validation, or aborted by shutdown). Rollouts queue:
    /// concurrent calls run strictly one at a time.
    pub fn deploy(&self, engine: EngineSpec) -> DeployStatus {
        let seq = self.deploy_start(engine);
        self.deploy_wait(seq)
    }

    /// §L11: enqueue a rollout without blocking; returns a ticket for
    /// `deploy_wait`. Lets a caller overlap a rollout with its own
    /// work (or shut the server down mid-rollout — the ticket then
    /// resolves to `Aborted`).
    pub fn deploy_start(&self, engine: EngineSpec) -> u64 {
        self.deploy_ctl.submit(engine)
    }

    /// §L11: block until the rollout behind `seq` reaches a terminal
    /// [`DeployStatus`].
    pub fn deploy_wait(&self, seq: u64) -> DeployStatus {
        self.deploy_ctl.wait(seq, &self.router_up)
    }

    /// §L11: `deploy` for a compiled artifact by suite name — the
    /// `Server::deploy(artifact_dir)` entry point (artifact names
    /// resolve to directories via the suite registry, and
    /// `Artifact::load` verifies the version fingerprint + checksums
    /// before the fleet ever sees the new weights).
    pub fn deploy_artifact(&self, name: &str) -> DeployStatus {
        self.deploy(EngineSpec::Artifact { name: name.to_string() })
    }

    /// §L11: live rollout status snapshot (`Idle` before any deploy).
    pub fn deploy_status(&self) -> DeployStatus {
        self.deploy_ctl.status()
    }

    /// Drain and shut down: stop admissions, flush partial groups, let
    /// replicas retire their in-flight slots naturally, join every
    /// thread, and return the merged stats. Every admitted request gets
    /// a terminal response before this returns. An in-flight rollout is
    /// aborted cleanly (reported as `Aborted` to its waiter and in the
    /// stats' deploy section).
    pub fn shutdown(self) -> Result<ServerStats> {
        let ServerHandle { sender, router, router_up: _, deploy_ctl: _ } = self;
        let router = router.expect("router handle");
        drop(sender); // stop admissions; the router begins its drain
        match router.join() {
            Ok(result) => result,
            Err(_) => Err(anyhow!("router thread panicked")),
        }
    }
}

/// (batch_size, enc_len) of the serving geometry. For artifacts this
/// runs the full `Artifact::load` (including §L11 checksum
/// verification), so the §L11 prep thread reuses it as the new
/// version's load-time validation.
pub(crate) fn engine_dims(spec: &EngineSpec) -> Result<(usize, usize)> {
    match spec {
        EngineSpec::Artifact { name } => {
            let artifact = load_named(name)?;
            Ok((artifact.config.batch_size, artifact.config.enc_len))
        }
        EngineSpec::Sim(s) => Ok((s.batch_size, s.enc_len)),
    }
}


/// Pack request token rows into a fixed (batch_size, len) geometry:
/// short rows are zero-padded, long rows are cut to fit. `len` is the
/// full `enc_len` or any smaller bucket the group was routed to.
/// Returns the flat batch plus a per-row truncation flag.
pub fn pack_requests(
    rows: &[&[i32]],
    batch_size: usize,
    len: usize,
) -> (Vec<i32>, Vec<bool>) {
    let mut enc = Vec::new();
    let mut truncated = Vec::new();
    pack_requests_into(rows, batch_size, len, &mut enc, &mut truncated);
    (enc, truncated)
}

/// `pack_requests` into caller-provided scratch buffers, so the
/// replica hot loop reuses one allocation across every batch instead
/// of building a fresh padded matrix per job. The scratch is cleared
/// and zero-filled to the new geometry on every call — no stale tokens
/// survive a reuse at a different shape.
pub fn pack_requests_into(
    rows: &[&[i32]],
    batch_size: usize,
    len: usize,
    enc: &mut Vec<i32>,
    truncated: &mut Vec<bool>,
) {
    enc.clear();
    enc.resize(batch_size * len, 0);
    truncated.clear();
    truncated.resize(rows.len(), false);
    for (i, row) in rows.iter().take(batch_size).enumerate() {
        let n = row.len().min(len);
        enc[i * len..i * len + n].copy_from_slice(&row[..n]);
        truncated[i] = row.len() > len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_spec() -> SimSpec {
        SimSpec {
            batch_size: 2,
            enc_len: 32,
            dec_len: 6,
            vocab_size: 97,
            token_ns: 0,
            dtoken_ns: 0,
            dstep_ns: 0,
            split_decode: true,
            draft: Some(SimDraftSpec { dtoken_ns: 0, dstep_ns: 0, accept_rate: 0.75 }),
            pool: None,
            collective: CollectiveSpec {
                d_model: 1024,
                active_width: 256,
                elem_bytes: 2,
                link_bps: 25.0e9,
                latency_ns: 1500,
                syncs_per_step: 12,
                partitioned_frac: 0.85,
            },
            fault: FaultSpec::default(),
            bad_token_salt: 0,
            bad_panic: false,
        }
    }

    /// §L10: a chaos schedule composes onto a sim spec — first kill on
    /// the legacy single-kill fields, the rest on `extra_kills`, stuck
    /// class passed through, pool pressure floored at one slot's pages.
    #[test]
    fn chaos_spec_composes_onto_sim_spec() {
        let mut spec = quiet_spec();
        spec.pool = Some(SimPoolSpec { page_size: 8, pool_pages: 100, prefix_cache: false });
        let chaos = ChaosSpec {
            kills: vec![(1, 5), (2, 9)],
            stuck_every: 7,
            stuck_step_ns: 11,
            pool_reserve: 0.25,
        };
        chaos.apply(&mut spec);
        assert_eq!(spec.fault.kill_replica, Some(1));
        assert_eq!(spec.fault.kill_after_calls, 5);
        assert_eq!(spec.fault.extra_kills, vec![(2, 9)]);
        assert_eq!(spec.fault.stuck_every, 7);
        assert_eq!(spec.fault.stuck_step_ns, 11);
        assert_eq!(spec.pool.as_ref().unwrap().pool_pages, 75, "25% withheld");
        // Extreme pressure still leaves one slot's worth of pages.
        let mut spec = quiet_spec();
        spec.pool = Some(SimPoolSpec { page_size: 8, pool_pages: 100, prefix_cache: false });
        ChaosSpec { pool_reserve: 1.0, ..ChaosSpec::default() }.apply(&mut spec);
        let floor = pages_for(spec.enc_len + spec.dec_len, 8);
        assert_eq!(spec.pool.as_ref().unwrap().pool_pages, floor);
        // An empty schedule is the identity.
        let mut spec = quiet_spec();
        ChaosSpec::default().apply(&mut spec);
        assert_eq!(spec.fault.kill_replica, None);
        assert!(spec.fault.extra_kills.is_empty());
    }

    /// §L12: the collective cost model stays bit-stable — the bench
    /// and the python twin mirror these exact formulas, so any drift
    /// here silently desynchronizes the two producers.
    #[test]
    fn collective_cost_model_pins() {
        let c = quiet_spec().collective;
        // Unsharded is free.
        assert_eq!(c.allreduce_ns(1, 64), 0);
        assert_eq!(c.step_collective_ns(0, 64), 0);
        assert_eq!(c.compute_scale(1), 1.0);
        // tp=2 over 8 fused tokens: 2 ring hops of latency plus
        // 2(tp-1)/tp = 1.0 of the active-block payload across one link.
        let bytes = (8 * 256 * 2) as f64;
        let wire = (bytes * 1.0 / 25.0e9 * 1e9).round() as u64;
        assert_eq!(c.allreduce_ns(2, 8), 1500 * 2 + wire);
        assert_eq!(c.step_collective_ns(2, 8), 12 * c.allreduce_ns(2, 8));
        // The AltUp asymmetry: a dense-widened baseline syncs all of
        // d_model, 4x the active subblock's wire bytes (ratio-checked
        // to stay clear of per-call rounding).
        let dense = CollectiveSpec { active_width: c.d_model, ..c.clone() };
        let dense_wire = (dense.allreduce_ns(2, 8) - 1500 * 2) as f64;
        assert!(
            (dense_wire / wire as f64 - 4.0).abs() < 0.01,
            "payload scales with the synced width ({dense_wire} vs {wire})"
        );
        // Per-shard compute: partitioned fraction splits, the
        // replicated predict/correct remainder is paid in full.
        assert!((c.compute_scale(2) - 0.575).abs() < 1e-12);
        assert!((c.compute_scale(4) - (0.15 + 0.85 / 4.0)).abs() < 1e-12);
    }

    /// §L12: kill triggers route to exactly one shard of a group while
    /// cost/stuck injection rides the cost-carrying leader, and
    /// `unit_tp` shapes a heterogeneous TP/DP fleet.
    #[test]
    fn fault_shard_routing_and_fleet_shape() {
        let fault = FaultSpec {
            kill_replica: Some(3),
            kill_after_calls: 9,
            extra_kills: vec![(4, 2)],
            stuck_every: 5,
            stuck_step_ns: 7,
            kill_shard: 1,
            ..FaultSpec::default()
        };
        let leader = fault.for_shard(0, 2);
        assert_eq!(leader.kill_replica, None, "kill routed away from the leader");
        assert_eq!(leader.stuck_every, 5, "stuck injection rides the leader");
        assert_eq!(leader.stuck_step_ns, 7);
        let follower = fault.for_shard(1, 2);
        assert_eq!(follower.kill_replica, Some(3));
        assert_eq!(follower.kill_after_calls, 9);
        assert_eq!(follower.extra_kills, vec![(4, 2)]);
        assert_eq!(follower.stuck_every, 0, "followers carry no cost model");
        // An out-of-range shard target clamps to the last shard.
        let clamped = FaultSpec { kill_shard: 9, ..fault.clone() };
        assert_eq!(clamped.for_shard(1, 2).kill_replica, Some(3));
        assert_eq!(clamped.for_shard(0, 2).kill_replica, None);

        let opts = ServerOptions {
            tp: 2,
            tp_groups: 2,
            replicas: 4,
            ..ServerOptions::default()
        };
        let shape: Vec<usize> = (0..4).map(|i| opts.unit_tp(i)).collect();
        assert_eq!(shape, vec![2, 2, 1, 1], "first tp_groups units shard, the rest stay DP");
        let unsharded = ServerOptions { tp: 1, ..opts };
        assert_eq!(unsharded.unit_tp(0), 1, "tp<2 never shards");
    }

    /// §L10 satellite: the respawn backoff doubles per consecutive
    /// crash with jitter bounded to [0.75, 1.25) of nominal, so delay
    /// ranges for successive crashes never overlap.
    #[test]
    fn respawn_backoff_grows_exponentially_with_bounded_jitter() {
        let (_job_tx, job_rx) = mpsc::sync_channel::<BatchJob>(1);
        let (events_tx, _events_rx) = mpsc::channel();
        let mut sup = Supervisor {
            specs: BTreeMap::from([(0u32, EngineSpec::Sim(quiet_spec()))]),
            decided: 0,
            versions: HashMap::from([(0usize, 0u32)]),
            shapes: HashMap::new(),
            opts: ServerOptions { restart_backoff_ms: 40, seed: 7, ..ServerOptions::default() },
            jobs: Arc::new(Mutex::new(job_rx)),
            events_tx,
            handles: Vec::new(),
            live: 1,
            restarts_left: 3,
            next_id: 1,
            last_error: None,
            died: None,
            pending_respawns: Vec::new(),
            crashes: 0,
            shared: Arc::new(QosShared::new()),
        };
        let mut prev = 0u64;
        for c in 0..4u32 {
            sup.crashes = c;
            let d = sup.backoff_delay().as_millis() as u64;
            let nominal = 40u64 << c;
            assert!(
                d >= nominal - nominal / 4 && d <= nominal + nominal / 2,
                "crash {c}: delay {d} outside jitter band of nominal {nominal}"
            );
            assert!(d > prev, "crash {c}: backoff must grow ({d} <= {prev})");
            prev = d;
        }
        // The exponent saturates instead of overflowing the shift.
        sup.crashes = u32::MAX;
        assert!(sup.backoff_delay() <= Duration::from_millis(40 * 64 * 2));
    }

    #[test]
    fn pack_requests_pads_and_flags_truncation() {
        let short = vec![1, 2, 3];
        let exact = vec![5, 6, 7, 8];
        let long = vec![9, 10, 11, 12, 13, 14];
        let rows: Vec<&[i32]> = vec![&short, &exact, &long];
        let (enc, truncated) = pack_requests(&rows, 4, 4);
        assert_eq!(enc.len(), 16);
        assert_eq!(&enc[0..4], &[1, 2, 3, 0], "short row zero-padded");
        assert_eq!(&enc[4..8], &[5, 6, 7, 8], "exact row untouched");
        assert_eq!(&enc[8..12], &[9, 10, 11, 12], "long row cut to enc_len");
        assert_eq!(&enc[12..16], &[0, 0, 0, 0], "unfilled slot stays zero");
        assert_eq!(truncated, vec![false, false, true]);
    }

    #[test]
    fn pack_requests_empty_and_full() {
        let (enc, truncated) = pack_requests(&[], 2, 3);
        assert_eq!(enc, vec![0; 6]);
        assert!(truncated.is_empty());
        let a = vec![1i32; 3];
        let b = vec![2i32; 4];
        let rows: Vec<&[i32]> = vec![&a, &b];
        let (enc, truncated) = pack_requests(&rows, 2, 3);
        assert_eq!(&enc[3..6], &[2, 2, 2]);
        assert_eq!(truncated, vec![false, true]);
    }

    #[test]
    fn pack_requests_at_smaller_bucket() {
        let a = vec![1, 2, 3];
        let rows: Vec<&[i32]> = vec![&a];
        let (enc, truncated) = pack_requests(&rows, 2, 8);
        assert_eq!(enc.len(), 16, "bucket stride, not enc_len stride");
        assert_eq!(&enc[0..4], &[1, 2, 3, 0]);
        assert_eq!(truncated, vec![false]);
    }

    /// Reusing one scratch across geometry changes must behave exactly
    /// like a fresh allocation: no stale tokens from a previous (and
    /// larger) batch may leak into the next packing.
    #[test]
    fn pack_scratch_reuse_leaves_no_stale_data() {
        let mut enc = Vec::new();
        let mut trunc = Vec::new();
        let big = vec![7i32; 8];
        let rows: Vec<&[i32]> = vec![&big, &big, &big];
        pack_requests_into(&rows, 3, 8, &mut enc, &mut trunc);
        assert_eq!(enc.len(), 24);
        assert!(enc.iter().all(|&t| t == 7));

        let small = vec![1i32, 2];
        let rows: Vec<&[i32]> = vec![&small];
        pack_requests_into(&rows, 2, 4, &mut enc, &mut trunc);
        let (fresh, fresh_trunc) = pack_requests(&rows, 2, 4);
        assert_eq!(enc, fresh, "reused scratch == fresh allocation");
        assert_eq!(trunc, fresh_trunc);
        assert_eq!(&enc[2..8], &[0, 0, 0, 0, 0, 0], "old 7s cleared");
        // Growing again after shrinking also matches.
        let rows: Vec<&[i32]> = vec![&big];
        pack_requests_into(&rows, 2, 8, &mut enc, &mut trunc);
        assert_eq!(enc, pack_requests(&rows, 2, 8).0);
    }

    #[test]
    fn sim_decode_is_bucket_invariant_and_deterministic() {
        let spec = quiet_spec();
        let prompt: Vec<i32> = vec![4, 9, 1, 7];
        let pad_to = |len: usize| {
            let mut v = prompt.clone();
            v.resize(len, 0);
            v
        };
        let mut small = pad_to(8);
        small.extend(pad_to(8));
        let mut full = pad_to(32);
        full.extend(pad_to(32));
        let a = sim_decode(&spec, &small, 8);
        let b = sim_decode(&spec, &full, 32);
        assert_eq!(a, b, "output depends only on the unpadded prompt");
        assert!(!a[0].is_empty() && a[0].len() <= spec.dec_len);
        assert_eq!(*a[0].last().unwrap(), EOS, "rows end at their sampled EOS");
        assert!(a[0][..a[0].len() - 1]
            .iter()
            .all(|&t| t >= 2 && (t as usize) < 97), "non-final tokens stay off PAD/EOS");
        // Different prompts decode differently (not a constant).
        let mut other = vec![5i32, 5, 5, 0, 0, 0, 0, 0];
        other.extend(pad_to(8));
        assert_ne!(sim_decode(&spec, &other, 8)[0], a[0]);
    }

    /// The slot-based stream must equal the monolithic row token for
    /// token: prefill one row, step `decode_token` to EOS, compare.
    #[test]
    fn sim_slot_stream_matches_monolithic_rows() {
        let spec = quiet_spec();
        let mut engine = Engine::Sim(SimEngine::new(spec.clone(), 0));
        let mut state = engine.init_slots(3).unwrap();
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        engine.prefill(&mut state, &prompt, 8, &[1]).unwrap();
        let mut live = vec![false, true, false];
        let mut stream = Vec::new();
        for _ in 0..spec.dec_len {
            let toks = engine.decode_token(&mut state, &live).unwrap();
            stream.push(toks[1]);
            if toks[1] == EOS {
                live[1] = false;
                break;
            }
        }
        let mut batch = prompt.clone();
        batch.extend(vec![0i32; 8]);
        let rows = sim_decode(&spec, &batch, 8);
        assert_eq!(stream, rows[0], "per-token stream == monolithic row");
        assert_eq!(*stream.last().unwrap(), EOS);
    }

    /// Stuck-generation injection: a stuck row never emits EOS, runs
    /// the full dec_len on both decode paths, and produces identical
    /// tokens on both.
    #[test]
    fn sim_stuck_rows_never_emit_eos_on_either_path() {
        let mut spec = quiet_spec();
        spec.fault.stuck_every = 1; // every prompt is stuck
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        let mut batch = prompt.clone();
        batch.extend(vec![0i32; 8]);
        let rows = sim_decode(&spec, &batch, 8);
        assert_eq!(rows[0].len(), spec.dec_len, "stuck row runs the full dec_len");
        assert!(!rows[0].contains(&EOS), "stuck row never emits EOS");

        let mut engine = Engine::Sim(SimEngine::new(spec.clone(), 0));
        let mut state = engine.init_slots(2).unwrap();
        engine.prefill(&mut state, &prompt, 8, &[0]).unwrap();
        let live = vec![true, false];
        let mut stream = Vec::new();
        for _ in 0..spec.dec_len {
            stream.push(engine.decode_token(&mut state, &live).unwrap()[0]);
        }
        assert_eq!(stream, rows[0], "slot stream == monolithic stuck row");
    }

    /// §L8 core invariant at the round level: driving the sim engine
    /// through `SpecDecoder` rounds yields exactly the plain
    /// `decode_token` stream, at every acceptance rate — reject-all,
    /// mixed, and accept-all.
    #[test]
    fn sim_spec_rounds_match_plain_stream() {
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        let plain = {
            let spec = quiet_spec();
            let mut engine = Engine::Sim(SimEngine::new(spec.clone(), 0));
            let mut state = engine.init_slots(2).unwrap();
            engine.prefill(&mut state, &prompt, 8, &[0]).unwrap();
            let live = vec![true, false];
            let mut stream = Vec::new();
            for _ in 0..spec.dec_len {
                let t = engine.decode_token(&mut state, &live).unwrap()[0];
                stream.push(t);
                if t == EOS {
                    break;
                }
            }
            stream
        };
        assert_eq!(*plain.last().unwrap(), EOS);

        for rate in [0.0, 0.5, 1.0] {
            let mut spec = quiet_spec();
            spec.draft.as_mut().unwrap().accept_rate = rate;
            let dec_len = spec.dec_len;
            let mut engine = Engine::Sim(SimEngine::new(spec, 0));
            let mut state = engine.init_slots(2).unwrap();
            engine.prefill(&mut state, &prompt, 8, &[0]).unwrap();
            let mut sd = SpecDecoder::new(3);
            let mut meter = SpecMeter::default();
            let live = vec![true, false];
            let mut stream = Vec::new();
            'rounds: for _ in 0..dec_len {
                let em =
                    sd.round(&mut engine, &mut state, &live, None, &mut meter, None).unwrap();
                assert!(em[1].is_empty(), "dead slot must emit nothing");
                assert!(!em[0].is_empty() && em[0].len() <= 3 + 1);
                for &t in &em[0] {
                    stream.push(t);
                    if t == EOS || stream.len() >= dec_len {
                        break 'rounds;
                    }
                }
            }
            assert_eq!(stream, plain, "spec stream != plain stream at rate {rate}");
            assert!(meter.verify_steps > 0 && meter.draft_steps == 3 * meter.verify_steps);
            assert_eq!(meter.drafted, 3 * meter.verify_steps);
            if rate == 0.0 {
                assert_eq!(meter.accepted, 0, "reject-all accepts nothing");
            }
            if rate == 1.0 {
                assert!(
                    (meter.acceptance_rate() - 1.0).abs() < 1e-12,
                    "accept-all accepts everything"
                );
            }
        }
    }

    /// §L8 acceptance sampling: exact at the extremes, bounded and
    /// deterministic in between, with a mean near the geometric-run
    /// expectation.
    #[test]
    fn sim_accept_len_sampling() {
        for pos in 0..20 {
            assert_eq!(sim_accept_len(0x1234, pos, 4, 1.0), 4, "rate 1.0 accepts all");
            assert_eq!(sim_accept_len(0x1234, pos, 4, 0.0), 0, "rate 0.0 rejects all");
        }
        assert_eq!(sim_accept_len(7, 3, 0, 1.0), 0, "gamma 0 accepts nothing");
        let mut seen = std::collections::BTreeSet::new();
        for pos in 0..200 {
            let a = sim_accept_len(0xABCDE, pos, 4, 0.75);
            assert!(a <= 4);
            assert_eq!(a, sim_accept_len(0xABCDE, pos, 4, 0.75), "deterministic");
            seen.insert(a);
        }
        assert!(seen.len() >= 3, "acceptance lengths too concentrated: {seen:?}");
        // Mean near α(1-α^γ)/(1-α) = 0.75(1-0.75^4)/0.25 ≈ 2.05.
        let total: usize = (0..2000).map(|p| sim_accept_len(0x5EED, p, 4, 0.75)).sum();
        let mean = total as f64 / 2000.0;
        assert!((1.6..=2.5).contains(&mean), "mean accept length {mean}");
    }

    /// §L9 capability detection: the sim opts in through its pool
    /// spec, and the flattened page-table operand lays out row-major
    /// with -1 in unmapped entries.
    #[test]
    fn paged_geometry_and_flatten_layout() {
        let mut spec = quiet_spec();
        spec.pool = Some(SimPoolSpec { page_size: 4, pool_pages: 12, prefix_cache: true });
        let engine = Engine::Sim(SimEngine::new(spec, 0));
        assert_eq!(engine.paged_geometry(), Some((4, 12, true)));
        let none = Engine::Sim(SimEngine::new(quiet_spec(), 0));
        assert_eq!(none.paged_geometry(), None, "no pool spec: monolithic fallback");

        let mut pool = PagePool::new(4, 8);
        let mut t0 = PageTable::new();
        assert!(t0.ensure(&mut pool, 2));
        let mut t1 = PageTable::new();
        assert!(t1.ensure(&mut pool, 1));
        let flat = flatten_page_tables(&[t0, t1], &[0, 1], 3);
        assert_eq!(flat, vec![0, 1, -1, 2, -1, -1]);
        let pool_dim = pool.capacity();
        assert!(flat.iter().all(|&p| p == -1 || (p as usize) < pool_dim));
    }

    /// §L9 sim parity at the engine level: the paged prefill (with
    /// prefix-covered tokens skipped) and paged decode steps emit the
    /// exact stream of the monolithic path — saved work never changes
    /// tokens.
    #[test]
    fn sim_paged_prefill_stream_matches_monolithic() {
        let spec = quiet_spec();
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        let run = |paged: bool| {
            let mut engine = Engine::Sim(SimEngine::new(spec.clone(), 0));
            let mut state = engine.init_slots(2).unwrap();
            if paged {
                // 4 of the 8 prompt tokens covered by prefix hits.
                engine.prefill_paged(&mut state, &prompt, 8, &[0], &[0, 1, 2], 4).unwrap();
            } else {
                engine.prefill(&mut state, &prompt, 8, &[0]).unwrap();
            }
            let live = vec![true, false];
            let mut stream = Vec::new();
            for _ in 0..spec.dec_len {
                let t = if paged {
                    engine.decode_token_paged(&mut state, &live, &[0, 1, 2]).unwrap()[0]
                } else {
                    engine.decode_token(&mut state, &live).unwrap()[0]
                };
                stream.push(t);
                if t == EOS {
                    break;
                }
            }
            stream
        };
        assert_eq!(run(true), run(false), "paged stream == monolithic stream");
    }

    /// §L8 capability detection + the no-draft error paths.
    #[test]
    fn engine_spec_support_requires_draft() {
        let with = Engine::Sim(SimEngine::new(quiet_spec(), 0));
        assert_eq!(with.effective_spec_gamma(4), 4);
        assert_eq!(with.effective_spec_gamma(0), 0, "gamma 0 never speculates");

        let mut spec = quiet_spec();
        spec.draft = None;
        let mut without = Engine::Sim(SimEngine::new(spec, 0));
        assert_eq!(without.effective_spec_gamma(4), 0);
        let mut state = without.init_slots(1).unwrap();
        assert!(without.draft_tokens(&mut state, &[false], 2).is_err());
        assert!(without.verify(&mut state, &[Vec::new()], &[false], 2).is_err());
    }

    /// §L8 γ resolution on the real backend: the requested γ when its
    /// verify HLO exists, the artifact's compiled `DraftSpec::gamma`
    /// as the fallback, and 0 (plain decode) without a draft session.
    #[test]
    fn real_engine_spec_gamma_resolution() {
        use crate::runtime::artifact::DraftSpec;
        use crate::runtime::params::tests::toy_artifact;
        let client = Client::cpu().unwrap();
        let mut a = toy_artifact();
        a.hlo_files.push(("verify@4".into(), std::path::PathBuf::from("/nonexistent")));
        a.draft = Some(DraftSpec { artifact: "toy-lite".into(), gamma: 4 });
        let session = Session::open_eval(&client, a, 0).unwrap();
        let dsession = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        let engine = Engine::Real { client, session, draft: Some(dsession) };
        assert_eq!(engine.effective_spec_gamma(4), 4, "exact verify@4 HLO wins");
        assert_eq!(
            engine.effective_spec_gamma(2),
            4,
            "no verify@2: falls back to the artifact's compiled gamma"
        );
        assert_eq!(engine.effective_spec_gamma(0), 0, "speculation stays opt-in");
        let Engine::Real { client, session, .. } = engine else { unreachable!() };
        let engine = Engine::Real { client, session, draft: None };
        assert_eq!(engine.effective_spec_gamma(4), 0, "no draft session: plain decode");
    }

    /// The deterministic kill fault must fire as a panic on exactly the
    /// configured engine call, and only on the configured replica id.
    #[test]
    fn sim_kill_fault_panics_on_configured_call() {
        let mut spec = quiet_spec();
        spec.fault.kill_replica = Some(3);
        spec.fault.kill_after_calls = 2;
        let run = |replica: usize| {
            catch_unwind(AssertUnwindSafe(|| {
                let mut engine = Engine::Sim(SimEngine::new(spec.clone(), replica));
                let mut state = engine.init_slots(1).unwrap();
                let prompt = vec![9i32, 2, 4, 0];
                engine.prefill(&mut state, &prompt, 4, &[0]).unwrap(); // call 1
                engine.decode_token(&mut state, &[true]).unwrap(); // call 2
            }))
        };
        assert!(run(0).is_ok(), "non-matching replica id serves cleanly");
        assert!(run(3).is_err(), "matching replica id panics at call 2");
    }

    /// The in-flight ledger: admit/take/drain, and drain returns
    /// exactly what was never taken (the crash-recovery contract).
    #[test]
    fn ledger_tracks_in_flight_requests() {
        let ledger = Ledger::new();
        let (tx, _rx) = mpsc::channel();
        let t1 = ledger.admit(8, 0, Request::new(vec![1, 2], tx.clone()));
        let t2 = ledger.admit(16, 1, Request::new(vec![3], tx.clone()));
        let t3 = ledger.admit(8, 0, Request::new(vec![4, 5, 6], tx));
        assert_ne!(t1, t2);
        let held = ledger.take(t2).expect("present");
        assert_eq!(held.bucket, 16);
        assert_eq!(held.attempts, 1);
        assert_eq!(held.req.enc_tokens, vec![3]);
        assert!(ledger.take(t2).is_none(), "take is exactly-once");
        let mut rest = ledger.drain();
        rest.sort_by_key(|h| h.req.enc_tokens.len());
        assert_eq!(rest.len(), 2);
        assert_eq!(rest[0].req.enc_tokens, vec![1, 2]);
        assert_eq!(rest[1].req.enc_tokens, vec![4, 5, 6]);
        let _ = t3;
        assert!(ledger.drain().is_empty(), "drain empties the ledger");
    }

    /// Explicit failure responses: terminal, empty, reasoned, counted.
    #[test]
    fn fail_request_sends_terminal_response_and_counts() {
        let mut stats = ServerStats::default();
        let (tx, rx) = mpsc::channel();
        let req = Request::new(vec![1, 2, 3], tx);
        fail_request(&mut stats, &req, FailReason::DeadlineExceeded, ROUTER_ID);
        let resp = rx.recv().expect("terminal response delivered");
        assert!(resp.is_failure());
        assert_eq!(resp.failure, Some(FailReason::DeadlineExceeded));
        assert!(resp.tokens.is_empty());
        assert_eq!(resp.replica, ROUTER_ID);
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.sheds, 1);

        // Non-deadline failures count in failed but not sheds.
        let (tx, rx) = mpsc::channel();
        let req = Request::new(vec![7], tx);
        fail_request(&mut stats, &req, FailReason::RetriesExhausted, ROUTER_ID);
        assert_eq!(rx.recv().unwrap().failure, Some(FailReason::RetriesExhausted));
        assert_eq!(stats.failed, 2);
        assert_eq!(stats.sheds, 1);
        // §L10 admission rejections are sheds too, and land on the
        // per-tenant meter of the request's tenant.
        let (tx, rx) = mpsc::channel();
        let req = Request::for_tenant(vec![8], tx, 1, 0);
        fail_request(&mut stats, &req, FailReason::QueueFull, ROUTER_ID);
        assert_eq!(rx.recv().unwrap().failure, Some(FailReason::QueueFull));
        assert_eq!(stats.failed, 3);
        assert_eq!(stats.sheds, 2);
        assert_eq!(stats.tenants[1].failed, 1);
        assert_eq!(stats.tenants[1].sheds, 1);
        // Every reason renders a non-empty human message.
        for reason in [
            FailReason::DeadlineExceeded,
            FailReason::RetriesExhausted,
            FailReason::NoReplicas,
            FailReason::AbortedOnDrain,
            FailReason::PoolExhausted,
            FailReason::QueueFull,
            FailReason::WouldMissDeadline,
        ] {
            assert!(!reason.to_string().is_empty());
        }
    }

    #[test]
    fn request_deadline_expiry() {
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        let req = Request::with_deadline(vec![1], tx.clone(), now + Duration::from_secs(60));
        assert!(!req.expired(now));
        assert!(req.expired(now + Duration::from_secs(61)));
        let no_deadline = Request::new(vec![1], tx);
        assert!(!no_deadline.expired(now + Duration::from_secs(3600)));
    }

    #[test]
    fn sim_gen_lengths_cover_the_range() {
        // EOS-distributed lengths: over many prompts the sampled
        // generation lengths must span [1, dec_len], not collapse.
        let dec_len = 8;
        let mut seen = std::collections::BTreeSet::new();
        for p in 0..200u64 {
            let h = sim_row_hash(&[(p as i32) + 1, 7, 9]);
            let g = sim_gen_len(h, dec_len);
            assert!((1..=dec_len).contains(&g));
            seen.insert(g);
        }
        assert!(seen.len() >= dec_len / 2, "lengths too concentrated: {seen:?}");
    }

    #[test]
    fn truncate_at_eos_is_inclusive_and_idempotent() {
        let mut row = vec![5, 9, EOS, 7, 8];
        truncate_at_eos(&mut row);
        assert_eq!(row, vec![5, 9, EOS]);
        truncate_at_eos(&mut row);
        assert_eq!(row, vec![5, 9, EOS]);
        let mut none = vec![5, 9, 7];
        truncate_at_eos(&mut none);
        assert_eq!(none, vec![5, 9, 7], "no EOS: row untouched");
    }

    #[test]
    fn server_stats_merge_waste_and_percentiles() {
        let mut a = ServerStats {
            requests: 4,
            batches: 2,
            total_fill: 4,
            replicas: 1,
            prompt_tokens: 40,
            executed_tokens: 64,
            truncated: 1,
            ..Default::default()
        };
        for ms in [1.0, 2.0, 3.0, 4.0] {
            a.latency.record(ms);
        }
        let mut b = ServerStats {
            requests: 2,
            batches: 1,
            total_fill: 2,
            replicas: 1,
            prompt_tokens: 10,
            executed_tokens: 36,
            truncated: 0,
            tokens_generated: 30,
            tokens_saved: 10,
            decode_steps: 5,
            prefills: 2,
            sheds: 1,
            retries: 2,
            restarts: 1,
            failed: 3,
            drained: 4,
            ..Default::default()
        };
        b.latency.record(10.0);
        b.latency.record(20.0);
        b.occupancy.record(4);
        a.merge(&b);
        assert_eq!(a.requests, 6);
        assert_eq!(a.batches, 3);
        assert_eq!(a.replicas, 2);
        assert_eq!(a.truncated, 1);
        assert_eq!(a.tokens_generated, 30);
        assert_eq!(a.tokens_saved, 10);
        assert_eq!(a.decode_steps, 5);
        assert_eq!(a.prefills, 2);
        assert_eq!(a.sheds, 1);
        assert_eq!(a.retries, 2);
        assert_eq!(a.restarts, 1);
        assert_eq!(a.failed, 3);
        assert_eq!(a.drained, 4);
        assert!(a.summary().contains("faults:"), "fault counters surface in the summary");
        assert!((a.early_exit_ratio() - 0.25).abs() < 1e-12);
        assert_eq!(a.occupancy.steps(), 1);
        assert_eq!(a.latency_count(), 6);
        assert!((a.waste_ratio() - 0.5).abs() < 1e-12, "50/100 executed tokens were padding");
        // Log-bucketed estimates: within the histogram's ~9% error.
        let p50 = a.p50_ms();
        assert!((p50 - 3.0).abs() / 3.0 < 0.10, "p50={p50}");
        let p100 = a.latency_percentile_ms(100.0);
        assert!((p100 - 20.0).abs() / 20.0 < 0.10, "p100={p100}");
        assert_eq!(ServerStats::default().waste_ratio(), 0.0);
        assert_eq!(ServerStats::default().p99_ms(), 0.0);
        assert_eq!(ServerStats::default().early_exit_ratio(), 0.0);
        assert!(
            !ServerStats::default().summary().contains("faults:"),
            "fault-free summary stays compact"
        );
    }

    #[test]
    fn note_response_accounting() {
        let mut s = ServerStats::default();
        s.note_response(Duration::from_millis(10), 5, 3, 7, true);
        assert_eq!(s.tokens_generated, 5);
        assert_eq!(s.tokens_saved, 3);
        assert_eq!(s.prompt_tokens, 7);
        assert_eq!(s.truncated, 1);
        assert_eq!(s.latency_count(), 1);
        assert_eq!(s.token_latency.count(), 1);
        let per_tok = s.token_ms();
        assert!((per_tok - 2.0).abs() / 2.0 < 0.10, "10ms/5tok ~ 2ms: {per_tok}");
        // Zero generated tokens must not divide by zero.
        s.note_response(Duration::from_millis(1), 0, 0, 0, false);
        assert_eq!(s.token_latency.count(), 2);
    }
}

