//! The per-replica serving loops: the `Engine` decode backend
//! (real `Session` or sim), slot state, the batch-level and
//! continuous-batching disciplines, and paged-pool serving. Split out
//! of the old monolithic `coordinator/server.rs` — paths are
//! preserved via re-exports in `server/mod.rs`.

use super::*;

/// The per-replica decode backend (built inside the replica thread:
/// `Session` is !Send). `pub(crate)` so `coordinator::spec` can drive
/// the §L8 draft/verify round; not part of the public API.
pub(crate) enum Engine {
    Real {
        client: Client,
        session: Session,
        /// §L8 draft-model session, loaded from the artifact's
        /// meta.json `draft` entry when speculation is requested.
        draft: Option<Session>,
    },
    Sim(SimEngine),
    /// §L12: a `tp`-way tensor-parallel execution group — one fleet
    /// unit whose shards run one sharded model in lockstep. Boxed:
    /// the group embeds a leader `Engine`, and the unsharded variants
    /// should not pay its size.
    Group(Box<ShardGroup>),
}

/// §L12 execution group: shard 0 (the leader) plus `tp - 1` follower
/// shards, driven in lockstep by ONE replica thread. The leader owns
/// the group's cost model and produces the tokens (identical on every
/// shard by the sharding contract); followers exist to model/execute
/// their shard's half of each step — in the sim, that means advancing
/// their fault clocks so an injected shard kill panics the whole
/// thread, which is exactly how the §L7 supervisor comes to treat the
/// group as one atomic crash/requeue/respawn unit.
pub(crate) struct ShardGroup {
    /// Shard 0: a whole `Engine` (never itself a `Group`) whose spec
    /// carries the sharded per-shard costs (`SimSpec::sharded_leader`)
    /// or whose session is bound to shard 0 of the artifact.
    pub(crate) leader: Engine,
    pub(crate) followers: Vec<ShardFollower>,
    /// Group width; `followers.len() + 1`.
    pub(crate) tp: usize,
    /// The link/width cost model collective time is charged from.
    pub(crate) coll: CollectiveSpec,
    /// All-reduce rounds this group has executed (exported into
    /// `ServerStats::collectives` when the serving loop exits).
    pub(crate) collectives: u64,
    /// Simulated ns spent in those rounds (`ServerStats::collective_ns`).
    pub(crate) collective_ns: u64,
}

/// One non-leader shard of an execution group.
pub(crate) enum ShardFollower {
    /// Sim shard: ticks its engine-call clock in lockstep with the
    /// leader so deterministic fault schedules can target any shard.
    Sim(SimEngine),
    /// Real shard: a session bound (`Session::bind_shard`) to this
    /// shard's executables. Held for the group's lifetime; the shard
    /// executables' own collectives synchronize it with the leader.
    #[allow(dead_code)]
    Real { client: Client, session: Session },
}

impl ShardGroup {
    /// Advance every sim follower's engine-call clock in lockstep with
    /// the leader call about to execute. A follower whose fault
    /// schedule fires here panics the whole replica thread — one shard
    /// dying takes the group down atomically, so its ledger requeues
    /// as one unit and no half-group response can escape.
    fn tick_followers(&mut self) {
        for f in self.followers.iter_mut() {
            if let ShardFollower::Sim(e) = f {
                e.on_call();
            }
        }
    }

    /// Charge one sharded step's collective time over `tokens` fused
    /// token positions: counters always; simulated wall-clock only on
    /// the sim backend (a real backend pays its collectives inside the
    /// shard executables themselves).
    fn sync(&mut self, tokens: usize) {
        self.sync_steps(1, tokens);
    }

    /// `steps` sharded steps of `tokens` fused positions each, charged
    /// as one wait (the monolithic-decode fallback runs its whole
    /// token loop inside a single engine call).
    fn sync_steps(&mut self, steps: u64, tokens: usize) {
        let ns = self.coll.step_collective_ns(self.tp, tokens).saturating_mul(steps);
        self.collectives += (self.coll.syncs_per_step as u64).saturating_mul(steps);
        self.collective_ns += ns;
        if matches!(self.leader, Engine::Sim(_)) {
            sim_sleep(ns);
        }
    }
}

/// Per-replica slot state for the continuous path: device-resident KV
/// buffers for the real backend, per-slot decode cursors for the sim.
pub(crate) enum SlotState {
    Real {
        /// `Option` so the `DecodeSlots` can be moved through the
        /// donating `Session::prefill`/`decode_token`/`verify` calls
        /// and put back.
        main: Option<DecodeSlots>,
        /// §L8 draft-model slot state, kept prefix-synced with `main`
        /// by `draft_accept` after every verify. `None` when the
        /// engine carries no draft session.
        draft: Option<DecodeSlots>,
    },
    Sim(Vec<Option<SimSlot>>),
}

/// §L8 γ resolution against a (real-backend) session — the single
/// predicate shared by the draft loader (`Engine::build`) and the
/// serve-time activation check (`Engine::effective_spec_gamma`): the
/// requested γ when the artifact ships `verify@<requested>`, else the
/// artifact's compiled `DraftSpec::gamma`, else 0 (plain decode).
pub(crate) fn resolve_spec_gamma(session: &Session, requested: usize) -> usize {
    if requested == 0 {
        return 0;
    }
    let Some(d) = &session.artifact.draft else { return 0 };
    if session.has_verify(requested) {
        requested
    } else if session.has_verify(d.gamma) {
        d.gamma
    } else {
        0
    }
}


impl Engine {
    /// Build the decode backend for one fleet unit. `tp >= 2` asks for
    /// a §L12 execution group of that width; when the spec cannot
    /// honor it (a real artifact without a matching sharded contract),
    /// the unit silently degrades to a whole-model single engine —
    /// sharding changes timing, never outputs.
    pub(crate) fn build(
        replica: usize,
        spec: &EngineSpec,
        opts: &ServerOptions,
        tp: usize,
    ) -> Result<Engine> {
        if tp >= 2 {
            if let Some(group) = Engine::build_group(replica, spec, opts, tp)? {
                return Ok(group);
            }
        }
        match spec {
            EngineSpec::Artifact { name } => Engine::build_real(name, opts, None),
            EngineSpec::Sim(s) => Ok(Engine::Sim(SimEngine::new(s.clone(), replica))),
        }
    }

    /// §L12 group construction. `None` means the spec ships no
    /// matching `tp`-way contract and the caller should fall back to a
    /// whole-model single engine. The sim always honors the request —
    /// the leader gets the sharded per-shard cost spec
    /// (`SimSpec::sharded_leader`) and each shard sees its slice of
    /// the fault schedule (`FaultSpec::for_shard`); the real backend
    /// honors it only when the artifact declares `sharding.tp == tp`
    /// and ships every shard's split-decode executables.
    fn build_group(
        replica: usize,
        spec: &EngineSpec,
        opts: &ServerOptions,
        tp: usize,
    ) -> Result<Option<Engine>> {
        match spec {
            EngineSpec::Sim(s) => {
                let mut lead = s.sharded_leader(tp);
                lead.fault = s.fault.for_shard(0, tp);
                let leader = Engine::Sim(SimEngine::new_shard(lead, replica, 0));
                let followers = (1..tp)
                    .map(|i| {
                        let mut fs = s.clone();
                        fs.fault = s.fault.for_shard(i, tp);
                        ShardFollower::Sim(SimEngine::new_shard(fs, replica, i))
                    })
                    .collect();
                Ok(Some(Engine::Group(Box::new(ShardGroup {
                    leader,
                    followers,
                    tp,
                    coll: s.collective.clone(),
                    collectives: 0,
                    collective_ns: 0,
                }))))
            }
            EngineSpec::Artifact { name } => {
                let artifact = load_named(name)?;
                if artifact.sharding.as_ref().map(|s| s.tp) != Some(tp) {
                    return Ok(None);
                }
                let leader = Engine::build_real(name, opts, Some(0))?;
                let sharded_ok = match &leader {
                    Engine::Real { session, .. } => session.has_sharded_decode(tp),
                    _ => false,
                };
                if !sharded_ok {
                    // Declared but incomplete shard manifest: degrade
                    // to whole-model rather than erroring (the leader
                    // built above compiled only fallback executables,
                    // so it is exactly a whole-model engine — reuse it).
                    return Ok(None);
                }
                let mut followers = Vec::with_capacity(tp - 1);
                for i in 1..tp {
                    let fclient = Client::cpu()?;
                    let fartifact = load_named(name)?;
                    let mut fsession = Session::open_eval(&fclient, fartifact, opts.seed)?;
                    fsession.bind_shard(i);
                    if let Some(ckpt) = &opts.checkpoint {
                        fsession.store =
                            crate::runtime::params::ParamStore::load(ckpt, &fsession.artifact)?;
                        fsession.invalidate_state();
                    }
                    fsession.ensure_decode(&fclient)?;
                    fsession.warm_device_cache(&fclient)?;
                    followers.push(ShardFollower::Real { client: fclient, session: fsession });
                }
                Ok(Some(Engine::Group(Box::new(ShardGroup {
                    leader,
                    followers,
                    tp,
                    coll: CollectiveSpec::from_env(),
                    collectives: 0,
                    collective_ns: 0,
                }))))
            }
        }
    }

    /// Build a real-backend engine. `shard` binds the session (and its
    /// draft) to one shard of the §L12 contract before any serving
    /// executable is compiled; `None` is the ordinary whole-model path.
    fn build_real(name: &str, opts: &ServerOptions, shard: Option<usize>) -> Result<Engine> {
        let client = Client::cpu()?;
        let artifact = load_named(name)?;
        let mut session = Session::open_eval(&client, artifact, opts.seed)?;
        if let Some(s) = shard {
            session.bind_shard(s);
        }
        if let Some(ckpt) = &opts.checkpoint {
            session.store = crate::runtime::params::ParamStore::load(ckpt, &session.artifact)?;
            session.invalidate_state();
        }
        session.ensure_decode(&client)?;
        // §Perf L4: upload the weights once; every batch reuses
        // the device-resident buffers.
        session.warm_device_cache(&client)?;
        // §L8: load the draft session only when speculation
        // will actually engage (`resolve_spec_gamma` — the
        // same predicate `effective_spec_gamma` applies at
        // serve time, so "draft loaded" and "speculation runs"
        // cannot drift apart) — otherwise the replica serves
        // plain decode and must not pay draft memory/prefill
        // for nothing. A named draft that fails to load or
        // mismatches the serving geometry is a real error.
        let draft = match &session.artifact.draft {
            Some(d) if resolve_spec_gamma(&session, opts.spec_gamma) > 0 => {
                let dartifact = load_named(&d.artifact)?;
                let (mc, dc) = (&session.artifact.config, &dartifact.config);
                if dc.enc_len != mc.enc_len
                    || dc.dec_len != mc.dec_len
                    || dc.vocab_size != mc.vocab_size
                {
                    bail!(
                        "draft artifact {} geometry mismatch: enc_len {} vs {}, \
                         dec_len {} vs {}, vocab {} vs {} (the draft must share \
                         the main artifact's serving geometry)",
                        d.artifact,
                        dc.enc_len,
                        mc.enc_len,
                        dc.dec_len,
                        mc.dec_len,
                        dc.vocab_size,
                        mc.vocab_size
                    );
                }
                let mut dsession = Session::open_eval(&client, dartifact, opts.seed)?;
                if let Some(s) = shard {
                    // §L12: the replicated draft still binds, so a
                    // draft that DOES ship shard variants routes to
                    // them; absent variants fall back whole-model.
                    dsession.bind_shard(s);
                }
                if !dsession.has_split_decode() {
                    bail!("draft artifact {} ships no split-decode HLO pair", d.artifact);
                }
                dsession.warm_device_cache(&client)?;
                Some(dsession)
            }
            _ => None,
        };
        Ok(Engine::Real { client, session, draft })
    }

    /// §L12: this unit's group width (1 for ordinary single engines)
    /// — the number of devices it occupies.
    pub(crate) fn group_tp(&self) -> usize {
        match self {
            Engine::Group(g) => g.tp,
            _ => 1,
        }
    }

    /// §L12: (all-reduce rounds, simulated collective ns) this engine
    /// has accumulated; (0, 0) for single engines. Exported into
    /// `ServerStats` when a serving loop exits cleanly.
    pub(crate) fn collective_totals(&self) -> (u64, u64) {
        match self {
            Engine::Group(g) => (g.collectives, g.collective_ns),
            _ => (0, 0),
        }
    }

    /// (batch_size, enc_len) of the serving geometry.
    pub(crate) fn dims(&self) -> (usize, usize) {
        match self {
            Engine::Real { session, .. } => {
                (session.artifact.config.batch_size, session.artifact.config.enc_len)
            }
            Engine::Sim(e) => (e.spec.batch_size, e.spec.enc_len),
            Engine::Group(g) => g.leader.dims(),
        }
    }

    /// Maximum tokens a request may generate.
    pub(crate) fn dec_len(&self) -> usize {
        match self {
            Engine::Real { session, .. } => session.artifact.config.dec_len,
            Engine::Sim(e) => e.spec.dec_len,
            Engine::Group(g) => g.leader.dec_len(),
        }
    }

    /// Whether this engine can run the split prefill/decode_token
    /// discipline (the artifact ships the HLO pair — monolithic-slot
    /// or §L9 paged; the sim can opt out to exercise the fallback).
    pub(crate) fn supports_continuous(&self) -> bool {
        match self {
            Engine::Real { session, .. } => {
                session.has_split_decode() || session.has_paged_decode()
            }
            Engine::Sim(e) => e.spec.split_decode,
            Engine::Group(g) => g.leader.supports_continuous(),
        }
    }

    /// §L9: the paged serving geometry — `(page_size, pool_pages,
    /// prefix_cache)` — when this engine carries the paged decode
    /// contract. `None` means the replica serves monolithic
    /// `DecodeSlots` (the documented fallback). The real backend reads
    /// pool capacity from `ALTUP_POOL_PAGES` (default: the monolithic
    /// batch's worth of pages) and the prefix-cache switch from
    /// `ALTUP_PREFIX_CACHE`; the sim carries both in its spec.
    pub(crate) fn paged_geometry(&self) -> Option<(usize, usize, bool)> {
        match self {
            Engine::Real { session, .. } => {
                if !session.has_paged_decode() {
                    return None;
                }
                let page_size = session.page_size()?;
                let max_pages = session.max_pages().ok()?;
                let pool_pages = env::opt_u64_nonzero("ALTUP_POOL_PAGES")
                    .map_or(session.artifact.config.batch_size * max_pages, |v| v as usize);
                Some((page_size, pool_pages, env::usize_or("ALTUP_PREFIX_CACHE", 1) > 0))
            }
            Engine::Sim(e) => {
                e.spec.pool.as_ref().map(|p| (p.page_size, p.pool_pages, p.prefix_cache))
            }
            Engine::Group(g) => g.leader.paged_geometry(),
        }
    }

    /// The sequence length a monolithic job at `bucket` actually
    /// executes at (the real backend falls back to `enc_len` when the
    /// artifact has no shape-specialized HLO for the bucket).
    pub(crate) fn effective_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_bucket(bucket),
            Engine::Sim(e) => bucket.min(e.spec.enc_len),
            Engine::Group(g) => g.leader.effective_bucket(bucket),
        }
    }

    /// Same, for the split prefill family.
    pub(crate) fn effective_prefill_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_prefill_bucket(bucket),
            Engine::Sim(e) => bucket.min(e.spec.enc_len),
            Engine::Group(g) => g.leader.effective_prefill_bucket(bucket),
        }
    }

    /// Same, for the §L9 `prefill_paged` family.
    pub(crate) fn effective_paged_prefill_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_paged_prefill_bucket(bucket),
            Engine::Sim(e) => bucket.min(e.spec.enc_len),
            Engine::Group(g) => g.leader.effective_paged_prefill_bucket(bucket),
        }
    }

    /// Monolithic decode of a (batch_size, bucket) packed batch.
    pub(crate) fn decode(&mut self, enc: &[i32], bucket: usize) -> Result<Vec<Vec<i32>>> {
        if let Engine::Group(g) = self {
            g.tick_followers();
            let out = g.leader.decode(enc, bucket)?;
            // One sharded prefill over the packed batch, then one
            // sharded step per generated token over the batch rows —
            // the whole monolithic loop runs inside this single call.
            let (batch_size, _) = g.leader.dims();
            g.sync(batch_size * bucket);
            g.sync_steps(g.leader.dec_len() as u64, batch_size);
            return Ok(out);
        }
        match self {
            Engine::Real { client, session, .. } => {
                session.decode_bucketed(client, enc, bucket)
            }
            Engine::Sim(e) => {
                e.on_call();
                Ok(sim_decode(&e.spec, enc, bucket))
            }
            Engine::Group(_) => unreachable!("handled above"),
        }
    }

    /// Allocate the per-replica slot state for `n` concurrent requests
    /// (plus the mirrored draft-model slot state when speculating).
    pub(crate) fn init_slots(&mut self, n: usize) -> Result<SlotState> {
        match self {
            Engine::Real { client, session, draft } => {
                let main = Some(session.init_decode_slots(client, n)?);
                let draft = match draft {
                    Some(ds) => Some(ds.init_decode_slots(client, n)?),
                    None => None,
                };
                Ok(SlotState::Real { main, draft })
            }
            Engine::Sim(_) => Ok(SlotState::Sim(vec![None; n])),
            // §L12: the slot state lives with the leader (followers
            // hold their shard of the KV inside their own sessions on
            // the real backend; the sim followers hold no state).
            Engine::Group(g) => g.leader.init_slots(n),
        }
    }

    /// §L9: allocate the device-resident page pool (`pool_pages`
    /// physical pages) for `n` concurrent requests. The draft-model
    /// slot state stays monolithic — prefix reuse applies to the main
    /// model's KV, not the draft's.
    pub(crate) fn init_slots_paged(&mut self, n: usize, pool_pages: usize) -> Result<SlotState> {
        match self {
            Engine::Real { client, session, draft } => {
                let main = Some(session.init_paged_slots(client, pool_pages)?);
                let draft = match draft {
                    Some(ds) => Some(ds.init_decode_slots(client, n)?),
                    None => None,
                };
                Ok(SlotState::Real { main, draft })
            }
            Engine::Sim(_) => Ok(SlotState::Sim(vec![None; n])),
            Engine::Group(g) => g.leader.init_slots_paged(n, pool_pages),
        }
    }

    /// Prefill a same-bucket admission group, `enc` packed row-major at
    /// (slot_ids.len(), bucket), into slot rows `slot_ids`.
    pub(crate) fn prefill(
        &mut self,
        state: &mut SlotState,
        enc: &[i32],
        bucket: usize,
        slot_ids: &[usize],
    ) -> Result<()> {
        if let Engine::Group(g) = self {
            // Lockstep: every shard executes this prefill; the leader
            // produces the state/tokens, followers advance their fault
            // clocks, and the group pays one sharded step's collectives
            // over the admitted rows' token positions.
            g.tick_followers();
            g.leader.prefill(state, enc, bucket, slot_ids)?;
            g.sync(slot_ids.len() * bucket);
            return Ok(());
        }
        match (self, state) {
            (Engine::Real { client, session, draft }, SlotState::Real { main, draft: dslots }) => {
                let held = main
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let ids: Vec<i32> = slot_ids.iter().map(|&s| s as i32).collect();
                *main = Some(session.prefill(client, held, enc, bucket, &ids)?);
                // §L8: the draft model prefills the same prompts into
                // the same slot rows, so both KV caches start from an
                // identical prefix.
                if let Some(ds) = draft {
                    let dheld = dslots
                        .take()
                        .context("draft slot state lost after an earlier error")?;
                    *dslots = Some(ds.prefill(client, dheld, enc, bucket, &ids)?);
                }
                Ok(())
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let spec = &e.spec;
                for (row, &sid) in enc.chunks(bucket).zip(slot_ids.iter()) {
                    let h = sim_row_hash(row);
                    slots[sid] = Some(SimSlot {
                        h,
                        pos: 0,
                        gen_len: sim_gen_len(h, spec.dec_len),
                        stuck: spec.fault.stuck(h),
                    });
                }
                // Varlen-style split prefill: dispatch overhead + cost
                // over the admitted rows only (no dead padding rows).
                sim_sleep(
                    spec.dstep_ns
                        + spec.token_ns.saturating_mul((slot_ids.len() * bucket) as u64),
                );
                Ok(())
            }
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// §L9 paged prefill: like `prefill`, plus the group's flattened
    /// (rows, max_pages) page-table operand and the prompt tokens the
    /// prefix cache already covers. On the real backend shared prefix
    /// pages may be rewritten by the HLO — with bit-identical KV, since
    /// a prefix's KV depends only on its tokens — so sharing stays
    /// sound; the sim charges the compute saving (`saved_tokens` of
    /// per-token work skipped), which is what the twin and benches
    /// measure.
    pub(crate) fn prefill_paged(
        &mut self,
        state: &mut SlotState,
        enc: &[i32],
        bucket: usize,
        slot_ids: &[usize],
        page_table: &[i32],
        saved_tokens: usize,
    ) -> Result<()> {
        if let Engine::Group(g) = self {
            g.tick_followers();
            g.leader.prefill_paged(state, enc, bucket, slot_ids, page_table, saved_tokens)?;
            // Prefix-cache hits shrink the sharded step — and with it
            // the collective payload — exactly like the compute.
            g.sync((slot_ids.len() * bucket).saturating_sub(saved_tokens));
            return Ok(());
        }
        match (self, state) {
            (Engine::Real { client, session, draft }, SlotState::Real { main, draft: dslots }) => {
                let held = main
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let ids: Vec<i32> = slot_ids.iter().map(|&s| s as i32).collect();
                *main = Some(session.prefill_paged(client, held, enc, bucket, &ids, page_table)?);
                // §L8: the draft model's KV stays monolithic — same
                // prompts, same slot rows, no prefix sharing.
                if let Some(ds) = draft {
                    let dheld = dslots
                        .take()
                        .context("draft slot state lost after an earlier error")?;
                    *dslots = Some(ds.prefill(client, dheld, enc, bucket, &ids)?);
                }
                Ok(())
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let spec = &e.spec;
                for (row, &sid) in enc.chunks(bucket).zip(slot_ids.iter()) {
                    let h = sim_row_hash(row);
                    slots[sid] = Some(SimSlot {
                        h,
                        pos: 0,
                        gen_len: sim_gen_len(h, spec.dec_len),
                        stuck: spec.fault.stuck(h),
                    });
                }
                // Prefix hits skip their covered prompt tokens: the
                // varlen prefill runs `rows*bucket - saved` tokens'
                // worth of work. Tokens still derive from the full row
                // hash — output parity with the unpaged path is by
                // construction.
                sim_sleep(
                    spec.dstep_ns
                        + spec.token_ns.saturating_mul(
                            (slot_ids.len() * bucket).saturating_sub(saved_tokens) as u64,
                        ),
                );
                Ok(())
            }
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// One fused decode iteration over the whole slot geometry:
    /// advances every slot with `live[s] == true` by one token and
    /// returns the (slots,) token row (dead rows carry garbage).
    pub(crate) fn decode_token(&mut self, state: &mut SlotState, live: &[bool]) -> Result<Vec<i32>> {
        if let Engine::Group(g) = self {
            g.tick_followers();
            let out = g.leader.decode_token(state, live)?;
            // The fused step runs the full static slot geometry, so
            // the activation payload crossing the links does too. This
            // is where AltUp's narrow active block pays off: per-token
            // bytes are `active_width`, not `d_model`.
            g.sync(live.len());
            return Ok(out);
        }
        match (self, state) {
            (Engine::Real { client, session, .. }, SlotState::Real { main, .. }) => {
                let held = main
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let (held, tokens) = session.decode_token(client, held, live)?;
                *main = Some(held);
                Ok(tokens)
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let spec = &e.spec;
                let mut out = vec![0i32; slots.len()];
                let mut stuck_live = 0u64;
                for (s, slot) in slots.iter_mut().enumerate() {
                    if !live[s] {
                        continue;
                    }
                    let sl = slot.as_mut().context("live mask set on an empty sim slot")?;
                    out[s] = sl.token_at(sl.pos, spec.vocab_size, spec.bad_token_salt);
                    sl.pos += 1;
                    if sl.stuck {
                        stuck_live += 1;
                    }
                }
                // Fused step over the full static slot geometry; stuck
                // rows are also slow rows.
                sim_sleep(
                    spec.dstep_ns
                        + spec.dtoken_ns.saturating_mul(slots.len() as u64)
                        + spec.fault.stuck_step_ns.saturating_mul(stuck_live),
                );
                Ok(out)
            }
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// §L9 paged decode iteration: `decode_token` with the flattened
    /// (slots, max_pages) page-table operand. The sim delegates to the
    /// monolithic step — the slot-to-page mapping is host-side
    /// bookkeeping there, and decode cost is per live row either way.
    pub(crate) fn decode_token_paged(
        &mut self,
        state: &mut SlotState,
        live: &[bool],
        page_table: &[i32],
    ) -> Result<Vec<i32>> {
        if let Engine::Group(g) = self {
            g.tick_followers();
            let out = g.leader.decode_token_paged(state, live, page_table)?;
            g.sync(live.len());
            return Ok(out);
        }
        if let Engine::Real { client, session, .. } = self {
            let SlotState::Real { main, .. } = state else {
                bail!("engine/slot-state backend mismatch");
            };
            let held = main
                .take()
                .context("slot state lost after an earlier prefill/decode error")?;
            let (held, tokens) = session.decode_token_paged(client, held, live, page_table)?;
            *main = Some(held);
            return Ok(tokens);
        }
        self.decode_token(state, live)
    }

    /// §L8: the draft length this engine will actually speculate at
    /// for a requested `--spec-gamma` (`resolve_spec_gamma` on the
    /// real backend — requested γ, or the artifact's compiled
    /// fallback). 0 means speculation is unavailable (no draft
    /// session, no runnable verify, or not requested) and the replica
    /// silently runs plain decode — the documented fallback.
    pub(crate) fn effective_spec_gamma(&self, requested: usize) -> usize {
        match self {
            Engine::Real { session, draft, .. } => {
                if draft.is_none() {
                    0
                } else {
                    resolve_spec_gamma(session, requested)
                }
            }
            Engine::Sim(e) => {
                // The sim has no compiled-γ constraint: any requested
                // length runs, given a draft cost model.
                if requested > 0 && e.spec.draft.is_some() {
                    requested
                } else {
                    0
                }
            }
            Engine::Group(g) => g.leader.effective_spec_gamma(requested),
        }
    }

    /// §L8: draft `gamma` tokens per live slot — γ cheap draft-model
    /// decode steps. Returns one row per slot; dead slots get empty
    /// rows. The draft state runs ahead speculatively; `verify`
    /// re-syncs it to what the full model accepts.
    pub(crate) fn draft_tokens(
        &mut self,
        state: &mut SlotState,
        live: &[bool],
        gamma: usize,
    ) -> Result<Vec<Vec<i32>>> {
        if let Engine::Group(g) = self {
            // §L12: the draft is replicated per shard (recycled AltUp
            // drafts are predict/correct-cheap — the paper's
            // asymmetry), so drafting needs NO collective: every shard
            // drafts the same γ tokens locally. Followers still tick —
            // their devices run the draft steps too.
            g.tick_followers();
            return g.leader.draft_tokens(state, live, gamma);
        }
        match (self, state) {
            (
                Engine::Real { client, draft: Some(ds), .. },
                SlotState::Real { draft: dslots, .. },
            ) => {
                let mut out: Vec<Vec<i32>> = vec![Vec::new(); live.len()];
                for _ in 0..gamma {
                    let held = dslots
                        .take()
                        .context("draft slot state lost after an earlier error")?;
                    let (held, toks) = ds.decode_token(client, held, live)?;
                    *dslots = Some(held);
                    for (s, row) in out.iter_mut().enumerate() {
                        if live[s] {
                            row.push(toks[s]);
                        }
                    }
                }
                Ok(out)
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let Some(d) = e.spec.draft.as_ref() else {
                    bail!("sim spec ships no draft model");
                };
                let mut out: Vec<Vec<i32>> = vec![Vec::new(); slots.len()];
                for (s, slot) in slots.iter().enumerate() {
                    if !live[s] {
                        continue;
                    }
                    let sl = slot.as_ref().context("live mask set on an empty sim slot")?;
                    out[s] = (0..gamma)
                        .map(|j| sl.token_at(sl.pos + j, e.spec.vocab_size, e.spec.bad_token_salt))
                        .collect();
                }
                // γ draft steps over the static slot geometry, charged
                // as one wait. The sim drafts the TRUE greedy tokens;
                // draft fallibility is modeled in `verify`'s acceptance
                // sampling instead, which mirrors the real guarantee
                // that accepted tokens are exactly the full model's.
                sim_sleep((gamma as u64).saturating_mul(
                    d.dstep_ns + d.dtoken_ns.saturating_mul(slots.len() as u64),
                ));
                Ok(out)
            }
            (Engine::Real { draft: None, .. }, _) => bail!("engine has no draft session"),
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// §L8: one fused verify across all live slots — the full model
    /// scores the drafted tokens in a single step, each live slot
    /// advances by its accepted prefix + 1 correction token, and (real
    /// backend) the draft state re-syncs via `draft_accept`. Returns
    /// per-slot `(accept_len, correction)` rows.
    pub(crate) fn verify(
        &mut self,
        state: &mut SlotState,
        drafted: &[Vec<i32>],
        live: &[bool],
        gamma: usize,
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        if let Engine::Group(g) = self {
            // One fused sharded verify step — scoring γ+1 positions is
            // one weight-bound pass, so one step's collectives.
            g.tick_followers();
            let out = g.leader.verify(state, drafted, live, gamma)?;
            g.sync(live.len());
            return Ok(out);
        }
        match (self, state) {
            (
                Engine::Real { client, session, draft: Some(ds) },
                SlotState::Real { main, draft: dslots },
            ) => {
                // Flatten to the (S, γ) geometry the HLO expects; dead
                // rows pad with zeros (ignored under the live mask).
                let mut flat = vec![0i32; live.len() * gamma];
                for (s, row) in drafted.iter().enumerate() {
                    let n = row.len().min(gamma);
                    flat[s * gamma..s * gamma + n].copy_from_slice(&row[..n]);
                }
                let held = main
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let (held, accept, correction) =
                    session.verify(client, held, &flat, live, gamma)?;
                *main = Some(held);
                let dheld = dslots
                    .take()
                    .context("draft slot state lost after an earlier error")?;
                *dslots = Some(ds.spec_accept(client, dheld, &accept, &correction, live)?);
                Ok((accept, correction))
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let spec = &e.spec;
                let Some(d) = spec.draft.as_ref() else {
                    bail!("sim spec ships no draft model");
                };
                let mut accept = vec![0i32; slots.len()];
                let mut correction = vec![0i32; slots.len()];
                let mut stuck_live = 0u64;
                for (s, slot) in slots.iter_mut().enumerate() {
                    if !live[s] {
                        continue;
                    }
                    let sl = slot.as_mut().context("live mask set on an empty sim slot")?;
                    let a = sim_accept_len(sl.h, sl.pos, gamma, d.accept_rate);
                    accept[s] = a as i32;
                    correction[s] = sl.token_at(sl.pos + a, spec.vocab_size, spec.bad_token_salt);
                    sl.pos += a + 1;
                    if sl.stuck {
                        stuck_live += 1;
                    }
                }
                // One fused full-model step over the static slot
                // geometry: decode is weight-bound, so scoring γ+1
                // positions costs ~one `decode_token` step (and stuck
                // rows stay slow rows).
                sim_sleep(
                    spec.dstep_ns
                        + spec.dtoken_ns.saturating_mul(slots.len() as u64)
                        + spec.fault.stuck_step_ns.saturating_mul(stuck_live),
                );
                Ok((accept, correction))
            }
            (Engine::Real { draft: None, .. }, _) => bail!("engine has no draft session"),
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// §L9 paged verify (§L8 speculation on the paged path): `verify`
    /// with the flattened page-table operand. The sim delegates to the
    /// monolithic verify — acceptance sampling and cost are
    /// page-layout-independent.
    pub(crate) fn verify_paged(
        &mut self,
        state: &mut SlotState,
        drafted: &[Vec<i32>],
        live: &[bool],
        gamma: usize,
        page_table: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        if let Engine::Group(g) = self {
            g.tick_followers();
            let out = g.leader.verify_paged(state, drafted, live, gamma, page_table)?;
            g.sync(live.len());
            return Ok(out);
        }
        if let Engine::Real { client, session, draft } = self {
            let Some(ds) = draft else { bail!("engine has no draft session") };
            let SlotState::Real { main, draft: dslots } = state else {
                bail!("engine/slot-state backend mismatch");
            };
            let mut flat = vec![0i32; live.len() * gamma];
            for (s, row) in drafted.iter().enumerate() {
                let n = row.len().min(gamma);
                flat[s * gamma..s * gamma + n].copy_from_slice(&row[..n]);
            }
            let held = main
                .take()
                .context("slot state lost after an earlier prefill/decode error")?;
            let (held, accept, correction) =
                session.verify_paged(client, held, &flat, live, gamma, page_table)?;
            *main = Some(held);
            let dheld = dslots
                .take()
                .context("draft slot state lost after an earlier error")?;
            *dslots = Some(ds.spec_accept(client, dheld, &accept, &correction, live)?);
            return Ok((accept, correction));
        }
        self.verify(state, drafted, live, gamma)
    }
}

/// §L9 host-side paged-serving state: the replica's page pool, one
/// page table per decode slot, and (when enabled) the cross-request
/// prefix cache. Backend-agnostic — the sim and real engines share
/// this allocator; only the device calls differ.
struct PoolServing {
    pool: PagePool,
    tables: Vec<PageTable>,
    cache: Option<PrefixCache>,
    /// Page-table width of every paged entry point:
    /// `ceil((enc_len + dec_len) / page_size)`.
    max_pages: usize,
}

/// Flatten per-slot page tables (rows picked by `slot_ids`, in order)
/// into the row-major (rows, max_pages) i32 operand the paged HLOs
/// take; unmapped entries are -1.
pub(crate) fn flatten_page_tables(tables: &[PageTable], slot_ids: &[usize], max_pages: usize) -> Vec<i32> {
    let mut flat = vec![-1i32; slot_ids.len() * max_pages];
    for (i, &sid) in slot_ids.iter().enumerate() {
        for (k, &page) in tables[sid].pages().iter().enumerate().take(max_pages) {
            flat[i * max_pages + k] = page as i32;
        }
    }
    flat
}

/// Truncate a decoded row at its first EOS (inclusive), aligning the
/// monolithic path's output with what the continuous path actually
/// generated before retiring the slot.
pub(crate) fn truncate_at_eos(tokens: &mut Vec<i32>) {
    if let Some(p) = tokens.iter().position(|&t| t == EOS) {
        tokens.truncate(p + 1);
    }
}

/// Replica entry: build the engine, then run whichever decode
/// discipline it supports (continuous wants the split HLO pair; the
/// batch-level loop works against every artifact). Runs inside the
/// panic boundary of `spawn_replica`; in-flight requests live in
/// `ledger` until terminally answered.
pub(crate) fn serve_replica(
    id: usize,
    spec: &EngineSpec,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
    ledger: &Ledger,
    stats: &mut ServerStats,
    shared: &Arc<QosShared>,
    tp: usize,
) -> Result<()> {
    let mut engine = Engine::build(id, spec, opts, tp)?;
    // §L11 canary gate: a rollout canary decodes the pinned probe set
    // and holds for the router's token-parity verdict before serving
    // any live traffic. Abandoned at the gate -> clean exit, zero
    // requests served (a bad version never answers a client).
    if shared.deploy.canary_id.load(Ordering::Acquire) == id
        && !deploy::canary_gate(&mut engine, opts, &shared.deploy)?
    {
        return Ok(());
    }
    stats.devices += engine.group_tp();
    // §L13 per-replica trace context: tracing rides the sampler switch
    // (`trace_sample > 0`); when off, no span, gauge, or phase-meter
    // code touches a clock.
    let tctx = TraceCtx {
        on: opts.trace_sample > 0.0,
        epoch: shared.epoch,
        group: id as u32,
    };
    if tctx.on {
        stats.trace.set_limits(opts.trace_ring, opts.trace_window_ms);
    }
    let out = if opts.continuous && engine.supports_continuous() {
        // §L8: speculation is strictly opt-in (spec_gamma > 0) and
        // runs at the engine's effective draft length (the requested γ
        // or the artifact's compiled fallback); anything missing falls
        // back to plain per-token decode.
        let gamma = engine.effective_spec_gamma(opts.spec_gamma);
        let spec_dec = (gamma > 0).then(|| SpecDecoder::new(gamma));
        serve_continuous(id, &mut engine, jobs, opts, ledger, stats, spec_dec, shared, tctx)
    } else {
        serve_batches(id, &mut engine, jobs, ledger, stats, &opts.tenants, shared, tctx)
    };
    // §L12: export the group's collective counters on exit. A panicked
    // incarnation loses its engine mid-loop (along with the rest of
    // its in-flight engine state), so crashed groups under-report —
    // the counters are a cost-model metric, not an audit log.
    let (collectives, collective_ns) = engine.collective_totals();
    stats.collectives += collectives;
    stats.collective_ns += collective_ns;
    if tctx.on && collectives > 0 {
        // §L13: collective time is a *nested* phase — it elapsed inside
        // prefill/decode wall time — attributed once at exit from the
        // group's own counters rather than timed per ring round.
        stats.trace.phases.add_n(trace::Phase::Allreduce, collective_ns, collectives);
    }
    out
}

/// Non-blocking / blocking pop off the shared job queue.
pub(crate) enum Popped {
    Job(BatchJob),
    Empty,
    Gone,
}

pub(crate) fn pop_job(jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>, block: bool) -> Result<Popped> {
    // Hold the queue lock only for the pop; decode runs unlocked so
    // other replicas pull the next job meanwhile. (A blocking pop only
    // happens when this replica is idle.) A poisoned lock is recovered:
    // replicas panic inside engine calls, never while holding this
    // guard, and the receiver itself stays sound either way.
    if block {
        let queue = match jobs.lock() {
            Ok(q) => q,
            Err(poisoned) => poisoned.into_inner(),
        };
        // Bounded wait, not `recv()`: an idle replica must resurface at
        // the supervision cadence to notice cross-thread levers (the
        // §L11 targeted drain), so a timed-out wait is `Empty`, not
        // `Gone`.
        match queue.recv_timeout(SUPERVISE_TICK) {
            Ok(job) => Ok(Popped::Job(job)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(Popped::Empty),
            Err(mpsc::RecvTimeoutError::Disconnected) => Ok(Popped::Gone),
        }
    } else {
        // try_lock, not lock: an idle replica parks inside `recv`
        // holding the mutex, and a replica with live slots must keep
        // decoding rather than stall on that hold until the next job
        // arrives.
        let queue = match jobs.try_lock() {
            Ok(q) => q,
            Err(std::sync::TryLockError::WouldBlock) => return Ok(Popped::Empty),
            Err(std::sync::TryLockError::Poisoned(poisoned)) => poisoned.into_inner(),
        };
        match queue.try_recv() {
            Ok(job) => Ok(Popped::Job(job)),
            Err(mpsc::TryRecvError::Empty) => Ok(Popped::Empty),
            Err(mpsc::TryRecvError::Disconnected) => Ok(Popped::Gone),
        }
    }
}

/// Run-to-completion batch loop (§Perf L5, and the fallback when the
/// artifact ships no split HLO): pop bucket-homogeneous jobs, shed
/// expired requests, admit the rest into the in-flight ledger, pack at
/// the (effective) bucket geometry into a reused scratch buffer,
/// decode to full `dec_len`, and move each output row into its reply.
fn serve_batches(
    id: usize,
    engine: &mut Engine,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    ledger: &Ledger,
    stats: &mut ServerStats,
    tenants: &[TenantSpec],
    shared: &Arc<QosShared>,
    tctx: TraceCtx,
) -> Result<()> {
    let (batch_size, _enc_len) = engine.dims();
    // Packing scratch reused across every batch on this hot path: the
    // fresh-allocation-per-batch version showed up in router/replica
    // profiles once decode itself got cheap.
    let mut enc_scratch: Vec<i32> = Vec::new();
    let mut trunc_scratch: Vec<bool> = Vec::new();
    loop {
        // §L11: a targeted rollout drain retires this replica between
        // batches (run-to-completion means no slots to let retire);
        // a probation canary publishes its health each pass.
        if shared.deploy.take_drain(id) {
            if tctx.on {
                // Run-to-completion means the drain is instantaneous
                // (nothing in flight between batches) — an event span.
                let at = tctx.ns(Instant::now());
                stats.trace.record(trace::Span {
                    req: 0,
                    tenant: 0,
                    group: tctx.group,
                    phase: trace::Phase::DeployDrain,
                    start_ns: at,
                    end_ns: at,
                    value: 0,
                });
            }
            return Ok(());
        }
        if shared.deploy.canary_id.load(Ordering::Relaxed) == id {
            shared.deploy.publish_canary_health(stats);
        }
        let job = match pop_job(jobs, true)? {
            Popped::Job(job) => job,
            Popped::Empty => continue, // timed pop: re-check the levers
            Popped::Gone => break,     // router gone and queue drained
        };
        if is_scale_down(&job) {
            return Ok(()); // §L10 autoscale retirement: a clean exit
        }
        let bucket = engine.effective_bucket(job.bucket);
        let routed_bucket = job.bucket;
        // Admission: ledger entries survive a decode panic so the
        // supervisor can requeue them; expired requests are shed now
        // rather than padded into the batch.
        let now = Instant::now();
        let mut batch: Vec<Pend> = Vec::with_capacity(job.requests.len());
        for entry in job.requests {
            let Admitted { req, attempts, admitted } = entry;
            if req.expired(now) {
                fail_request(stats, &req, FailReason::DeadlineExceeded, id);
                continue;
            }
            let t0 = req.t0;
            let deadline = req.deadline;
            let enc_len = req.enc_tokens.len();
            let req_id = req.id;
            let tenant = req.tenant as u32;
            let traced = req.traced;
            let ticket = ledger.admit(routed_bucket, attempts, req);
            batch.push(Pend { ticket, t0, deadline, enc_len, admitted, req_id, tenant, traced });
        }
        if batch.is_empty() {
            continue;
        }
        let fill = batch.len();
        {
            let tickets: Vec<u64> = batch.iter().map(|p| p.ticket).collect();
            ledger.pack_rows(&tickets, batch_size, bucket, &mut enc_scratch, &mut trunc_scratch);
        }
        // §L13: the monolithic path has no separate prefill step, so a
        // traced request's timeline here is router-dispatch -> decode —
        // still a contiguous tiling of [t0, retirement].
        let t_dec0 = Instant::now();
        let decoded = engine.decode(&enc_scratch, bucket)?;
        if tctx.on {
            stats.trace.phases.add(trace::Phase::DecodeIter, t_dec0.elapsed().as_nanos() as u64);
        }
        let mut decoded = decoded.into_iter();
        for (i, p) in batch.into_iter().enumerate() {
            let Some(held) = ledger.take(p.ticket) else { continue };
            let latency = p.t0.elapsed();
            let mut tokens = decoded.next().unwrap_or_default();
            truncate_at_eos(&mut tokens);
            stats.note_response(
                latency,
                tokens.len(),
                0, // monolithic decode ran the full dec_len regardless
                p.enc_len.min(bucket),
                trunc_scratch[i],
            );
            stats.requests += 1;
            if tctx.on {
                let done = Instant::now();
                stats.trace.timeline.note_done(
                    held.req.tenant,
                    latency.as_secs_f64() * 1e3,
                    tctx.ns(done),
                );
                if p.traced {
                    stats.trace.record(trace::Span {
                        req: p.req_id,
                        tenant: p.tenant,
                        group: tctx.group,
                        phase: trace::Phase::RouterDispatch,
                        start_ns: tctx.ns(p.admitted),
                        end_ns: tctx.ns(t_dec0),
                        value: 0,
                    });
                    stats.trace.record(trace::Span {
                        req: p.req_id,
                        tenant: p.tenant,
                        group: tctx.group,
                        phase: trace::Phase::Decode,
                        start_ns: tctx.ns(t_dec0),
                        end_ns: tctx.ns(done),
                        value: tokens.len() as i64,
                    });
                }
            }
            let slo_ms = tenants.get(held.req.tenant).map_or(0, |t| t.slo_ms);
            stats
                .tenant_mut(held.req.tenant)
                .note_done(latency.as_secs_f64() * 1e3, tokens.len(), slo_ms);
            stats.deploy.note_done(latency.as_secs_f64() * 1e3, tokens.len());
            let _ = held.req.reply.send(Response {
                tokens,
                latency,
                batch_fill: fill,
                truncated: trunc_scratch[i],
                bucket,
                replica: id,
                failure: None,
            });
        }
        stats.batches += 1;
        stats.total_fill += fill;
        stats.executed_tokens += batch_size * bucket;
    }
    Ok(())
}

/// §L13 per-replica trace context: the on/off switch, the server-wide
/// epoch all span timestamps are relative to, and the worker's group
/// id stamped on every span it records. Copy-cheap by design — it
/// threads through the serving loops by value.
#[derive(Clone, Copy)]
pub(crate) struct TraceCtx {
    pub(crate) on: bool,
    pub(crate) epoch: Instant,
    pub(crate) group: u32,
}

impl TraceCtx {
    /// Nanoseconds since the server epoch (saturating at 0 for
    /// instants stamped before the epoch, e.g. request arrival on a
    /// handle built before serve started).
    fn ns(&self, t: Instant) -> u64 {
        trace::ns_since(self.epoch, t)
    }
}

/// A request waiting for a free decode slot (already in the ledger —
/// which also owns the prompt tokens; see `Ledger::pack_rows`).
struct Pend {
    ticket: u64,
    t0: Instant,
    deadline: Option<Instant>,
    enc_len: usize,
    /// When the router handed this request to the replica queue (the
    /// §L13 `router-dispatch` span opens here).
    admitted: Instant,
    /// §L13 trace identity, carried past the point the ledger owns the
    /// `Request` itself.
    req_id: u64,
    tenant: u32,
    traced: bool,
}

/// A request occupying a decode slot (already in the ledger).
struct Active {
    ticket: u64,
    t0: Instant,
    deadline: Option<Instant>,
    tokens: Vec<i32>,
    bucket: usize,
    fill: usize,
    truncated: bool,
    prompt_len: usize,
    /// §L13: when this slot's prefill group finished — the `decode`
    /// span runs from here to retirement.
    prefill_end: Instant,
    req_id: u64,
    tenant: u32,
    traced: bool,
}

/// Unpack a router job into the replica's pending queue via the
/// in-flight ledger, shedding anything already past its deadline.
fn stash(
    ledger: &Ledger,
    pending: &mut VecDeque<(usize, Pend)>,
    job: BatchJob,
    stats: &mut ServerStats,
    id: usize,
) {
    let BatchJob { bucket, requests } = job;
    let now = Instant::now();
    for entry in requests {
        let Admitted { req, attempts, admitted } = entry;
        if req.expired(now) {
            fail_request(stats, &req, FailReason::DeadlineExceeded, id);
            continue;
        }
        let t0 = req.t0;
        let deadline = req.deadline;
        let enc_len = req.enc_tokens.len();
        let req_id = req.id;
        let tenant = req.tenant as u32;
        let traced = req.traced;
        let ticket = ledger.admit(bucket, attempts, req);
        pending.push_back((
            bucket,
            Pend { ticket, t0, deadline, enc_len, admitted, req_id, tenant, traced },
        ));
    }
}

/// Slot-based continuous batching (§Perf L6): between fused
/// `decode_token` iterations the scheduler admits pending requests
/// into free slots (one batched prefill per same-bucket group),
/// retires slots the moment they emit EOS or hit `dec_len`, and —
/// §L7 — sheds expired pending requests and retires expired slots so
/// one stuck generation cannot hold a slot forever. With a
/// `SpecDecoder` (§L8) each decode iteration becomes a draft/verify
/// round delivering 1..=γ+1 tokens per live slot; admission,
/// deadlines, retirement, and drain are identical.
#[allow(clippy::too_many_arguments)]
fn serve_continuous(
    id: usize,
    engine: &mut Engine,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
    ledger: &Ledger,
    stats: &mut ServerStats,
    mut spec_dec: Option<SpecDecoder>,
    shared: &Arc<QosShared>,
    tctx: TraceCtx,
) -> Result<()> {
    let (batch_size, enc_len) = engine.dims();
    let dec_len = engine.dec_len();
    let slots_n = if opts.slots > 0 { opts.slots } else { batch_size };
    // §L9: serve out of a page pool when the engine carries the paged
    // contract; otherwise monolithic per-slot state (the fallback —
    // token-for-token identical, pinned by tests/server.rs).
    let mut paged: Option<PoolServing> = engine.paged_geometry().map(
        |(page_size, pool_pages, prefix_cache)| PoolServing {
            pool: PagePool::new(page_size, pool_pages),
            tables: (0..slots_n).map(|_| PageTable::new()).collect(),
            cache: prefix_cache.then(PrefixCache::new),
            max_pages: pages_for(enc_len + dec_len, page_size),
        },
    );
    let mut state = match &paged {
        Some(ps) => {
            stats.pool.capacity = ps.pool.capacity();
            engine.init_slots_paged(slots_n, ps.pool.capacity())?
        }
        None => engine.init_slots(slots_n)?,
    };
    let all_slots: Vec<usize> = (0..slots_n).collect();
    let mut active: Vec<Option<Active>> = (0..slots_n).map(|_| None).collect();
    let mut pending: VecDeque<(usize, Pend)> = VecDeque::new();
    let mut router_gone = false;
    // §L10 autoscale retirement: once this replica pops the
    // scale-down sentinel it stops pulling work, finishes what it
    // holds, and exits cleanly.
    let mut retiring = false;
    // §L13: set only by a *deploy* drain (not autoscale retirement) so
    // the trace can show how long the rolling swap held this replica
    // in its drain-the-slots phase.
    let mut drain_started: Option<Instant> = None;
    // §L8 base draft length; the §L10 γ-cap lever can only shrink it.
    let base_gamma = spec_dec.as_ref().map_or(0, |sd| sd.gamma());
    let mut enc_scratch: Vec<i32> = Vec::new();
    let mut trunc_scratch: Vec<bool> = Vec::new();
    loop {
        let n_live = active.iter().filter(|s| s.is_some()).count();

        // §L11: a targeted rollout drain retires this replica exactly
        // like an autoscale retirement — stop pulling work, let the
        // in-flight slots finish naturally (releasing their §L9 pages),
        // exit cleanly. A probation canary publishes its live health
        // each iteration for the router's gates.
        if !retiring && shared.deploy.take_drain(id) {
            retiring = true;
            drain_started = Some(Instant::now());
        }
        if shared.deploy.canary_id.load(Ordering::Relaxed) == id {
            shared.deploy.publish_canary_health(stats);
        }

        // Pull new work: block when fully idle (nothing to decode),
        // poll otherwise so in-flight slots keep stepping.
        if !router_gone && !retiring {
            if n_live == 0 && pending.is_empty() {
                match pop_job(jobs, true)? {
                    Popped::Job(job) if is_scale_down(&job) => retiring = true,
                    Popped::Job(job) => stash(ledger, &mut pending, job, stats, id),
                    Popped::Empty => {} // timed pop: re-check the levers
                    Popped::Gone => router_gone = true,
                }
            }
            while pending.len() < slots_n && !router_gone && !retiring {
                match pop_job(jobs, false)? {
                    Popped::Job(job) if is_scale_down(&job) => retiring = true,
                    Popped::Job(job) => stash(ledger, &mut pending, job, stats, id),
                    Popped::Empty => break,
                    Popped::Gone => router_gone = true,
                }
            }
        }

        // §L10: apply the overload controller's current γ cap before
        // this iteration's draft/verify round.
        if let Some(sd) = spec_dec.as_mut() {
            let eff = base_gamma.min(shared.gamma_cap.load(Ordering::Relaxed)).max(1);
            if sd.gamma() != eff {
                sd.set_gamma(eff);
            }
        }

        // §L7 deadline pass, run between decode iterations (so a shed
        // costs at most one fused step of extra latency): drop expired
        // pending requests and retire expired slots with explicit
        // failures.
        let now = Instant::now();
        pending.retain(|(_, p)| {
            if p.deadline.is_some_and(|d| now >= d) {
                if let Some(held) = ledger.take(p.ticket) {
                    fail_request(stats, &held.req, FailReason::DeadlineExceeded, id);
                }
                false
            } else {
                true
            }
        });
        for slot in active.iter_mut() {
            let expired =
                slot.as_ref().is_some_and(|a| a.deadline.is_some_and(|d| now >= d));
            if expired {
                let act = slot.take().expect("expired slot");
                if let Some(held) = ledger.take(act.ticket) {
                    fail_request(stats, &held.req, FailReason::DeadlineExceeded, id);
                }
            }
        }

        // §L9: release retired slots' page tables before admission, so
        // pages freed by EOS/deadline retirement are allocatable this
        // pass. A released page drops to refcount 1 while the prefix
        // cache still holds it (evictable, reusable) and to 0 (free)
        // otherwise.
        if let Some(ps) = paged.as_mut() {
            for (s, slot) in active.iter().enumerate() {
                if slot.is_none() && !ps.tables[s].is_empty() {
                    ps.tables[s].release(&mut ps.pool)?;
                }
            }
        }

        // Admit pending requests into free slots, one batched prefill
        // per same-bucket run (bounded by the prefill geometry and —
        // §L9 — by page-pool capacity).
        let mut free: VecDeque<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect();
        let mut stalled = false;
        while !free.is_empty() && !pending.is_empty() && !stalled {
            let bucket = pending.front().expect("non-empty pending").0;
            let eff = if paged.is_some() {
                engine.effective_paged_prefill_bucket(bucket)
            } else {
                engine.effective_prefill_bucket(bucket)
            };
            let mut group: Vec<Pend> = Vec::new();
            let mut slot_ids: Vec<usize> = Vec::new();
            let mut group_saved = 0usize;
            while group.len() < batch_size.min(free.len() + group.len()) {
                let (ticket, cand_deadline) = match pending.front() {
                    Some((b, p)) if *b == bucket => (p.ticket, p.deadline),
                    _ => break,
                };
                // §L10 satellite (pre-expiry audit): a candidate can
                // expire *during this admission pass* — an earlier
                // group's prefill slept — so re-check against a fresh
                // clock before the §L9 pool gate spends prefix-cache
                // probes or page reservations on doomed work. The
                // monolithic arm shares the check for parity.
                if cand_deadline.is_some_and(|d| Instant::now() >= d) {
                    let (_, p) = pending.pop_front().expect("front present");
                    if let Some(held) = ledger.take(p.ticket) {
                        fail_request(stats, &held.req, FailReason::DeadlineExceeded, id);
                    }
                    continue;
                }
                if let Some(ps) = paged.as_mut() {
                    // §L9 pool gate: reserve this request's pages —
                    // shared prefix pages first, fresh pages for the
                    // uncovered prompt tail + decode room — before
                    // taking a slot.
                    let page_size = ps.pool.page_size();
                    let total = pages_for(eff + dec_len, page_size);
                    if total > ps.pool.capacity() {
                        // Can never fit, even with every page free:
                        // an explicit terminal failure, not an
                        // eternal stall.
                        let (_, p) = pending.pop_front().expect("front present");
                        if let Some(held) = ledger.take(p.ticket) {
                            fail_request(stats, &held.req, FailReason::PoolExhausted, id);
                        }
                        continue;
                    }
                    let hashes = match ps.cache.as_ref() {
                        Some(_) => ledger
                            .with_prompt(ticket, |toks| {
                                chunk_hashes(&toks[..toks.len().min(eff)], page_size)
                            })
                            .unwrap_or_default(),
                        None => Vec::new(),
                    };
                    let hits = ps.cache.as_ref().map_or(0, |c| c.match_len(&hashes));
                    let need = total - hits;
                    if let Some(cache) = ps.cache.as_mut() {
                        while ps.pool.free_pages() < need && cache.evict_lru(&mut ps.pool)? {
                            stats.pool.evictions += 1;
                        }
                    }
                    if ps.pool.free_pages() < need {
                        // Pool pressure with every unpinned cache page
                        // already evicted: wait for live slots to
                        // retire. The request stays pending (a stall,
                        // not a failure) — with zero live slots every
                        // cached page is evictable, so `total <=
                        // capacity` always unblocks eventually.
                        stats.pool.alloc_stalls += 1;
                        stalled = true;
                        break;
                    }
                    let (_, p) = pending.pop_front().expect("front present");
                    let sid = free.pop_front().expect("free slot");
                    let table = &mut ps.tables[sid];
                    for &h in &hashes[..hits] {
                        let page = ps
                            .cache
                            .as_mut()
                            .and_then(|c| c.hit(h))
                            .context("matched prefix chunk vanished")?;
                        table.push_shared(&mut ps.pool, page)?;
                    }
                    if !table.ensure(&mut ps.pool, total) {
                        bail!("page pool exhausted after its reservation check");
                    }
                    if let Some(cache) = ps.cache.as_mut() {
                        stats.pool.prefix_lookups += hashes.len() as u64;
                        stats.pool.prefix_hits += hits as u64;
                        // Publish this prompt's fresh chunks so later
                        // requests share them.
                        for k in hits..hashes.len() {
                            cache.insert(&mut ps.pool, hashes[k], table.pages()[k])?;
                        }
                    }
                    group_saved += hits * page_size;
                    slot_ids.push(sid);
                    group.push(p);
                } else {
                    let (_, p) = pending.pop_front().expect("front present");
                    slot_ids.push(free.pop_front().expect("free slot"));
                    group.push(p);
                }
            }
            if group.is_empty() {
                break; // no free capacity for this bucket run
            }
            {
                let tickets: Vec<u64> = group.iter().map(|p| p.ticket).collect();
                ledger.pack_rows(&tickets, group.len(), eff, &mut enc_scratch, &mut trunc_scratch);
            }
            // §L13: bracket the group's prefill. One `Instant` pair per
            // *group* (never per token), so the tracing tax on this hot
            // path is two clock reads ahead of a fused engine call.
            let t_pre0 = Instant::now();
            match paged.as_ref() {
                Some(ps) => {
                    let flat = flatten_page_tables(&ps.tables, &slot_ids, ps.max_pages);
                    engine.prefill_paged(
                        &mut state,
                        &enc_scratch,
                        eff,
                        &slot_ids,
                        &flat,
                        group_saved,
                    )?;
                    stats.executed_tokens += group.len() * eff - group_saved;
                    stats.pool.prefill_tokens_saved += group_saved as u64;
                }
                None => {
                    engine.prefill(&mut state, &enc_scratch, eff, &slot_ids)?;
                    stats.executed_tokens += group.len() * eff;
                }
            }
            let t_pre1 = Instant::now();
            if tctx.on {
                stats.trace.phases.add(trace::Phase::Prefill, (t_pre1 - t_pre0).as_nanos() as u64);
            }
            stats.prefills += 1;
            stats.batches += 1;
            stats.total_fill += group.len();
            for (i, p) in group.into_iter().enumerate() {
                let prompt_len = p.enc_len.min(eff);
                if tctx.on && p.traced {
                    // The sampled request's top-level timeline stays
                    // contiguous: router-dispatch runs from the router
                    // handoff to the moment its prefill group launched,
                    // prefill covers the fused call itself.
                    stats.trace.record(trace::Span {
                        req: p.req_id,
                        tenant: p.tenant,
                        group: tctx.group,
                        phase: trace::Phase::RouterDispatch,
                        start_ns: tctx.ns(p.admitted),
                        end_ns: tctx.ns(t_pre0),
                        value: 0,
                    });
                    stats.trace.record(trace::Span {
                        req: p.req_id,
                        tenant: p.tenant,
                        group: tctx.group,
                        phase: trace::Phase::Prefill,
                        start_ns: tctx.ns(t_pre0),
                        end_ns: tctx.ns(t_pre1),
                        value: prompt_len as i64,
                    });
                }
                active[slot_ids[i]] = Some(Active {
                    ticket: p.ticket,
                    t0: p.t0,
                    deadline: p.deadline,
                    tokens: Vec::with_capacity(dec_len),
                    bucket: eff,
                    fill: slot_ids.len(),
                    truncated: trunc_scratch[i],
                    prompt_len,
                    prefill_end: t_pre1,
                    req_id: p.req_id,
                    tenant: p.tenant,
                    traced: p.traced,
                });
            }
        }

        let n_live = active.iter().filter(|s| s.is_some()).count();
        if n_live == 0 {
            if (router_gone || retiring) && pending.is_empty() {
                break; // drained (or §L10 autoscale retirement)
            }
            continue;
        }

        // §L13 worker gauges, sampled once per decode iteration (the
        // timeline bins by 100ms window, so per-iteration sampling is
        // already far denser than the bin width).
        if tctx.on {
            let at = tctx.ns(Instant::now());
            stats.trace.timeline.gauge(trace::Gauge::SlotOccupancy, n_live as f64, at);
            if let Some(ps) = paged.as_ref() {
                stats.trace.timeline.gauge(
                    trace::Gauge::PoolPages,
                    ps.pool.used_pages() as f64,
                    at,
                );
            }
        }

        // One full-model decode iteration over the whole slot
        // geometry: a §L8 draft/verify round (1..=γ+1 tokens per live
        // slot) when speculating, else one fused `decode_token`. On
        // the §L9 paged path the step takes the flattened
        // (slots, max_pages) table and the pool meter samples
        // occupancy once per iteration.
        let live: Vec<bool> = active.iter().map(|s| s.is_some()).collect();
        let flat_table = paged.as_ref().map(|ps| {
            stats.pool.record(ps.pool.used_pages(), n_live);
            flatten_page_tables(&ps.tables, &all_slots, ps.max_pages)
        });
        let t_iter = if tctx.on { Some(Instant::now()) } else { None };
        if let Some(sd) = spec_dec.as_mut() {
            let spec_trace = if tctx.on { Some(&mut stats.trace.phases) } else { None };
            let emissions = sd.round(
                engine,
                &mut state,
                &live,
                flat_table.as_deref(),
                &mut stats.spec,
                spec_trace,
            )?;
            if let Some(t0i) = t_iter {
                stats.trace.phases.add(trace::Phase::DecodeIter, t0i.elapsed().as_nanos() as u64);
            }
            stats.decode_steps += 1;
            stats.occupancy.record(n_live);
            for (s, slot) in active.iter_mut().enumerate() {
                let Some(act) = slot.as_mut() else { continue };
                // Push the round's tokens in stream order, truncating
                // at EOS / dec_len exactly like plain decode — tokens
                // the verify accepted past a retirement point are
                // discarded, never delivered.
                let mut pushed = 0u64;
                let mut done = false;
                for &tok in &emissions[s] {
                    act.tokens.push(tok);
                    pushed += 1;
                    if tok == EOS || act.tokens.len() >= dec_len {
                        done = true;
                        break;
                    }
                }
                // The meter's delivered-tokens half is the serving
                // loop's to report: only it knows the truncation.
                stats.spec.note_delivered(pushed);
                if done {
                    finish_slot(slot, ledger, stats, dec_len, id, router_gone, &opts.tenants, tctx);
                }
            }
        } else {
            let tokens = match flat_table.as_deref() {
                Some(flat) => engine.decode_token_paged(&mut state, &live, flat)?,
                None => engine.decode_token(&mut state, &live)?,
            };
            if let Some(t0i) = t_iter {
                stats.trace.phases.add(trace::Phase::DecodeIter, t0i.elapsed().as_nanos() as u64);
            }
            stats.decode_steps += 1;
            stats.occupancy.record(n_live);
            for (s, slot) in active.iter_mut().enumerate() {
                let Some(act) = slot.as_mut() else { continue };
                act.tokens.push(tokens[s]);
                if tokens[s] == EOS || act.tokens.len() >= dec_len {
                    finish_slot(slot, ledger, stats, dec_len, id, router_gone, &opts.tenants, tctx);
                }
            }
        }
    }
    if tctx.on {
        if let Some(t0d) = drain_started {
            // §L13 deploy-drain interval: how long the §L11 rolling
            // swap held this replica draining its live slots.
            let now = Instant::now();
            stats.trace.record(trace::Span {
                req: 0,
                tenant: 0,
                group: tctx.group,
                phase: trace::Phase::DeployDrain,
                start_ns: tctx.ns(t0d),
                end_ns: tctx.ns(now),
                value: 0,
            });
            stats.trace.phases.add(trace::Phase::DeployDrain, (now - t0d).as_nanos() as u64);
        }
    }
    Ok(())
}

/// Retire a finished slot: move its request out of the ledger, record
/// the response bookkeeping, and send the terminal token response.
/// Shared by the plain and §L8 speculative decode paths — retirement
/// semantics (early-exit accounting, drain counting, ledger removal)
/// must not depend on which path generated the tokens.
#[allow(clippy::too_many_arguments)]
fn finish_slot(
    slot: &mut Option<Active>,
    ledger: &Ledger,
    stats: &mut ServerStats,
    dec_len: usize,
    id: usize,
    router_gone: bool,
    tenants: &[TenantSpec],
    tctx: TraceCtx,
) {
    let Some(act) = slot.take() else { return };
    let Some(held) = ledger.take(act.ticket) else { return };
    let latency = act.t0.elapsed();
    if tctx.on {
        let now = Instant::now();
        stats.trace.timeline.note_done(
            held.req.tenant,
            latency.as_secs_f64() * 1e3,
            tctx.ns(now),
        );
        if act.traced {
            // Decode span: prefill end -> retirement. Together with
            // admission-queue/qos-queue/router-dispatch/prefill this
            // tiles the request's whole [t0, retirement] interval, so
            // the per-request phase sum reproduces e2e latency (pinned
            // by tests/server.rs).
            stats.trace.record(trace::Span {
                req: act.req_id,
                tenant: act.tenant,
                group: tctx.group,
                phase: trace::Phase::Decode,
                start_ns: tctx.ns(act.prefill_end),
                end_ns: tctx.ns(now),
                value: act.tokens.len() as i64,
            });
        }
    }
    stats.note_response(
        latency,
        act.tokens.len(),
        dec_len - act.tokens.len(), // early-exit savings
        act.prompt_len,
        act.truncated,
    );
    stats.requests += 1;
    let slo_ms = tenants.get(held.req.tenant).map_or(0, |t| t.slo_ms);
    stats
        .tenant_mut(held.req.tenant)
        .note_done(latency.as_secs_f64() * 1e3, act.tokens.len(), slo_ms);
    stats.deploy.note_done(latency.as_secs_f64() * 1e3, act.tokens.len());
    if router_gone {
        stats.drained += 1;
    }
    let _ = held.req.reply.send(Response {
        tokens: act.tokens,
        latency,
        batch_fill: act.fill,
        truncated: act.truncated,
        bucket: act.bucket,
        replica: id,
        failure: None,
    });
}
