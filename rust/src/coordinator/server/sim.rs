//! The deterministic sim engine: cost-model specs (`SimSpec` and
//! friends), injectable faults (`FaultSpec`/`ChaosSpec`), §L11 swap
//! specs, the per-replica `SimEngine`/`SimSlot` state, and the pure
//! sim hash/cost helpers. Split out of the old monolithic
//! `coordinator/server.rs` — paths are preserved via re-exports in
//! `server/mod.rs`.

use super::*;

/// Injectable faults for the sim engine (§L7). Everything is
/// deterministic — keyed by replica id, engine-call index, or prompt
/// hash — so supervision, retry, shedding, and drain behavior can be
/// pinned by tests and A/B-benched without a real backend.
#[derive(Debug, Clone, Default)]
pub struct FaultSpec {
    /// Kill this replica id: its serving thread panics on engine call
    /// number `kill_after_calls`. Respawned replacements get fresh ids
    /// and therefore serve cleanly.
    pub kill_replica: Option<usize>,
    /// Which engine call (prefill / decode_token / monolithic decode,
    /// 1-based) triggers `kill_replica`; 0 behaves like 1.
    pub kill_after_calls: u64,
    /// §L10: additional deterministic kills beyond the single
    /// `kill_replica` — `(replica id, engine call)` pairs, so a chaos
    /// schedule can take down several replicas at different points of
    /// a trace replay. `ChaosSpec::apply` fills this.
    pub extra_kills: Vec<(usize, u64)>,
    /// Probability that any engine call panics, hash-sampled from
    /// (replica id, call index). 0.0 = never.
    pub panic_rate: f64,
    /// Stuck-generation injection: prompts whose hash falls in the
    /// 1-in-`stuck_every` class never emit EOS (decode runs the full
    /// `dec_len`) — the workload deadlines exist to shed. 0 = off.
    pub stuck_every: u64,
    /// Extra simulated ns per decode step per live stuck row (a stuck
    /// generation is also a slow one).
    pub stuck_step_ns: u64,
    /// §L12: when the killed unit is a `tp`-way execution group, which
    /// shard the panic lands on (clamped to `tp-1`). 0 = the leader.
    /// Any shard dying must take the whole group down atomically —
    /// that invariant is what the shard-kill chaos tests pin.
    pub kill_shard: usize,
}

impl FaultSpec {
    fn stuck(&self, row_hash: u64) -> bool {
        self.stuck_every > 0 && row_hash % self.stuck_every == 0
    }

    /// §L12: the slice of this fault schedule that shard `shard` of a
    /// `tp`-way group observes. Kill triggers land on exactly one
    /// shard (`kill_shard`, clamped); cost/stuck/panic-rate injection
    /// rides the leader (shard 0), which owns the group's cost model.
    pub(crate) fn for_shard(&self, shard: usize, tp: usize) -> FaultSpec {
        let target = self.kill_shard.min(tp.saturating_sub(1));
        let mut f = if shard == 0 { self.clone() } else { FaultSpec::default() };
        if shard == target {
            f.kill_replica = self.kill_replica;
            f.kill_after_calls = self.kill_after_calls;
            f.extra_kills = self.extra_kills.clone();
        } else {
            f.kill_replica = None;
            f.kill_after_calls = 0;
            f.extra_kills = Vec::new();
        }
        f
    }
}

/// §L10: a composable chaos schedule for trace-driven load tests. A
/// `ChaosSpec` bundles the failure modes the sim engine already knows
/// how to inject — deterministic replica kills, stuck generations,
/// page-pool pressure — into one schedule that `apply` composes onto a
/// `SimSpec`, so the bench/CI chaos harness describes "kill replica 1
/// mid-burst while 25% of the pool is withheld" as data, not as
/// hand-edited spec fields.
#[derive(Debug, Clone, Default)]
pub struct ChaosSpec {
    /// Replica kills as `(replica id, engine call ordinal)` — each
    /// listed replica panics on its Nth engine call.
    pub kills: Vec<(usize, u64)>,
    /// Stuck-generation class (`FaultSpec::stuck_every` semantics);
    /// 0 leaves the spec's existing setting alone.
    pub stuck_every: u64,
    /// Extra ns per decode step per stuck row.
    pub stuck_step_ns: u64,
    /// Withhold this fraction of the page pool (simulated external
    /// memory pressure); pool capacity never drops below one slot's
    /// worth of pages.
    pub pool_reserve: f64,
}

impl ChaosSpec {
    /// Compose this schedule onto a sim spec: the first kill lands on
    /// `FaultSpec::kill_replica` (keeping single-kill A/Bs bit-compatible
    /// with the §L7 degraded bench), the rest on `extra_kills`.
    pub fn apply(&self, spec: &mut SimSpec) {
        if let Some(&(replica, after)) = self.kills.first() {
            spec.fault.kill_replica = Some(replica);
            spec.fault.kill_after_calls = after;
        }
        spec.fault.extra_kills.extend(self.kills.iter().skip(1).copied());
        if self.stuck_every > 0 {
            spec.fault.stuck_every = self.stuck_every;
            spec.fault.stuck_step_ns = self.stuck_step_ns;
        }
        if self.pool_reserve > 0.0 {
            if let Some(pool) = spec.pool.as_mut() {
                let keep = (pool.pool_pages as f64 * (1.0 - self.pool_reserve.clamp(0.0, 1.0)))
                    .floor() as usize;
                let floor = pages_for(spec.enc_len + spec.dec_len, pool.page_size);
                pool.pool_pages = keep.max(floor);
            }
        }
    }
}

/// §L11: how a *new* sim version differs from the serving one — the
/// deploy analogue of `ChaosSpec`. `apply` derives the successor
/// version's `SimSpec` from the old one, so swap benches describe "the
/// new checkpoint is 10% cheaper" or "the new checkpoint is broken" as
/// data. Composes with `ChaosSpec`: chaos targets `fault` fields, a
/// swap targets costs and the bad-version injections.
#[derive(Debug, Clone, Default)]
pub struct SimSwapSpec {
    /// Per-token / per-step cost multiplier for the new version (a
    /// re-distilled successor is usually cheaper). 0.0 means 1.0.
    pub cost_mult: f64,
    /// Deterministic bad-version injection, exercised by the rollback
    /// arms.
    pub bad: BadVersionMode,
}

/// What a deliberately broken successor version does. Both modes are
/// deterministic so the rollback benches and parity assertions pin
/// exact behavior.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum BadVersionMode {
    /// The new version is healthy.
    #[default]
    None,
    /// Every engine call panics — the canary crashes at its very first
    /// probe decode (exercises the crash-rollback path).
    Panic,
    /// Decode emits wrong-but-well-formed tokens: the per-row hash is
    /// salted so every non-EOS token differs from the old version while
    /// stream lengths and costs stay identical (exercises the
    /// token-parity probe gate).
    WrongTokens,
}

/// Salt XORed into the sim row hash by `BadVersionMode::WrongTokens`.
/// Only token *values* change — `sim_gen_len` and EOS placement key off
/// the unsalted hash, so a wrong-token version is behaviorally
/// identical except for what it says.
const BAD_VERSION_SALT: u64 = 0x0BAD_5EED_0BAD_5EED;

impl SimSwapSpec {
    /// Derive the new version's spec from the serving one. All cost
    /// scaling goes through `SimSpec::scaled` — the one audited place
    /// a uniform multiplier is applied.
    pub fn apply(&self, old: &SimSpec) -> SimSpec {
        let mut spec = old.scaled(self.cost_mult);
        match self.bad {
            BadVersionMode::None => {}
            BadVersionMode::Panic => spec.bad_panic = true,
            BadVersionMode::WrongTokens => spec.bad_token_salt = BAD_VERSION_SALT,
        }
        spec
    }
}

/// §L12 collective cost model: the simulated price of the all-reduce
/// sync points a `tp`-way sharded step executes. The model is a
/// standard ring all-reduce over `tp` links — per sync point each rank
/// sends `2(tp-1)/tp` of the payload across its link and pays a
/// per-hop latency floor — with the payload
///
///   bytes = fused_tokens x active_width x elem_bytes
///
/// i.e. only the *active* AltUp subblock crosses the wire. The
/// predict/correct updates of the inactive blocks are cheap elementwise
/// maps replicated per shard (the paper's core asymmetry), so a K-way
/// widened AltUp model syncs a `d_model/K` slice where a dense-widened
/// baseline syncs all of `d_model` — set `active_width = d_model` to
/// model that baseline arm.
#[derive(Debug, Clone)]
pub struct CollectiveSpec {
    /// Widened model width (K·d_sub) the cost model describes. Only
    /// documentation + the dense-baseline arm read it directly; the
    /// wire payload keys off `active_width`. `ALTUP_TP_DMODEL` sets
    /// the default (else 1024).
    pub d_model: usize,
    /// Width of the representation slice that is actually partitioned
    /// and synced per token — the AltUp active subblock (`d_model/K`);
    /// equal to `d_model` for a dense-widened baseline.
    /// `ALTUP_TP_ACTIVE_WIDTH` sets the default (else `d_model/4`,
    /// the paper's K=4 operating point).
    pub active_width: usize,
    /// Bytes per activation element on the wire (bf16 = 2).
    /// `ALTUP_TP_ELEM_BYTES` sets the default (else 2).
    pub elem_bytes: usize,
    /// Per-link bandwidth in bytes/second. `ALTUP_TP_LINK_GBPS` sets
    /// the default in GB/s (else 25.0 — one NVLink3-class sublink).
    pub link_bps: f64,
    /// Latency floor per ring hop, ns — dominates small-payload syncs,
    /// which is exactly where AltUp's narrow active block lives.
    /// `ALTUP_TP_LINK_LATENCY_NS` sets the default (else 1500).
    pub latency_ns: u64,
    /// All-reduce rounds per sharded step: one post-attention + one
    /// post-FFN per partitioned layer (Pope et al.).
    /// `ALTUP_TP_SYNCS_PER_STEP` sets the default (else 12 — the
    /// 6-layer micro geometry).
    pub syncs_per_step: usize,
    /// Fraction of per-token compute that partitions `tp` ways
    /// (attention + FFN of the active block); the remainder —
    /// AltUp predict/correct, embeddings, norms — is replicated.
    /// `ALTUP_TP_PARTITIONED_FRAC` sets the default (else 0.85).
    pub partitioned_frac: f64,
}

impl CollectiveSpec {
    /// Read the §L12 link/width knobs (`ALTUP_TP_*`, see field docs).
    pub fn from_env() -> CollectiveSpec {
        let d_model = env::usize_at_least("ALTUP_TP_DMODEL", 1, 1024);
        CollectiveSpec {
            d_model,
            active_width: env::usize_at_least("ALTUP_TP_ACTIVE_WIDTH", 1, (d_model / 4).max(1)),
            elem_bytes: env::usize_at_least("ALTUP_TP_ELEM_BYTES", 1, 2),
            link_bps: env::f64_or("ALTUP_TP_LINK_GBPS", 25.0).max(0.001) * 1e9,
            latency_ns: env::u64_or("ALTUP_TP_LINK_LATENCY_NS", 1500),
            syncs_per_step: env::usize_at_least("ALTUP_TP_SYNCS_PER_STEP", 1, 12),
            partitioned_frac: env::f64_or("ALTUP_TP_PARTITIONED_FRAC", 0.85).clamp(0.0, 1.0),
        }
    }

    /// Ring all-reduce cost of one sync point over `tokens` fused
    /// token positions: `2(tp-1)` latency hops plus `2(tp-1)/tp` of
    /// the payload across one link. 0 when unsharded.
    pub fn allreduce_ns(&self, tp: usize, tokens: usize) -> u64 {
        if tp < 2 {
            return 0;
        }
        let bytes = (tokens * self.active_width * self.elem_bytes) as f64;
        let hops = 2 * (tp - 1) as u64;
        let wire = bytes * (hops as f64 / tp as f64) / self.link_bps * 1e9;
        self.latency_ns * hops + wire.round() as u64
    }

    /// Collective time of one full sharded step over `tokens` fused
    /// token positions: `syncs_per_step` all-reduce rounds.
    pub fn step_collective_ns(&self, tp: usize, tokens: usize) -> u64 {
        if tp < 2 {
            return 0;
        }
        self.syncs_per_step as u64 * self.allreduce_ns(tp, tokens)
    }

    /// Per-token compute multiplier of one shard in a `tp`-way group:
    /// the partitioned fraction splits `tp` ways, the replicated
    /// remainder (predict/correct etc.) is paid in full on every shard.
    pub fn compute_scale(&self, tp: usize) -> f64 {
        if tp < 2 {
            return 1.0;
        }
        (1.0 - self.partitioned_frac) + self.partitioned_frac / tp as f64
    }
}

#[derive(Debug, Clone)]
pub struct SimSpec {
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    pub vocab_size: usize,
    /// Simulated device nanoseconds per prefill token. A monolithic
    /// `decode_step` batch prefills the full `batch_size x bucket`
    /// geometry; a split `prefill` runs varlen-style over only the
    /// admitted `rows x bucket`. `ALTUP_SIM_TOKEN_NS` sets the default
    /// (else 20000 — ~20 ms per full (8,128) prefill, in the ballpark
    /// of a micro-model CPU decode — so service time, not
    /// router/scheduler overhead, dominates benches even on small
    /// shared machines).
    pub token_ns: u64,
    /// Simulated ns per slot-row per fused decode step (the decoder
    /// reads one token's worth of weights per live row).
    /// `ALTUP_SIM_DTOKEN_NS` sets the default (else `token_ns`).
    pub dtoken_ns: u64,
    /// Fixed dispatch overhead per prefill/decode-step execute.
    /// `ALTUP_SIM_DSTEP_NS` sets the default (else 50000).
    pub dstep_ns: u64,
    /// Pretend the artifact ships the split prefill/decode_token HLO
    /// pair. `false` exercises the batch-level fallback path.
    pub split_decode: bool,
    /// §L8 draft-model cost/acceptance model. `Some` means the sim
    /// "artifact" ships a draft (speculation still needs
    /// `ServerOptions::spec_gamma > 0` to switch on); `None` exercises
    /// the no-draft fallback path.
    pub draft: Option<SimDraftSpec>,
    /// §L9 paged decode-state pool. `Some` means the sim "artifact"
    /// ships the paged contract and replicas serve the continuous path
    /// out of a page pool with host-side allocation, prefix caching,
    /// and pool-aware admission; `None` exercises the monolithic
    /// fallback. `SimSpec::new` reads it from `ALTUP_POOL_PAGES` &
    /// friends.
    pub pool: Option<SimPoolSpec>,
    /// §L12 collective link/width cost model. Only consulted when a
    /// fleet unit is built as a `tp >= 2` execution group (the leader
    /// spec comes from `sharded_leader`); single-engine units never
    /// read it. `SimSpec::new` fills it from the `ALTUP_TP_*` knobs.
    pub collective: CollectiveSpec,
    /// Injected faults (default: none).
    pub fault: FaultSpec,
    /// §L11 bad-version injection: XORed into every row hash at token
    /// emission, so a "wrong weights" version emits different tokens
    /// with identical stream lengths and costs. 0 = healthy.
    /// `SimSwapSpec::apply` sets it; never read from env.
    pub bad_token_salt: u64,
    /// §L11 bad-version injection: every engine call panics (a version
    /// broken badly enough to crash on first execute).
    pub bad_panic: bool,
}

/// §L9 sim page-pool geometry: mirrors the real backend's
/// `paged` meta entry (page size) + `ALTUP_POOL_PAGES` capacity knob.
/// The pool/table/cache machinery itself is host-side and shared with
/// the real backend — only the per-token cost model is simulated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimPoolSpec {
    /// Tokens of KV per page. `ALTUP_PAGE_SIZE` sets the default
    /// (else 16).
    pub page_size: usize,
    /// Physical pages in the replica pool (the §L9 memory budget).
    pub pool_pages: usize,
    /// Cross-request prefix caching (default on;
    /// `ALTUP_PREFIX_CACHE=0` disables — the A/B baseline).
    pub prefix_cache: bool,
}

impl SimPoolSpec {
    /// `Some` iff `ALTUP_POOL_PAGES` is set nonzero — the paged sim
    /// opt-in, mirroring how a real artifact opts in via its `paged`
    /// meta entry.
    pub fn from_env() -> Option<SimPoolSpec> {
        env::opt_u64_nonzero("ALTUP_POOL_PAGES").map(|pages| SimPoolSpec {
            page_size: env::usize_at_least("ALTUP_PAGE_SIZE", 1, 16),
            pool_pages: pages as usize,
            prefix_cache: env::usize_or("ALTUP_PREFIX_CACHE", 1) > 0,
        })
    }
}

/// Sim cost + acceptance model for the §L8 draft model. Defaults
/// mirror a recycled AltUp-lite draft (fig5): roughly an eighth of the
/// full model's per-row decode cost.
#[derive(Debug, Clone)]
pub struct SimDraftSpec {
    /// Simulated ns per slot-row per draft decode step.
    /// `ALTUP_SIM_DRAFT_TOKEN_NS` sets the default (else `dtoken_ns/8`).
    pub dtoken_ns: u64,
    /// Fixed dispatch overhead per draft step (the draft executable is
    /// smaller, so cheaper to launch too). `ALTUP_SIM_DRAFT_STEP_NS`
    /// sets the default (else `dstep_ns/4`).
    pub dstep_ns: u64,
    /// Probability that any single drafted token matches the full
    /// model's greedy choice, hash-sampled per (row, position) — the
    /// accepted prefix is the leading run of matches, so the mean
    /// accepted length is `α(1-α^γ)/(1-α)`. `ALTUP_SIM_ACCEPT_RATE`
    /// sets the default (else 0.8 — the per-token match rate of a
    /// well-matched draft per Leviathan et al., which the fig5
    /// recycled draft is trained to be). 1.0 = accept-all, 0.0 =
    /// reject-all (the parity-test extremes).
    pub accept_rate: f64,
}

impl SimSpec {
    pub fn new(batch_size: usize, enc_len: usize, dec_len: usize) -> SimSpec {
        let token_ns = env::u64_or("ALTUP_SIM_TOKEN_NS", 20000);
        let dtoken_ns = env::u64_or("ALTUP_SIM_DTOKEN_NS", token_ns);
        let dstep_ns = env::u64_or("ALTUP_SIM_DSTEP_NS", 50000);
        SimSpec {
            batch_size,
            enc_len,
            dec_len,
            vocab_size: 512,
            token_ns,
            dtoken_ns,
            dstep_ns,
            split_decode: true,
            draft: Some(SimDraftSpec {
                dtoken_ns: env::u64_or("ALTUP_SIM_DRAFT_TOKEN_NS", dtoken_ns / 8),
                dstep_ns: env::u64_or("ALTUP_SIM_DRAFT_STEP_NS", dstep_ns / 4),
                accept_rate: env::f64_or("ALTUP_SIM_ACCEPT_RATE", 0.8).clamp(0.0, 1.0),
            }),
            pool: SimPoolSpec::from_env(),
            collective: CollectiveSpec::from_env(),
            fault: FaultSpec::default(),
            bad_token_salt: 0,
            bad_panic: false,
        }
    }

    /// Uniformly scale the per-token / per-step compute costs by
    /// `mult` (0.0 means 1.0 — the "unset" convention the swap knob
    /// uses). This is the ONE place a cost multiplier is applied: the
    /// exhaustive destructure (no `..`) makes adding a `SimSpec` field
    /// a compile error here, so a new cost knob must explicitly decide
    /// whether it scales — it can no longer silently miss one of the
    /// derivation sites (§L11 swap, §L12 sharded leader).
    pub fn scaled(&self, mult: f64) -> SimSpec {
        let m = if mult > 0.0 { mult } else { 1.0 };
        let scale = |ns: u64| -> u64 { ((ns as f64) * m).round().max(0.0) as u64 };
        let SimSpec {
            batch_size,
            enc_len,
            dec_len,
            vocab_size,
            token_ns,
            dtoken_ns,
            dstep_ns,
            split_decode,
            draft,
            pool,
            collective,
            fault,
            bad_token_salt,
            bad_panic,
        } = self.clone();
        SimSpec {
            batch_size,
            enc_len,
            dec_len,
            vocab_size,
            token_ns: scale(token_ns),
            dtoken_ns: scale(dtoken_ns),
            dstep_ns: scale(dstep_ns),
            split_decode,
            draft: draft.map(|d| SimDraftSpec {
                dtoken_ns: scale(d.dtoken_ns),
                dstep_ns: scale(d.dstep_ns),
                accept_rate: d.accept_rate,
            }),
            // Geometry, not cost.
            pool,
            // Link hardware + model widths are version-invariant; the
            // collective *time* is charged per sync from these, never
            // pre-multiplied into the spec.
            collective,
            // Chaos composes onto faults separately (ChaosSpec::apply).
            fault,
            bad_token_salt,
            bad_panic,
        }
    }

    /// §L12: derive the leader spec of a `tp`-way execution group from
    /// a whole-model spec. Per-token compute drops to one shard's
    /// share (`CollectiveSpec::compute_scale`: partitioned layers
    /// split `tp` ways, AltUp predict/correct replicated), while
    /// dispatch overhead — one execute per step regardless of width —
    /// and the per-shard-replicated §L8 draft keep whole-model costs.
    /// Collective time is NOT in the spec: the group charges it per
    /// sync point from `collective` at call time.
    pub fn sharded_leader(&self, tp: usize) -> SimSpec {
        let mut lead = self.scaled(self.collective.compute_scale(tp));
        lead.dstep_ns = self.dstep_ns;
        lead.draft = self.draft.clone();
        lead
    }
}

/// Sim backend instance: the spec plus per-replica fault bookkeeping
/// (the engine-call counter drives deterministic kill injection).
pub(crate) struct SimEngine {
    pub(crate) spec: SimSpec,
    pub(crate) replica: usize,
    pub(crate) calls: u64,
    /// §L12: which shard of an execution group this engine models
    /// (0 for the leader and for ordinary unsharded replicas). Only
    /// used to label injected-fault panics — the fault *routing* is
    /// `FaultSpec::for_shard`'s job at group build time.
    pub(crate) shard: usize,
}

impl SimEngine {
    pub(crate) fn new(spec: SimSpec, replica: usize) -> SimEngine {
        SimEngine { spec, replica, calls: 0, shard: 0 }
    }

    /// §L12: a group member — `replica` is the GROUP's fleet unit id
    /// (all shards share it; supervision is per unit), `shard` the
    /// member's rank within the group.
    pub(crate) fn new_shard(spec: SimSpec, replica: usize, shard: usize) -> SimEngine {
        SimEngine { spec, replica, calls: 0, shard }
    }

    /// Count one engine execute and trigger any injected fault due at
    /// this call. Panics deliberately — exercising the replica panic
    /// boundary exactly the way a real backend crash would.
    pub(crate) fn on_call(&mut self) {
        self.calls += 1;
        if self.spec.bad_panic {
            // §L11 bad-version injection: a version broken badly enough
            // to crash on its very first execute — the canary dies at
            // its probe decode, before any live traffic.
            panic!(
                "injected sim fault: bad version panics on replica {} call {} \
                 (expected during §L11 rollback tests/benches)",
                self.replica, self.calls
            );
        }
        let f = &self.spec.fault;
        let killed_here = (f.kill_replica == Some(self.replica)
            && self.calls >= f.kill_after_calls.max(1))
            || f.extra_kills
                .iter()
                .any(|&(r, after)| r == self.replica && self.calls >= after.max(1));
        if killed_here {
            panic!(
                "injected sim fault: replica {} shard {} killed at engine call {} \
                 (expected during fault-injection tests/benches)",
                self.replica, self.shard, self.calls
            );
        }
        if f.panic_rate > 0.0 {
            let h = sim_mix(((self.replica as u64) << 32) ^ self.calls);
            if (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < f.panic_rate {
                panic!(
                    "injected sim fault: hash-sampled panic on replica {} call {} \
                     (expected during fault-injection tests/benches)",
                    self.replica, self.calls
                );
            }
        }
    }
}

/// One live sim request: prompt hash (the whole decode stream derives
/// from it), next position, the hash-sampled generation length, and
/// whether fault injection marked it a stuck (never-EOS) generation.
#[derive(Clone, Copy)]
pub(crate) struct SimSlot {
    pub(crate) h: u64,
    pub(crate) pos: usize,
    pub(crate) gen_len: usize,
    pub(crate) stuck: bool,
}

impl SimSlot {
    /// The deterministic "true" (greedy full-model) token at absolute
    /// decode position `j`: EOS exactly at the sampled generation end
    /// (stuck rows never reach it), `sim_token` everywhere else. The
    /// single source of truth shared by plain decode, drafting, and
    /// verify — which is what makes sim spec decoding exact-by-
    /// construction, mirroring the real greedy-verify guarantee.
    /// `salt` is the §L11 bad-version salt (0 = healthy): it perturbs
    /// token values only — EOS placement keys off the unsalted hash,
    /// so a wrong-token version stays cost-identical.
    pub(crate) fn token_at(&self, j: usize, vocab: usize, salt: u64) -> i32 {
        if !self.stuck && j + 1 == self.gen_len {
            EOS
        } else {
            sim_token(self.h ^ salt, j, vocab)
        }
    }
}


/// FNV-1a over a row's non-padding prompt tokens only, so decode
/// streams are identical no matter which bucket executed the prompt
/// (the parity contract real bucketed decode must also satisfy).
pub(crate) fn sim_row_hash(row: &[i32]) -> u64 {
    let used = row.iter().rposition(|&t| t != 0).map_or(0, |i| i + 1);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in &row[..used] {
        h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// 64-bit finalizer (murmur3-style) shared by the gen-length sampler
/// and the hash-sampled panic injector.
pub(crate) fn sim_mix(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^ (x >> 29)
}

/// Hash-sampled generation length in [1, dec_len] — the "EOS
/// distribution" of the sim workload. The row's final token is EOS.
pub(crate) fn sim_gen_len(h: u64, dec_len: usize) -> usize {
    1 + (sim_mix(h) % dec_len.max(1) as u64) as usize
}

/// §L8 sim acceptance model: drafted token j (absolute decode position
/// `pos + j`) matches the full model's greedy choice iff a hash coin
/// keyed on (row hash, position) lands under `rate`; the accepted
/// prefix is the leading run of matches, so the mean accepted length
/// is `rate(1-rate^γ)/(1-rate)`. `rate` 1.0 accepts everything, 0.0
/// rejects everything (the parity-test extremes). Deterministic in
/// (h, pos): a retried decode accepts identically, preserving §L7
/// crash-recovery determinism. Mirrored bit-for-bit by
/// `python/tools/server_throughput_twin.py`.
pub(crate) fn sim_accept_len(h: u64, pos: usize, gamma: usize, rate: f64) -> usize {
    let mut n = 0;
    while n < gamma {
        let x = sim_mix(h ^ ((pos + n) as u64).wrapping_mul(0xD1B5_4A32_D192_ED03));
        if (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64) >= rate {
            break;
        }
        n += 1;
    }
    n
}

/// Deterministic non-EOS token for decode position `j`: in
/// [2, vocab) — ids 0 (PAD) and 1 (EOS) stay reserved.
pub(crate) fn sim_token(h: u64, j: usize, vocab: usize) -> i32 {
    let mut x = h.wrapping_mul(j as u64 + 1).wrapping_add(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 29;
    2 + (x % (vocab.max(3) as u64 - 2)) as i32
}

/// Precise simulated-device wait. Kernels round `thread::sleep` up to
/// their timer quantum (~1 ms on some hosts), which would tax the
/// continuous path's many sub-ms fused decode steps while leaving the
/// batch path's few ~20 ms sleeps untouched — so coarse-sleep the bulk
/// and yield-spin the final stretch.
pub(crate) fn sim_sleep(ns: u64) {
    if ns == 0 {
        return;
    }
    let end = Instant::now() + Duration::from_nanos(ns);
    loop {
        let now = Instant::now();
        if now >= end {
            return;
        }
        let rem = end - now;
        if rem > Duration::from_micros(1500) {
            std::thread::sleep(rem - Duration::from_micros(1200));
        } else {
            std::thread::yield_now();
        }
    }
}

/// Deterministic stand-in monolithic decode: each output row derives
/// from the row's non-padding prompt tokens only and ends at its
/// hash-sampled EOS — except injected stuck generations, which run the
/// full `dec_len` without ever emitting EOS. Costs the full geometry —
/// `batch_size x bucket` prefill plus all `dec_len` decode steps for
/// every row, early exit or not — which is exactly what the split
/// path's A/B measures against.
pub(crate) fn sim_decode(spec: &SimSpec, enc: &[i32], bucket: usize) -> Vec<Vec<i32>> {
    let mut out = Vec::with_capacity(spec.batch_size);
    let mut stuck_rows = 0u64;
    for row in enc.chunks(bucket) {
        let h = sim_row_hash(row);
        // §L11: the bad-version salt perturbs token values only —
        // stuck class, generation length, and EOS placement key off
        // the unsalted hash, so a wrong-token version is
        // cost-identical to the healthy one.
        let th = h ^ spec.bad_token_salt;
        if spec.fault.stuck(h) {
            stuck_rows += 1;
            out.push((0..spec.dec_len).map(|j| sim_token(th, j, spec.vocab_size)).collect());
            continue;
        }
        let gen_len = sim_gen_len(h, spec.dec_len);
        let mut tokens = Vec::with_capacity(gen_len);
        for j in 0..gen_len {
            tokens.push(if j + 1 == gen_len { EOS } else { sim_token(th, j, spec.vocab_size) });
        }
        out.push(tokens);
    }
    let prefill = spec.token_ns.saturating_mul((spec.batch_size * bucket) as u64);
    let decode = (spec.dec_len as u64)
        .saturating_mul(spec.dstep_ns + spec.dtoken_ns.saturating_mul(spec.batch_size as u64));
    let stuck_tax =
        stuck_rows.saturating_mul(spec.dec_len as u64).saturating_mul(spec.fault.stuck_step_ns);
    sim_sleep(prefill + decode + stuck_tax);
    out
}
