//! Client-facing serving types: `Request`/`Response`, typed failure
//! reasons, the `ServerOptions` knob set, and the `EngineSpec` a
//! server (or a §L11 rollout) boots an engine from. Split out of the
//! old monolithic `coordinator/server.rs` — paths are preserved via
//! re-exports in `server/mod.rs`.

use super::*;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic request-id source (§L13): correlates every span a request
/// leaves across router and worker threads. Id 0 is reserved for
/// request-less trace events.
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

pub struct Request {
    pub enc_tokens: Vec<i32>,
    pub reply: mpsc::Sender<Response>,
    /// When the request was created (client side), so reported latency
    /// includes time spent blocked in the bounded request channel and
    /// queued at the router — not just time after admission.
    /// `Request::new` stamps it; construct requests through it.
    pub t0: Instant,
    /// Optional absolute deadline. Left `None` by `Request::new`, the
    /// router stamps `t0 + ServerOptions::request_timeout_ms` at
    /// admission; a request past its deadline is shed with an explicit
    /// `FailReason::DeadlineExceeded` response instead of occupying a
    /// batch row or decode slot.
    pub deadline: Option<Instant>,
    /// §L10: index into `ServerOptions::tenants` for QoS accounting
    /// (rate limit, priority queue, SLO). Out-of-range indices clamp to
    /// the last configured tenant; 0 with no tenants configured.
    pub tenant: usize,
    /// §L10: scheduling class, clamped to the tenant's configured
    /// priority at admission (a request can deprioritize itself, never
    /// escalate past its tenant's class). Higher drains first.
    pub priority: u8,
    /// §L13: process-unique request id stamped by `Request::new`,
    /// correlating the request's trace spans across threads.
    pub id: u64,
    /// §L13: true once the router's deterministic sampler
    /// (`ALTUP_TRACE_SAMPLE` × content hash) selects this request for
    /// span collection. Stamped at router pop; `false` before that.
    pub traced: bool,
    /// §L13: when the router popped this request off the request
    /// channel — the admission-queue → qos-queue phase boundary.
    /// Stamped by the router only when tracing is enabled.
    pub routed: Option<Instant>,
}

impl Request {
    pub fn new(enc_tokens: Vec<i32>, reply: mpsc::Sender<Response>) -> Request {
        Request {
            enc_tokens,
            reply,
            t0: Instant::now(),
            deadline: None,
            tenant: 0,
            priority: 1,
            id: NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed),
            traced: false,
            routed: None,
        }
    }

    /// A request with an explicit client-chosen deadline (overrides the
    /// server-wide `request_timeout_ms` default).
    pub fn with_deadline(
        enc_tokens: Vec<i32>,
        reply: mpsc::Sender<Response>,
        deadline: Instant,
    ) -> Request {
        Request { deadline: Some(deadline), ..Request::new(enc_tokens, reply) }
    }

    /// §L10: a request attributed to a tenant/priority for QoS
    /// admission (token bucket, weighted queue, SLO stamp).
    pub fn for_tenant(
        enc_tokens: Vec<i32>,
        reply: mpsc::Sender<Response>,
        tenant: usize,
        priority: u8,
    ) -> Request {
        Request { tenant, priority, ..Request::new(enc_tokens, reply) }
    }

    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Why a request received an explicit terminal failure instead of
/// decoded tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// The request sat past its deadline and was shed before or during
    /// decode.
    DeadlineExceeded,
    /// Every permitted retry landed on a dying replica.
    RetriesExhausted,
    /// The server has no live replicas (startup failure or restart
    /// budget exhausted).
    NoReplicas,
    /// A replica failed during drain, after the job queue closed, so
    /// there was no requeue path left.
    AbortedOnDrain,
    /// §L9: the request's KV footprint (prompt bucket + decode room)
    /// exceeds the replica page pool's total capacity — it could never
    /// be admitted, even with every page free.
    PoolExhausted,
    /// §L10: shed at admission by the QoS layer — the tenant is over
    /// its token-bucket rate, the admission queue is at capacity (or a
    /// higher class preempted this request's slot), or the overload
    /// controller is shedding the lowest class early.
    QueueFull,
    /// §L10: shed at admission because the estimated queue wait alone
    /// already overshoots the request's deadline/SLO — rejected before
    /// spending a queue slot or prefill on doomed work.
    WouldMissDeadline,
}

impl std::fmt::Display for FailReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FailReason::DeadlineExceeded => "deadline exceeded before completion",
            FailReason::RetriesExhausted => "retry budget exhausted after replica failures",
            FailReason::NoReplicas => "no live replicas (startup failure or restart budget exhausted)",
            FailReason::AbortedOnDrain => "replica failed during drain with no requeue path left",
            FailReason::PoolExhausted => {
                "request needs more KV pages than the replica pool holds"
            }
            FailReason::QueueFull => "admission queue full or tenant over its rate limit",
            FailReason::WouldMissDeadline => {
                "estimated queue wait already overshoots the deadline"
            }
        })
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    /// Decoded tokens, truncated at the first EOS (inclusive) — under
    /// continuous batching the decode actually stopped there (early
    /// exit); under batch-level decode the full row ran and the tail
    /// past EOS is dropped for parity. Empty on explicit failures.
    pub tokens: Vec<i32>,
    /// Time from `Request::new` (includes channel/router queueing).
    pub latency: Duration,
    pub batch_fill: usize,
    /// True when the request's prompt exceeded the model's `enc_len`
    /// and was cut to fit (previously a silent truncation).
    pub truncated: bool,
    /// Sequence-length bucket the request actually executed at.
    pub bucket: usize,
    /// Which model replica served the request (`ROUTER_ID` for
    /// router-side failures that never reached a replica).
    pub replica: usize,
    /// `Some(reason)` marks an explicit terminal failure (deadline
    /// shed, retry-budget exhaustion, drain abort, dead server). §L7:
    /// every admitted request gets a terminal response — this, or
    /// tokens — never a silently dropped reply channel.
    pub failure: Option<FailReason>,
}

impl Response {
    /// An explicit terminal failure (no tokens).
    pub fn failed(reason: FailReason, t0: Instant, replica: usize) -> Response {
        Response {
            tokens: Vec::new(),
            latency: t0.elapsed(),
            batch_fill: 0,
            truncated: false,
            bucket: 0,
            replica,
            failure: Some(reason),
        }
    }

    pub fn is_failure(&self) -> bool {
        self.failure.is_some()
    }
}

#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub batch_window: Duration,
    pub seed: u64,
    /// Optional checkpoint to load weights from.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Number of model threads behind the shared router queue.
    /// `ALTUP_SERVER_REPLICAS` sets the default (else 1); 0 means 1.
    pub replicas: usize,
    /// Shape-bucketed batching (default on; `ALTUP_NO_BUCKETS=1` pads
    /// every batch to the full `enc_len` — the A/B baseline).
    pub bucketed: bool,
    /// Decode slots per replica for continuous batching; 0 = auto (the
    /// engine's `batch_size`). `ALTUP_SERVER_SLOTS` sets the default.
    pub slots: usize,
    /// Iteration-level (continuous) scheduling (default on;
    /// `ALTUP_NO_CONT_BATCH=1` forces run-to-completion batches — the
    /// A/B baseline). Replicas also fall back per-engine when the
    /// artifact ships no split HLO.
    pub continuous: bool,
    /// Capacity of the bounded request channel (admission
    /// backpressure); 0 means 1. Senders block once it fills; that
    /// blocked time still counts toward reported latency because the
    /// clock starts at `Request::new`.
    pub queue_cap: usize,
    /// Per-request deadline in ms from `Request::new`; requests past it
    /// are shed with an explicit failure instead of occupying a batch
    /// row or decode slot. `ALTUP_REQUEST_TIMEOUT_MS` sets the default
    /// (unset or 0 = no deadline).
    pub request_timeout_ms: Option<u64>,
    /// How many times a request may be requeued to another replica
    /// after a crash before it fails explicitly with
    /// `FailReason::RetriesExhausted`.
    pub max_retries: u32,
    /// How many replacement replicas the supervisor may spawn over the
    /// server's lifetime after crashes. `ALTUP_REPLICA_RESTARTS` sets
    /// the default (else 2).
    pub replica_restarts: usize,
    /// Speculative-decoding draft length γ (§L8): each continuous
    /// decode iteration drafts γ tokens per live slot and verifies
    /// them in one fused full-model step. 0 (the default) disables
    /// speculation; `ALTUP_SPEC_GAMMA` sets the default. An artifact
    /// without `verify@<γ>` for this exact γ serves at its compiled
    /// `DraftSpec::gamma` instead (`Engine::effective_spec_gamma`);
    /// with no draft model or no runnable verify at all, replicas fall
    /// back to plain decode.
    pub spec_gamma: usize,
    /// §L10 multi-tenant QoS contracts (token-bucket rates, weighted
    /// priority classes, SLOs). Empty (the default) disables the QoS
    /// layer entirely — admission is a passthrough and serving behaves
    /// exactly as pre-L10. `ALTUP_TENANT_SPEC` sets the default
    /// (`name:priority:weight:rate:burst:slo_ms`, `;`-separated).
    pub tenants: Vec<TenantSpec>,
    /// §L10: how many *extra* replicas the overload controller may
    /// spawn beyond `replicas` under sustained queue pressure (retired
    /// again when calm). 0 disables autoscaling; `ALTUP_AUTOSCALE`
    /// sets the default.
    pub autoscale: usize,
    /// Base delay in ms for the supervisor's exponential respawn
    /// backoff after a replica crash (doubles per consecutive crash,
    /// ±25% deterministic jitter). `ALTUP_RESTART_BACKOFF_MS` sets the
    /// default (else 25); 0 is clamped to 1.
    pub restart_backoff_ms: u64,
    /// §L11 rolling-swap knobs (probation window, probe count, canary
    /// health gates). `ALTUP_DEPLOY_*` set the defaults.
    pub deploy: DeployOptions,
    /// §L12: tensor-parallel group width. 0 or 1 (the default) serves
    /// every fleet unit as a whole-model single engine; `tp >= 2`
    /// builds the first `tp_groups` units as `tp`-way `ShardGroup`s
    /// (one sharded model in lockstep across `tp` devices). A real
    /// artifact without a matching §L12 sharded contract silently
    /// degrades that unit to whole-model. `ALTUP_TP` sets the default.
    pub tp: usize,
    /// §L12: how many of the `replicas` fleet units are TP groups; the
    /// rest stay whole-model DP singles, giving a heterogeneous fleet
    /// behind one router. Clamped to `replicas` at spawn. The default
    /// (`usize::MAX`, or `ALTUP_TP_GROUPS`) shards every unit.
    pub tp_groups: usize,
    /// §L13: fraction of requests span-traced, chosen deterministically
    /// by prompt-content hash (same workload ⇒ same sampled set). 0.0
    /// (the default) disables the tracing subsystem entirely — no
    /// timestamps are taken on the per-token path. `ALTUP_TRACE_SAMPLE`
    /// sets the default; values clamp to [0, 1].
    pub trace_sample: f64,
    /// §L13: per-worker span ring capacity. When a worker's ring fills,
    /// the oldest span is dropped and `TraceStats::dropped_spans`
    /// counts it. `ALTUP_TRACE_RING` sets the default (else 4096).
    pub trace_ring: usize,
    /// §L13: timeline window width in ms for the gauge time series.
    /// `ALTUP_TRACE_WINDOW_MS` sets the default (else 100).
    pub trace_window_ms: u64,
}

impl Default for ServerOptions {
    // All knob defaults resolve through `util::env` (§L8 satellite:
    // one typed parse-with-default helper instead of a hand-rolled
    // chain per knob).
    fn default() -> Self {
        ServerOptions {
            batch_window: Duration::from_millis(5),
            seed: 0,
            checkpoint: None,
            replicas: env::usize_at_least("ALTUP_SERVER_REPLICAS", 1, 1),
            bucketed: !env::flag("ALTUP_NO_BUCKETS"),
            slots: env::usize_or("ALTUP_SERVER_SLOTS", 0),
            continuous: !env::flag("ALTUP_NO_CONT_BATCH"),
            queue_cap: 1024,
            request_timeout_ms: env::opt_u64_nonzero("ALTUP_REQUEST_TIMEOUT_MS"),
            max_retries: 2,
            replica_restarts: env::usize_or("ALTUP_REPLICA_RESTARTS", 2),
            spec_gamma: spec::gamma_from_env(),
            tenants: admission::tenants_from_env(),
            autoscale: env::usize_or("ALTUP_AUTOSCALE", 0),
            restart_backoff_ms: env::u64_or("ALTUP_RESTART_BACKOFF_MS", 25),
            deploy: DeployOptions::default(),
            tp: env::usize_or("ALTUP_TP", 0),
            tp_groups: env::usize_or("ALTUP_TP_GROUPS", usize::MAX),
            trace_sample: env::f64_or("ALTUP_TRACE_SAMPLE", 0.0).clamp(0.0, 1.0),
            trace_ring: env::usize_at_least("ALTUP_TRACE_RING", 1, trace::DEFAULT_RING),
            trace_window_ms: env::u64_or("ALTUP_TRACE_WINDOW_MS", trace::DEFAULT_WINDOW_MS),
        }
    }
}

impl ServerOptions {
    /// §L12: the group width fleet unit `i` of the INITIAL fleet gets —
    /// the first `tp_groups` units are `tp`-way groups, the rest
    /// whole-model singles. 1 = unsharded. Respawns/autoscale spawns
    /// don't call this; the supervisor tracks live unit shapes itself
    /// (`Supervisor::shapes`).
    pub fn unit_tp(&self, unit: usize) -> usize {
        if self.tp >= 2 && unit < self.tp_groups {
            self.tp
        } else {
            1
        }
    }
}

/// Which decode backend the replicas run.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// A compiled artifact by suite name (requires a real PJRT backend).
    Artifact { name: String },
    /// Deterministic backend-free decode with a token-proportional cost
    /// model — for scheduler tests/benches on machines without the
    /// xla-rs bindings.
    Sim(SimSpec),
}
