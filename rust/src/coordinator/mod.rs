//! L3 coordinator: training loop, evaluation, experiment pipelines, and
//! the batching eval server (DESIGN.md S12).

pub mod admission;
pub mod deploy;
pub mod metrics;
pub mod pipeline;
pub mod server;
pub mod spec;
pub mod trace;
pub mod trainer;
