//! Training coordinator: owns the step loop over a `Session`, the LR
//! schedule, metrics logging, periodic eval, and checkpoints.

use crate::coordinator::metrics::{rsqrt_lr, EvalResult, MetricsLog};
use crate::data::batcher::{Batch, PretrainBatcher, TaskBatcher};
use crate::data::tasks::{exact_match, f1_score};
use crate::runtime::client::Client;
use crate::runtime::session::Session;
use anyhow::Result;
use std::time::Instant;

/// Which data source feeds the trainer.
pub enum DataSource {
    Pretrain(PretrainBatcher),
    Task(TaskBatcher),
}

impl DataSource {
    pub fn next_batch(&mut self) -> Batch {
        match self {
            DataSource::Pretrain(b) => b.next_batch(),
            DataSource::Task(b) => b.next_batch(),
        }
    }
}

pub struct TrainOptions {
    pub steps: u64,
    pub warmup: u64,
    pub base_lr: f64,
    /// Constant LR (finetune recipe) if set — overrides rsqrt.
    pub constant_lr: Option<f64>,
    pub log_every: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub checkpoint_path: Option<std::path::PathBuf>,
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            warmup: 1000,
            base_lr: 1.0,
            constant_lr: None,
            log_every: 10,
            eval_every: 0,
            eval_batches: 4,
            checkpoint_path: None,
            verbose: true,
        }
    }
}

pub struct Trainer {
    pub session: Session,
    pub source: DataSource,
    pub log: MetricsLog,
}

impl Trainer {
    pub fn new(session: Session, source: DataSource, log: MetricsLog) -> Trainer {
        Trainer { session, source, log }
    }

    pub fn lr_at(&self, step: u64, opts: &TrainOptions) -> f64 {
        match opts.constant_lr {
            Some(lr) => lr,
            None => rsqrt_lr(step, opts.warmup, opts.base_lr),
        }
    }

    /// Run the training loop; returns (final train loss EMA, steps/sec).
    pub fn run(&mut self, client: &Client, opts: &TrainOptions) -> Result<(f64, f64)> {
        let t0 = Instant::now();
        let mut ema: Option<f64> = None;
        for _ in 0..opts.steps {
            let step = self.session.store.step + 1;
            let lr = self.lr_at(step, opts) as f32;
            let batch = self.source.next_batch();
            let m = self.session.train_step(client, lr, step as u32, &batch)?;
            let loss = m.loss as f64;
            ema = Some(match ema {
                None => loss,
                Some(e) => 0.95 * e + 0.05 * loss,
            });
            if step % opts.log_every == 0 || step == 1 {
                self.log.log(
                    step,
                    &[
                        ("loss", loss),
                        ("loss_ema", ema.unwrap()),
                        ("acc", m.accuracy() as f64),
                        ("lr", lr as f64),
                    ],
                );
                if opts.verbose {
                    println!(
                        "step {:>6}  loss {:>7.4}  ema {:>7.4}  acc {:>5.1}%  lr {:.2e}",
                        step,
                        loss,
                        ema.unwrap(),
                        m.accuracy() * 100.0,
                        lr
                    );
                }
            }
            if opts.eval_every > 0 && step % opts.eval_every == 0 {
                let ev = self.eval(client, opts.eval_batches)?;
                self.log.log(step, &[("eval_loss", ev.loss), ("eval_acc", ev.accuracy)]);
                if opts.verbose {
                    println!("  eval @{step}: {}", ev.summary());
                }
            }
            if let Some(path) = &opts.checkpoint_path {
                if step % 1000 == 0 || step == opts.steps {
                    self.session.checkpoint(path)?;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let sps = opts.steps as f64 / wall;
        // Runtime split (§Perf L4): where the wall-clock went —
        // executing HLO, host marshalling, or host<->device transfers.
        self.log.log(
            self.session.store.step,
            &[
                ("exec_seconds", self.session.exec_seconds),
                ("marshal_seconds", self.session.marshal_seconds),
                ("transfer_seconds", self.session.transfer_seconds),
            ],
        );
        if opts.verbose {
            println!(
                "runtime split: execute {:.2}s, marshal {:.2}s, transfer {:.2}s",
                self.session.exec_seconds,
                self.session.marshal_seconds,
                self.session.transfer_seconds
            );
        }
        Ok((ema.unwrap_or(f64::NAN), sps))
    }

    /// Teacher-forced eval on a held-out stream.
    pub fn eval(&mut self, client: &Client, batches: usize) -> Result<EvalResult> {
        let mut source = match &self.source {
            DataSource::Pretrain(b) => DataSource::Pretrain(b.validation()),
            DataSource::Task(b) => {
                // Same task distribution (same seed), held-out indices.
                let mut tb =
                    TaskBatcher::new(b.task.eval_twin(), b.batch_size, b.enc_len, b.dec_len);
                tb.eval_split();
                DataSource::Task(tb)
            }
        };
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut ntok = 0.0f64;
        let mut examples = 0usize;
        for _ in 0..batches {
            let batch = source.next_batch();
            let m = self.session.eval_step(client, &batch)?;
            loss_sum += m.loss as f64;
            correct += m.correct as f64;
            ntok += m.ntok as f64;
            examples += batch.batch_size;
        }
        Ok(EvalResult {
            loss: loss_sum / ntok.max(1.0),
            accuracy: correct / ntok.max(1.0),
            em: 0.0,
            f1: 0.0,
            examples,
        })
    }

    /// Generative eval: greedy decode + EM/F1 against task answers.
    pub fn eval_generative(&mut self, client: &Client, batches: usize) -> Result<EvalResult> {
        let DataSource::Task(b) = &self.source else {
            anyhow::bail!("generative eval needs a task source");
        };
        let mut tb = TaskBatcher::new(b.task.eval_twin(), b.batch_size, b.enc_len, b.dec_len);
        tb.eval_split();

        let tk =
            crate::data::tokenizer::Tokenizer::new(self.session.artifact.config.vocab_size)?;
        let mut em_sum = 0.0;
        let mut f1_sum = 0.0;
        let mut n = 0usize;
        for _ in 0..batches {
            let batch = tb.next_batch();
            let decoded = self.session.decode(client, &batch.enc_tokens)?;
            for (row, gold) in decoded.iter().zip(batch.answers.iter()) {
                let pred = tk.content_of(tk.until_eos(row));
                em_sum += exact_match(&pred, gold);
                f1_sum += f1_score(&pred, gold);
                n += 1;
            }
        }
        Ok(EvalResult {
            loss: 0.0,
            accuracy: 0.0,
            em: em_sum / n.max(1) as f64,
            f1: f1_sum / n.max(1) as f64,
            examples: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_modes() {
        let log = MetricsLog::in_memory();
        let _ = log;
        let opts = TrainOptions { constant_lr: Some(1e-3), ..Default::default() };
        // schedule math only (no session required)
        assert_eq!(
            match opts.constant_lr {
                Some(lr) => lr,
                None => 0.0,
            },
            1e-3
        );
        let opts2 = TrainOptions { warmup: 100, base_lr: 1.0, ..Default::default() };
        assert!((rsqrt_lr(1, opts2.warmup, opts2.base_lr) - 0.1).abs() < 1e-12);
    }
}
