//! Training coordinator: owns the step loop over a `Session`, the LR
//! schedule, metrics logging, periodic eval, and checkpoints.
//!
//! §Perf L5: the step loop is double-buffered — batch N+1 is prepared
//! (corpus sampling, span corruption, padding) on a background worker
//! (`data::prefetch`) while batch N executes, so host data preparation
//! hides behind `exec_seconds`. `ALTUP_NO_PREFETCH=1` restores the
//! synchronous baseline; the residual blocked time is reported as
//! `data_wait_seconds`.

use crate::coordinator::metrics::{rsqrt_lr, EvalResult, MetricsLog};
use crate::data::batcher::{Batch, BatchSource, PretrainBatcher, TaskBatcher};
use crate::data::prefetch::{self, Prefetcher};
use crate::data::tasks::{exact_match, f1_score};
use crate::runtime::client::Client;
use crate::runtime::session::Session;
use anyhow::Result;
use std::time::Instant;

/// Which data source feeds the trainer.
pub enum DataSource {
    Pretrain(PretrainBatcher),
    Task(TaskBatcher),
    /// Placeholder left behind while the real source is loaned to the
    /// prefetch worker (`Trainer::run`); never produces batches.
    Loaned,
}

impl DataSource {
    pub fn next_batch(&mut self) -> Batch {
        match self {
            DataSource::Pretrain(b) => b.next_batch(),
            DataSource::Task(b) => b.next_batch(),
            DataSource::Loaned => panic!("data source is loaned to the prefetcher"),
        }
    }

    /// A fresh held-out twin of this source: same distribution, indices
    /// from a disjoint range. Repeated calls yield identical streams,
    /// so periodic evals always score the same held-out data.
    pub fn eval_twin(&self) -> DataSource {
        match self {
            DataSource::Pretrain(b) => DataSource::Pretrain(b.validation()),
            DataSource::Task(b) => {
                let mut tb =
                    TaskBatcher::new(b.task.eval_twin(), b.batch_size, b.enc_len, b.dec_len);
                tb.eval_split();
                DataSource::Task(tb)
            }
            DataSource::Loaned => panic!("data source is loaned to the prefetcher"),
        }
    }
}

impl BatchSource for DataSource {
    fn next_batch(&mut self) -> Batch {
        DataSource::next_batch(self)
    }
}

pub struct TrainOptions {
    pub steps: u64,
    pub warmup: u64,
    pub base_lr: f64,
    /// Constant LR (finetune recipe) if set — overrides rsqrt.
    pub constant_lr: Option<f64>,
    pub log_every: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub checkpoint_path: Option<std::path::PathBuf>,
    pub verbose: bool,
    /// Overlap batch preparation with execution (§Perf L5).
    pub prefetch: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            warmup: 1000,
            base_lr: 1.0,
            constant_lr: None,
            log_every: 10,
            eval_every: 0,
            eval_batches: 4,
            checkpoint_path: None,
            verbose: true,
            prefetch: prefetch::enabled_from_env(),
        }
    }
}

pub struct Trainer {
    pub session: Session,
    pub source: DataSource,
    pub log: MetricsLog,
    /// Seconds the last `run` spent blocked waiting for batch data
    /// (≈0 when prefetch hides preparation behind execution).
    pub data_wait_seconds: f64,
}

impl Trainer {
    pub fn new(session: Session, source: DataSource, log: MetricsLog) -> Trainer {
        Trainer { session, source, log, data_wait_seconds: 0.0 }
    }

    pub fn lr_at(&self, step: u64, opts: &TrainOptions) -> f64 {
        match opts.constant_lr {
            Some(lr) => lr,
            None => rsqrt_lr(step, opts.warmup, opts.base_lr),
        }
    }

    /// Run the training loop; returns (final train loss EMA, steps/sec).
    pub fn run(&mut self, client: &Client, opts: &TrainOptions) -> Result<(f64, f64)> {
        let t0 = Instant::now();
        let mut ema: Option<f64> = None;
        // The prefetcher takes the source; keep a twin factory around
        // for periodic evals while it is loaned out.
        let eval_twin = if opts.eval_every > 0 { Some(self.source.eval_twin()) } else { None };
        let mut prefetcher = if opts.prefetch && opts.steps > 0 {
            let source = std::mem::replace(&mut self.source, DataSource::Loaned);
            Some(Prefetcher::spawn(source, opts.steps as usize, prefetch::depth_from_env()))
        } else {
            None
        };
        let mut data_wait_direct = 0.0f64;
        let mut run_err: Option<anyhow::Error> = None;
        for _ in 0..opts.steps {
            let step = self.session.store.step + 1;
            let lr = self.lr_at(step, opts) as f32;
            let batch = match prefetcher.as_mut() {
                Some(p) => match p.next() {
                    Some(b) => b,
                    None => {
                        run_err = Some(anyhow::anyhow!("prefetch worker ended early"));
                        break;
                    }
                },
                None => {
                    let tb = Instant::now();
                    let b = self.source.next_batch();
                    data_wait_direct += tb.elapsed().as_secs_f64();
                    b
                }
            };
            let m = match self.session.train_step(client, lr, step as u32, &batch) {
                Ok(m) => m,
                Err(e) => {
                    run_err = Some(e);
                    break;
                }
            };
            let loss = m.loss as f64;
            ema = Some(match ema {
                None => loss,
                Some(e) => 0.95 * e + 0.05 * loss,
            });
            if step % opts.log_every == 0 || step == 1 {
                self.log.log(
                    step,
                    &[
                        ("loss", loss),
                        ("loss_ema", ema.unwrap()),
                        ("acc", m.accuracy() as f64),
                        ("lr", lr as f64),
                    ],
                );
                if opts.verbose {
                    println!(
                        "step {:>6}  loss {:>7.4}  ema {:>7.4}  acc {:>5.1}%  lr {:.2e}",
                        step,
                        loss,
                        ema.unwrap(),
                        m.accuracy() * 100.0,
                        lr
                    );
                }
            }
            if opts.eval_every > 0 && step % opts.eval_every == 0 {
                let twin = eval_twin.as_ref().expect("eval twin").eval_twin();
                match self.eval_on(client, opts.eval_batches, twin) {
                    Ok(ev) => {
                        self.log
                            .log(step, &[("eval_loss", ev.loss), ("eval_acc", ev.accuracy)]);
                        if opts.verbose {
                            println!("  eval @{step}: {}", ev.summary());
                        }
                    }
                    Err(e) => {
                        run_err = Some(e);
                        break;
                    }
                }
            }
            if let Some(path) = &opts.checkpoint_path {
                if step % 1000 == 0 || step == opts.steps {
                    if let Err(e) = self.session.checkpoint(path) {
                        run_err = Some(e);
                        break;
                    }
                }
            }
        }
        // Reclaim the source from the worker (also on error paths, so
        // the trainer stays usable for eval afterwards).
        self.data_wait_seconds = match prefetcher.take() {
            Some(p) => {
                let (source, wait) = p.finish();
                match source {
                    Some(source) => self.source = source,
                    // Worker panicked: leave the source Loaned and make
                    // sure the run reports an error instead of panicking
                    // on this cleanup path.
                    None => {
                        if run_err.is_none() {
                            run_err =
                                Some(anyhow::anyhow!("prefetch worker panicked mid-run"));
                        }
                    }
                }
                wait
            }
            None => data_wait_direct,
        };
        if let Some(e) = run_err {
            return Err(e);
        }
        let wall = t0.elapsed().as_secs_f64();
        let sps = opts.steps as f64 / wall;
        // Runtime split (§Perf L4/L5): where the wall-clock went —
        // executing HLO, host marshalling, host<->device transfers, and
        // waiting on batch data.
        self.log.log(
            self.session.store.step,
            &[
                ("exec_seconds", self.session.exec_seconds),
                ("marshal_seconds", self.session.marshal_seconds),
                ("transfer_seconds", self.session.transfer_seconds),
                ("data_wait_seconds", self.data_wait_seconds),
            ],
        );
        if opts.verbose {
            println!(
                "runtime split: execute {:.2}s, marshal {:.2}s, transfer {:.2}s, data wait {:.2}s",
                self.session.exec_seconds,
                self.session.marshal_seconds,
                self.session.transfer_seconds,
                self.data_wait_seconds
            );
        }
        Ok((ema.unwrap_or(f64::NAN), sps))
    }

    /// Teacher-forced eval on a held-out stream.
    pub fn eval(&mut self, client: &Client, batches: usize) -> Result<EvalResult> {
        let twin = self.source.eval_twin();
        self.eval_on(client, batches, twin)
    }

    /// Teacher-forced eval over an explicit source (used directly for
    /// periodic evals while the main source is loaned to the prefetch
    /// worker).
    fn eval_on(
        &mut self,
        client: &Client,
        batches: usize,
        mut source: DataSource,
    ) -> Result<EvalResult> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut ntok = 0.0f64;
        let mut examples = 0usize;
        for _ in 0..batches {
            let batch = source.next_batch();
            let m = self.session.eval_step(client, &batch)?;
            loss_sum += m.loss as f64;
            correct += m.correct as f64;
            ntok += m.ntok as f64;
            examples += batch.batch_size;
        }
        Ok(EvalResult {
            loss: loss_sum / ntok.max(1.0),
            accuracy: correct / ntok.max(1.0),
            em: 0.0,
            f1: 0.0,
            examples,
        })
    }

    /// Generative eval: greedy decode + EM/F1 against task answers.
    pub fn eval_generative(&mut self, client: &Client, batches: usize) -> Result<EvalResult> {
        let DataSource::Task(b) = &self.source else {
            anyhow::bail!("generative eval needs a task source");
        };
        let mut tb = TaskBatcher::new(b.task.eval_twin(), b.batch_size, b.enc_len, b.dec_len);
        tb.eval_split();

        let tk =
            crate::data::tokenizer::Tokenizer::new(self.session.artifact.config.vocab_size)?;
        let mut em_sum = 0.0;
        let mut f1_sum = 0.0;
        let mut n = 0usize;
        for _ in 0..batches {
            let batch = tb.next_batch();
            let decoded = self.session.decode(client, &batch.enc_tokens)?;
            for (row, gold) in decoded.iter().zip(batch.answers.iter()) {
                let pred = tk.content_of(tk.until_eos(row));
                em_sum += exact_match(&pred, gold);
                f1_sum += f1_score(&pred, gold);
                n += 1;
            }
        }
        Ok(EvalResult {
            loss: 0.0,
            accuracy: 0.0,
            em: em_sum / n.max(1) as f64,
            f1: f1_sum / n.max(1) as f64,
            examples: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_modes() {
        let log = MetricsLog::in_memory();
        let _ = log;
        let opts = TrainOptions { constant_lr: Some(1e-3), ..Default::default() };
        // schedule math only (no session required)
        assert_eq!(
            match opts.constant_lr {
                Some(lr) => lr,
                None => 0.0,
            },
            1e-3
        );
        let opts2 = TrainOptions { warmup: 100, base_lr: 1.0, ..Default::default() };
        assert!((rsqrt_lr(1, opts2.warmup, opts2.base_lr) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn eval_twin_streams_are_repeatable() {
        let mut a = DataSource::Pretrain(PretrainBatcher::new(2048, 2, 32, 16, 5));
        // Twin-of-twin must equal twin: periodic evals during a
        // prefetched run re-derive the twin each time.
        let mut t1 = a.eval_twin();
        let mut t2 = a.eval_twin().eval_twin();
        assert_eq!(t1.next_batch().enc_tokens, t2.next_batch().enc_tokens);
        // And the twin is disjoint from the training stream.
        assert_ne!(a.next_batch().enc_tokens, a.eval_twin().next_batch().enc_tokens);
    }
}
