//! Pretrain -> finetune experiment pipelines, mirroring the paper's
//! recipe (Sec. 5 "Setting"): pretrain on span corruption, then finetune
//! on each benchmark task and report its metric.

use crate::coordinator::metrics::{EvalResult, MetricsLog};
use crate::coordinator::trainer::{DataSource, TrainOptions, Trainer};
use crate::data::batcher::{PretrainBatcher, TaskBatcher};
use crate::data::tasks::{Task, TaskKind};
use crate::runtime::artifact::{load_named, Artifact};
use crate::runtime::client::Client;
use crate::runtime::session::Session;
use anyhow::Result;

/// Scaled-down mirror of the paper's pretrain+finetune recipe.
#[derive(Debug, Clone)]
pub struct PipelineOptions {
    pub pretrain_steps: u64,
    pub finetune_steps: u64,
    pub warmup: u64,
    pub finetune_lr: f64,
    pub eval_batches: usize,
    pub seed: u64,
    pub verbose: bool,
    /// Overlap batch preparation with device execution (§Perf L5).
    pub prefetch: bool,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            pretrain_steps: 300,
            finetune_steps: 100,
            // rsqrt warmup: the paper uses 10k; for short scaled runs the
            // schedule is ~constant 1/sqrt(warmup), so 1000 ~= LR 0.03.
            // Small warmups (=> LR ~0.2+) destabilize Adafactor at micro
            // scale (see EXPERIMENTS.md run log).
            warmup: 1000,
            finetune_lr: 1e-3,
            eval_batches: 8,
            seed: 0,
            verbose: false,
            prefetch: crate::data::prefetch::enabled_from_env(),
        }
    }
}

/// Results of one full pipeline run for one artifact.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub artifact: String,
    pub pretrain_accuracy: f64,
    pub pretrain_loss: f64,
    pub train_steps_per_sec: f64,
    /// Pretrain runtime split (§Perf L4): PJRT execute, host
    /// marshalling, and host<->device transfer wall-clock seconds.
    pub exec_seconds: f64,
    pub marshal_seconds: f64,
    pub transfer_seconds: f64,
    /// Pretrain seconds blocked waiting for batch data (§Perf L5 —
    /// ~0 when the prefetcher hides preparation behind execution).
    pub data_wait_seconds: f64,
    pub task_results: Vec<(TaskKind, EvalResult)>,
}

/// Pretrain an artifact and return (session, pretrain eval, steps/sec,
/// data-wait seconds).
pub fn pretrain(
    client: &Client,
    artifact: Artifact,
    opts: &PipelineOptions,
) -> Result<(Session, EvalResult, f64, f64)> {
    let cfg = artifact.config.clone();
    let session = Session::open(client, artifact, opts.seed)?;
    let batcher = PretrainBatcher::new(
        cfg.vocab_size,
        cfg.batch_size,
        cfg.enc_len,
        cfg.dec_len,
        opts.seed ^ 0xDA7A,
    );
    let mut trainer = Trainer::new(session, DataSource::Pretrain(batcher), MetricsLog::in_memory());
    let topts = TrainOptions {
        steps: opts.pretrain_steps,
        warmup: opts.warmup,
        base_lr: 1.0,
        log_every: 50,
        verbose: opts.verbose,
        prefetch: opts.prefetch,
        ..Default::default()
    };
    let (_, sps) = trainer.run(client, &topts)?;
    let data_wait = trainer.data_wait_seconds;
    let ev = trainer.eval(client, opts.eval_batches)?;
    let mut session = trainer.session;
    session.sync_store()?; // finetune_task clones weights via store
    Ok((session, ev, sps, data_wait))
}

/// Finetune a pretrained session on one task; returns its eval result.
/// The pretrained `ParamStore` is cloned in memory so each task starts
/// from the same state (the caller must have `sync_store()`d —
/// `pretrain` does).
pub fn finetune_task(
    client: &Client,
    base: &Session,
    kind: TaskKind,
    opts: &PipelineOptions,
) -> Result<EvalResult> {
    let artifact = base.artifact.clone();
    let cfg = artifact.config.clone();
    let mut session = Session::open(client, artifact, opts.seed)?;
    session.store = base.store.clone();
    session.invalidate_state();

    let task = Task::new(kind, cfg.vocab_size, opts.seed ^ 0x7A58);
    let batcher = TaskBatcher::new(task, cfg.batch_size, cfg.enc_len, cfg.dec_len);
    let mut trainer = Trainer::new(session, DataSource::Task(batcher), MetricsLog::in_memory());
    let topts = TrainOptions {
        steps: opts.finetune_steps,
        constant_lr: Some(opts.finetune_lr),
        log_every: 50,
        verbose: opts.verbose,
        prefetch: opts.prefetch,
        ..Default::default()
    };
    trainer.run(client, &topts)?;
    let mut ev = trainer.eval(client, opts.eval_batches)?;
    if kind.is_generative() {
        let gen = trainer.eval_generative(client, opts.eval_batches.min(4))?;
        ev.em = gen.em;
        ev.f1 = gen.f1;
    }
    Ok(ev)
}

/// Full paper recipe for one artifact name.
pub fn run_pipeline(
    client: &Client,
    artifact_name: &str,
    tasks: &[TaskKind],
    opts: &PipelineOptions,
) -> Result<PipelineResult> {
    let artifact = load_named(artifact_name)?;
    let (session, pre_ev, sps, data_wait_seconds) = pretrain(client, artifact, opts)?;
    let (exec_seconds, marshal_seconds, transfer_seconds) =
        (session.exec_seconds, session.marshal_seconds, session.transfer_seconds);
    let mut task_results = Vec::new();
    for &kind in tasks {
        let ev = finetune_task(client, &session, kind, opts)?;
        if opts.verbose {
            println!("  {}: {}", kind.name(), ev.summary());
        }
        task_results.push((kind, ev));
    }
    Ok(PipelineResult {
        artifact: artifact_name.to_string(),
        pretrain_accuracy: pre_ev.accuracy,
        pretrain_loss: pre_ev.loss,
        train_steps_per_sec: sps,
        exec_seconds,
        marshal_seconds,
        transfer_seconds,
        data_wait_seconds,
        task_results,
    })
}
