//! §L11 zero-downtime rolling weight swap with canary health gates and
//! automatic rollback.
//!
//! A rollout replaces the fleet's artifact version one replica at a
//! time behind the existing §L7 supervisor: the router drains one
//! replica (a *targeted* drain — unlike the §L10 scale-down sentinel,
//! which any replica may pop), lets its slots retire naturally, then
//! spawns a replacement on the new version as a **canary**. The canary
//! must pass two gates before the rollout promotes to the next
//! replica:
//!
//! 1. **Token-parity probes** — before serving any live traffic, the
//!    canary decodes a pinned set of deterministic probe prompts and
//!    publishes the rows; the router compares them against a baseline
//!    computed from the *old* version on a side thread. A mismatch
//!    abandons the canary at the gate — it exits cleanly having served
//!    zero requests, so a bad version never emits a single wrong token
//!    to a client.
//! 2. **Probation window** — once admitted, the canary serves live
//!    traffic for N requests (or a wall-clock window on idle fleets)
//!    while publishing its request/failure/p95 counters; the router
//!    rolls back on excess non-shed error rate or p95 blown past a
//!    multiple of the fleet's old-version p95 EWMA.
//!
//! A failing canary triggers **automatic rollback**: that replica
//! reloads the old version and the rollout freezes with a typed
//! [`DeployStatus`]. Crash respawns and §L10 autoscale replicas always
//! land on the rollout's *decided* version (flipped to the new version
//! after the first canary passes, reverted on rollback). The §L9 page
//! pool and prefix cache are replica-local, so a swap inherently
//! releases the drained replica's pages and starts the new version
//! with a cold (version-clean) prefix cache.
//!
//! State machine (driven from the router's supervision pass, one
//! replica at a time):
//!
//! ```text
//! Idle -> Preparing -> Draining -> Probing -> Probation --pass--> (next replica | Completed)
//!            |            |           |          |
//!            v (load/geometry error)  |          +--fail/crash--> RollingBack -> RolledBack
//!          Failed         +-----------+---------------crash-----> RollingBack -> RolledBack
//! ```
//!
//! `shutdown()` during a rollout aborts it cleanly: a canary holding
//! at the gate is abandoned (clean exit, nothing half-loaded), the
//! drain target finishes the normal §L7 drain, and the rollout reports
//! `Aborted` (counted in `DeployMeter::aborted`, surfaced in the
//! shutdown summary).

use crate::coordinator::server::{
    engine_dims, pack_requests, truncate_at_eos, Engine, EngineSpec, FaultSpec, ServerOptions,
    ServerStats, Supervisor,
};
use crate::util::env;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Replica id used by the baseline probe engine (never a fleet id, so
/// deterministic kill schedules keyed on fleet ids cannot hit it).
const PROBE_REPLICA_ID: usize = usize::MAX - 1;

/// `DeployShared` gate values, in canary-lifecycle order.
pub(crate) const GATE_HOLD: usize = 0;
pub(crate) const GATE_ADMIT: usize = 1;
pub(crate) const GATE_ABANDON: usize = 2;

/// Poison-proof lock: deploy state is read across the replica panic
/// boundary and entries are plain data.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// §L11 rollout knobs. All defaults resolve through `util::env`
/// (`ALTUP_DEPLOY_*`); tests override the struct directly instead of
/// mutating the process environment.
#[derive(Debug, Clone)]
pub struct DeployOptions {
    /// Probation window in requests: the canary must finish this many
    /// terminal outcomes under the health gates before promotion.
    /// `ALTUP_DEPLOY_PROBATION` sets the default (else 16).
    pub probation: usize,
    /// Probation wall-clock cap in ms: an idle fleet promotes a
    /// healthy canary after this long even without traffic, so a
    /// rollout never wedges waiting for requests.
    /// `ALTUP_DEPLOY_PROBATION_MS` sets the default (else 1500).
    pub probation_ms: u64,
    /// Pinned token-parity probe prompts decoded by every canary
    /// before it serves (clamped to the engine's batch size; 0
    /// disables the parity gate). `ALTUP_DEPLOY_PROBES` sets the
    /// default (else 2).
    pub probes: usize,
    /// Maximum non-shed failure rate (failures / terminal outcomes)
    /// the canary may show over its probation window.
    /// `ALTUP_DEPLOY_MAX_ERR` sets the default (else 0.1).
    pub max_err: f64,
    /// Latency gate: the canary's p95 must stay within this factor of
    /// the fleet's old-version p95 EWMA. `ALTUP_DEPLOY_LAT_FACTOR`
    /// sets the default (else 4.0).
    pub lat_factor: f64,
    /// How long a canary holds at the probe gate waiting for the
    /// router's verdict before giving up (clean exit -> rollback).
    /// `ALTUP_DEPLOY_HOLD_MS` sets the default (else 5000).
    pub hold_ms: u64,
}

impl Default for DeployOptions {
    fn default() -> Self {
        DeployOptions {
            probation: env::usize_at_least("ALTUP_DEPLOY_PROBATION", 1, 16),
            probation_ms: env::u64_or("ALTUP_DEPLOY_PROBATION_MS", 1500),
            probes: env::usize_or("ALTUP_DEPLOY_PROBES", 2),
            max_err: env::f64_or("ALTUP_DEPLOY_MAX_ERR", 0.1).clamp(0.0, 1.0),
            lat_factor: env::f64_or("ALTUP_DEPLOY_LAT_FACTOR", 4.0).max(1.0),
            hold_ms: env::u64_or("ALTUP_DEPLOY_HOLD_MS", 5000),
        }
    }
}

/// Typed rollout outcome, returned by `ServerHandle::deploy` and
/// queryable mid-flight via `ServerHandle::deploy_status`.
#[derive(Debug, Clone, PartialEq)]
pub enum DeployStatus {
    /// No rollout has run on this server.
    Idle,
    /// A rollout is mid-flight: `swapped` of `fleet` replicas promoted
    /// to `version` so far.
    InProgress { version: u32, swapped: usize, fleet: usize },
    /// Every replica promoted to `version`.
    Completed { version: u32, swapped: usize },
    /// A canary failed a health gate (or crashed); its replica
    /// reloaded the old version and the rollout froze. `swapped`
    /// replicas promoted before the freeze keep serving the new
    /// version; respawns and autoscale land back on the old version.
    RolledBack { version: u32, swapped: usize, reason: String },
    /// The new version never reached a canary: artifact load /
    /// checksum / geometry validation failed (a typed load error, not
    /// a first-execute replica panic).
    Failed { version: u32, reason: String },
    /// `shutdown()` (or fleet loss) interrupted the rollout; no
    /// replica was left mid-drain or holding at the gate.
    Aborted { version: u32, reason: String },
}

impl DeployStatus {
    /// Whether the rollout reached a terminal state.
    pub fn terminal(&self) -> bool {
        !matches!(self, DeployStatus::Idle | DeployStatus::InProgress { .. })
    }
}

impl std::fmt::Display for DeployStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeployStatus::Idle => write!(f, "idle"),
            DeployStatus::InProgress { version, swapped, fleet } => {
                write!(f, "rolling out v{version}: {swapped}/{fleet} replicas swapped")
            }
            DeployStatus::Completed { version, swapped } => {
                write!(f, "completed: {swapped} replica(s) on v{version}")
            }
            DeployStatus::RolledBack { version, swapped, reason } => {
                write!(f, "rolled back v{version} after {swapped} swap(s): {reason}")
            }
            DeployStatus::Failed { version, reason } => {
                write!(f, "v{version} rejected before canary: {reason}")
            }
            DeployStatus::Aborted { version, reason } => {
                write!(f, "rollout of v{version} aborted: {reason}")
            }
        }
    }
}

/// Cross-thread rollout levers, owned by `QosShared` so replicas reach
/// them without any new plumbing. Written by the router's rollout
/// driver, read by replicas between decode iterations.
pub(crate) struct DeployShared {
    /// Replica id asked to drain and exit cleanly (targeted §L11
    /// drain); `usize::MAX` = none. The targeted replica CASes it back
    /// to `usize::MAX` as its ack — ids are never reused, so a stale
    /// target can never hit a later replica.
    drain_target: AtomicUsize,
    /// Replica id that must run the canary probe + gate before
    /// serving; `usize::MAX` = none.
    pub(crate) canary_id: AtomicUsize,
    /// Probe-gate verdict (`GATE_*`), polled by the holding canary.
    pub(crate) gate: AtomicUsize,
    /// Probe output rows published by the canary for the router's
    /// parity check.
    pub(crate) probe_rows: Mutex<Option<Vec<Vec<i32>>>>,
    /// Canary live health, published once per serve-loop iteration:
    /// completions, non-shed failures, p95 latency (f64 bits).
    canary_requests: AtomicUsize,
    canary_failed: AtomicUsize,
    canary_p95_bits: AtomicU64,
}

impl DeployShared {
    pub(crate) fn new() -> DeployShared {
        DeployShared {
            drain_target: AtomicUsize::new(usize::MAX),
            canary_id: AtomicUsize::new(usize::MAX),
            gate: AtomicUsize::new(GATE_HOLD),
            probe_rows: Mutex::new(None),
            canary_requests: AtomicUsize::new(0),
            canary_failed: AtomicUsize::new(0),
            canary_p95_bits: AtomicU64::new(0),
        }
    }

    /// Router: ask replica `id` to drain and exit cleanly.
    pub(crate) fn request_drain(&self, id: usize) {
        self.drain_target.store(id, Ordering::Release);
    }

    /// Replica: claim a drain request addressed to this id (CAS ack).
    pub(crate) fn take_drain(&self, id: usize) -> bool {
        self.drain_target
            .compare_exchange(id, usize::MAX, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    }

    /// Router: arm the probe gate for a canary about to spawn.
    pub(crate) fn begin_probe(&self, canary: usize) {
        *lock(&self.probe_rows) = None;
        self.gate.store(GATE_HOLD, Ordering::Release);
        self.reset_health();
        self.canary_id.store(canary, Ordering::Release);
    }

    /// Router: clear every lever (rollout over or aborted). A canary
    /// still holding at the gate reads `GATE_ABANDON` and exits
    /// cleanly without serving.
    pub(crate) fn clear(&self) {
        self.canary_id.store(usize::MAX, Ordering::Release);
        self.drain_target.store(usize::MAX, Ordering::Release);
        self.gate.store(GATE_ABANDON, Ordering::Release);
    }

    pub(crate) fn reset_health(&self) {
        self.canary_requests.store(0, Ordering::Relaxed);
        self.canary_failed.store(0, Ordering::Relaxed);
        self.canary_p95_bits.store(0, Ordering::Relaxed);
    }

    /// Replica: publish this canary's live counters. Deadline/QoS
    /// sheds are excluded from the failure count — they are
    /// load-driven, not version-driven.
    pub(crate) fn publish_canary_health(&self, stats: &ServerStats) {
        self.canary_requests.store(stats.requests, Ordering::Relaxed);
        self.canary_failed.store(stats.failed.saturating_sub(stats.sheds), Ordering::Relaxed);
        self.canary_p95_bits
            .store(stats.latency.percentile_ms(95.0).to_bits(), Ordering::Relaxed);
    }

    /// Router: (completions, non-shed failures, p95 ms).
    pub(crate) fn health(&self) -> (usize, usize, f64) {
        (
            self.canary_requests.load(Ordering::Relaxed),
            self.canary_failed.load(Ordering::Relaxed),
            f64::from_bits(self.canary_p95_bits.load(Ordering::Relaxed)),
        )
    }
}

/// The pinned probe prompts: deterministic token rows shared by the
/// canary and the baseline engine (and mirrored bit-for-bit by the
/// Python twin). Tokens stay in [2, 91) — clear of PAD/EOS and inside
/// every test vocabulary.
pub(crate) fn probe_prompts(count: usize, enc_len: usize) -> Vec<Vec<i32>> {
    (0..count)
        .map(|k| {
            let len = (enc_len / 2 + k + 1).clamp(1, enc_len.max(1));
            (0..len).map(|i| 2 + ((i * 7 + k * 131) % 89) as i32).collect()
        })
        .collect()
}

/// Decode the pinned probe set on `engine` and return the
/// EOS-truncated rows (the token-parity fingerprint of a version).
pub(crate) fn probe_decode(engine: &mut Engine, probes: usize) -> Result<Vec<Vec<i32>>> {
    let (batch_size, enc_len) = engine.dims();
    let prompts = probe_prompts(probes.min(batch_size), enc_len);
    if prompts.is_empty() {
        return Ok(Vec::new());
    }
    let rows: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let (enc, _trunc) = pack_requests(&rows, batch_size, enc_len);
    let mut out = engine.decode(&enc, enc_len)?;
    out.truncate(prompts.len());
    for row in &mut out {
        truncate_at_eos(row);
    }
    Ok(out)
}

/// Canary side of the probe gate, run by `serve_replica` after the
/// engine builds and before any live traffic: decode the pinned
/// probes, publish the rows, and hold until the router's verdict.
/// Returns `false` when abandoned (the replica exits cleanly having
/// served nothing — a bad version never emits a wrong token to a
/// client).
pub(crate) fn canary_gate(
    engine: &mut Engine,
    opts: &ServerOptions,
    shared: &DeployShared,
) -> Result<bool> {
    let rows = probe_decode(engine, opts.deploy.probes)?;
    *lock(&shared.probe_rows) = Some(rows);
    let deadline = Instant::now() + Duration::from_millis(opts.deploy.hold_ms.max(1));
    loop {
        match shared.gate.load(Ordering::Acquire) {
            GATE_ADMIT => return Ok(true),
            GATE_ABANDON => return Ok(false),
            _ => {
                if Instant::now() >= deadline {
                    // Router never answered (wedged or gone): give up
                    // cleanly; the rollout driver treats the exit as a
                    // failed canary.
                    return Ok(false);
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// Handle-side rollout mailbox: `ServerHandle::deploy` submits specs
/// here and blocks on the condvar; the router's rollout driver drains
/// the queue one rollout at a time and posts terminal statuses.
pub struct DeployControl {
    queue: Mutex<VecDeque<(u64, EngineSpec)>>,
    next_seq: AtomicU64,
    done: Mutex<HashMap<u64, DeployStatus>>,
    progress: Mutex<DeployStatus>,
    cvar: Condvar,
}

impl DeployControl {
    pub(crate) fn new() -> DeployControl {
        DeployControl {
            queue: Mutex::new(VecDeque::new()),
            next_seq: AtomicU64::new(0),
            done: Mutex::new(HashMap::new()),
            progress: Mutex::new(DeployStatus::Idle),
            cvar: Condvar::new(),
        }
    }

    /// Enqueue a rollout; returns the ticket to `wait` on.
    pub(crate) fn submit(&self, spec: EngineSpec) -> u64 {
        let seq = self.next_seq.fetch_add(1, Ordering::SeqCst) + 1;
        lock(&self.queue).push_back((seq, spec));
        seq
    }

    /// Block until rollout `seq` reaches a terminal status. Returns
    /// `Aborted` if the router dies before running it.
    pub(crate) fn wait(
        &self,
        seq: u64,
        router_up: &std::sync::atomic::AtomicBool,
    ) -> DeployStatus {
        let mut guard = lock(&self.done);
        loop {
            if let Some(status) = guard.remove(&seq) {
                return status;
            }
            if !router_up.load(Ordering::Acquire) {
                return DeployStatus::Aborted {
                    version: 0,
                    reason: "server shut down before the rollout completed".into(),
                };
            }
            guard = self
                .cvar
                .wait_timeout(guard, Duration::from_millis(50))
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .0;
        }
    }

    /// Live status snapshot (most recent rollout, `Idle` before any).
    pub(crate) fn status(&self) -> DeployStatus {
        lock(&self.progress).clone()
    }

    fn take_next(&self) -> Option<(u64, EngineSpec)> {
        lock(&self.queue).pop_front()
    }

    fn set_progress(&self, status: DeployStatus) {
        *lock(&self.progress) = status;
    }

    fn finish(&self, seq: u64, status: DeployStatus) {
        self.set_progress(status.clone());
        lock(&self.done).insert(seq, status);
        self.cvar.notify_all();
    }
}

/// Validate the new version and compute the old-version probe
/// baseline, off the router thread (artifact loads are slow). The
/// validation half is what turns a corrupt artifact into a typed
/// `DeployStatus::Failed` instead of a first-execute replica panic:
/// `engine_dims` runs the full `Artifact::load`, including the §L11
/// per-HLO checksum verification.
fn prepare_rollout(
    old_spec: &EngineSpec,
    new_spec: &EngineSpec,
    opts: &ServerOptions,
    dims: (usize, usize),
    probes: usize,
) -> Result<Vec<Vec<i32>>> {
    let new_dims =
        engine_dims(new_spec).context("new version failed validation at load time")?;
    if new_dims != dims {
        bail!(
            "new version geometry (batch {}, enc_len {}) does not match the serving \
             geometry (batch {}, enc_len {})",
            new_dims.0,
            new_dims.1,
            dims.0,
            dims.1
        );
    }
    // Baseline = the old version with injected faults stripped: the
    // probe fingerprint must reflect the model, not the chaos
    // schedule.
    let mut base_spec = old_spec.clone();
    if let EngineSpec::Sim(s) = &mut base_spec {
        s.fault = FaultSpec::default();
    }
    // tp=1: the probe baseline is a whole-model engine — sharding
    // changes timing, never tokens, so a single replica is the
    // canonical parity reference for any fleet shape.
    let mut engine = Engine::build(PROBE_REPLICA_ID, &base_spec, opts, 1)
        .context("old-version baseline engine failed to build")?;
    probe_decode(&mut engine, probes).context("old-version probe baseline failed")
}

/// Rollout phases; one rollout swaps replicas strictly one at a time.
enum Phase {
    /// Side thread validating the new version + computing the probe
    /// baseline.
    Preparing { rx: mpsc::Receiver<Result<Vec<Vec<i32>>>> },
    /// Waiting for the targeted replica's clean (§L7 drain) exit.
    Draining { target: usize },
    /// Canary spawned; waiting for its published probe rows.
    Probing { canary: usize },
    /// Canary admitted; watching its live health over the window.
    Probation { canary: usize, since: Instant },
    /// Failed canary draining; its exit respawns the old version.
    RollingBack { canary: usize, reason: String },
}

/// One in-flight rollout (router-side bookkeeping).
struct Rollout {
    seq: u64,
    version: u32,
    /// Decided version when the rollout started — the rollback target.
    old: u32,
    swapped: usize,
    fleet: usize,
    /// Whether `Supervisor::decided` already flipped to `version`
    /// (after the first canary passes).
    promoted: bool,
    phase: Phase,
    /// §L12: TP shape of the slot currently being swapped, captured
    /// when the drain target exits (before the supervisor forgets it).
    /// The canary comes up with the same footprint, and a rollback
    /// respawn restores it.
    unit_tp: usize,
    baseline: Option<Vec<Vec<i32>>>,
    /// EWMA of the fleet's old-version p95 (the latency-gate
    /// reference), fed from the router's merged stats each tick.
    fleet_p95_ewma: f64,
}

/// The router-side rollout driver: ticked from the supervision pass,
/// intercepts replica exits that belong to the rollout, and owns the
/// `DeployControl` mailbox.
pub(crate) struct RolloutDriver {
    ctl: Arc<DeployControl>,
    /// Serving geometry the router dispatches at; a new version must
    /// match it exactly.
    dims: (usize, usize),
    active: Option<Rollout>,
}

impl RolloutDriver {
    pub(crate) fn new(ctl: Arc<DeployControl>, dims: (usize, usize)) -> RolloutDriver {
        RolloutDriver { ctl, dims, active: None }
    }

    /// Advance the rollout one step (start a queued one, poll the prep
    /// thread, check probe parity, evaluate probation gates). Called
    /// once per router supervision pass while the server is serving.
    pub(crate) fn tick(&mut self, sup: &mut Supervisor, stats: &mut ServerStats) {
        if self.active.is_none() {
            let Some((seq, spec)) = self.ctl.take_next() else { return };
            self.start(seq, spec, sup);
            return;
        }
        let r = self.active.as_mut().expect("active rollout");
        match &r.phase {
            Phase::Preparing { rx } => match rx.try_recv() {
                Ok(Ok(rows)) => {
                    r.baseline = Some(rows);
                    self.advance_or_complete(sup, stats);
                }
                Ok(Err(e)) => {
                    let (version, seq) = (r.version, r.seq);
                    sup.specs.remove(&version);
                    stats.deploy.canary_fail += 1;
                    self.finish(
                        seq,
                        DeployStatus::Failed { version, reason: format!("{e:#}") },
                    );
                }
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => {
                    let (version, seq) = (r.version, r.seq);
                    sup.specs.remove(&version);
                    self.finish(
                        seq,
                        DeployStatus::Failed {
                            version,
                            reason: "rollout preparation thread died".into(),
                        },
                    );
                }
            },
            Phase::Draining { .. } | Phase::RollingBack { .. } => {
                // Waiting on an exit event (`observe_exit`).
            }
            Phase::Probing { canary } => {
                let canary = *canary;
                let published = lock(&sup.shared.deploy.probe_rows).take();
                if let Some(rows) = published {
                    let want = r.baseline.as_deref().unwrap_or(&[]);
                    if rows == want {
                        sup.shared.deploy.reset_health();
                        sup.shared.deploy.gate.store(GATE_ADMIT, Ordering::Release);
                        r.phase = Phase::Probation { canary, since: Instant::now() };
                    } else {
                        // Abandon at the gate: the canary exits
                        // cleanly having served nothing; its exit
                        // event completes the rollback.
                        stats.deploy.canary_fail += 1;
                        sup.shared.deploy.canary_id.store(usize::MAX, Ordering::Release);
                        sup.shared.deploy.gate.store(GATE_ABANDON, Ordering::Release);
                        r.phase = Phase::RollingBack {
                            canary,
                            reason: "canary failed the token-parity probe".into(),
                        };
                    }
                }
            }
            Phase::Probation { canary, since } => {
                let (canary, since) = (*canary, *since);
                // Feed the fleet p95 EWMA from the router's merged
                // stats — at this point those are old-version
                // completions only (swapped replicas haven't exited).
                let fleet_p95 = stats.latency.percentile_ms(95.0);
                if fleet_p95 > 0.0 {
                    r.fleet_p95_ewma = if r.fleet_p95_ewma > 0.0 {
                        0.8 * r.fleet_p95_ewma + 0.2 * fleet_p95
                    } else {
                        fleet_p95
                    };
                }
                let (served, failed, p95) = sup.shared.deploy.health();
                let done = served + failed;
                let window_done = done >= sup.opts.deploy.probation
                    || since.elapsed() >= Duration::from_millis(sup.opts.deploy.probation_ms);
                if !window_done {
                    return;
                }
                let err_rate = if done == 0 { 0.0 } else { failed as f64 / done as f64 };
                let lat_bad = r.fleet_p95_ewma > 0.0
                    && served >= 2
                    && p95 > sup.opts.deploy.lat_factor * r.fleet_p95_ewma;
                if err_rate > sup.opts.deploy.max_err || lat_bad {
                    let reason = if lat_bad {
                        format!(
                            "canary p95 {p95:.1} ms blew the {:.1}x fleet-EWMA gate ({:.1} ms)",
                            sup.opts.deploy.lat_factor, r.fleet_p95_ewma
                        )
                    } else {
                        format!(
                            "canary error rate {err_rate:.2} over {done} requests exceeds {:.2}",
                            sup.opts.deploy.max_err
                        )
                    };
                    stats.deploy.canary_fail += 1;
                    sup.shared.deploy.canary_id.store(usize::MAX, Ordering::Release);
                    // The canary is serving: drain it like any swap
                    // target; its clean exit respawns the old version.
                    sup.shared.deploy.request_drain(canary);
                    r.phase = Phase::RollingBack { canary, reason };
                } else {
                    // Promotion: first pass flips the decided version,
                    // so respawns/autoscale land on the new version
                    // from here on.
                    stats.deploy.canary_pass += 1;
                    r.swapped += 1;
                    if !r.promoted {
                        r.promoted = true;
                        sup.decided = r.version;
                        stats.deploy.current = r.version;
                    }
                    sup.shared.deploy.canary_id.store(usize::MAX, Ordering::Release);
                    self.advance_or_complete(sup, stats);
                }
            }
        }
    }

    /// Intercept a replica exit that belongs to the rollout. Returns
    /// whether generic §L7 respawning may handle this exit (`false`
    /// when the rollout already spawned the replacement — no restart
    /// budget is spent on deploy lifecycle exits).
    pub(crate) fn observe_exit(
        &mut self,
        id: usize,
        crashed: bool,
        sup: &mut Supervisor,
        stats: &mut ServerStats,
    ) -> bool {
        let Some(r) = self.active.as_mut() else { return true };
        match &r.phase {
            Phase::Draining { target } if *target == id => {
                // Old replica gone (drained clean, or crashed mid-
                // drain — §L7 requeues its work either way): spawn the
                // canary on the new version. `canary_id` is armed
                // before the spawn so the canary cannot race past its
                // own gate check.
                sup.shared.deploy.drain_target.store(usize::MAX, Ordering::Release);
                sup.shared.deploy.begin_probe(sup.next_id);
                // §L12: the canary inherits the drained unit's TP
                // shape — `observe_exit` runs before `Supervisor::
                // on_exit`, so the shape map still has the target.
                r.unit_tp = sup.shape_of(id);
                let (version, unit_tp) = (r.version, r.unit_tp);
                let canary = sup.spawn_shaped(version, unit_tp);
                r.phase = Phase::Probing { canary };
                false
            }
            Phase::Probing { canary } | Phase::Probation { canary, .. } if *canary == id => {
                // Canary died before a verdict (crash, hold timeout,
                // or a raced §L10 scale-down): automatic rollback.
                stats.deploy.canary_fail += 1;
                let reason = if crashed {
                    "canary crashed before completing probation".to_string()
                } else {
                    "canary exited before completing probation".to_string()
                };
                self.rollback(sup, stats, reason);
                false
            }
            Phase::RollingBack { canary, reason } if *canary == id => {
                let reason = reason.clone();
                self.rollback(sup, stats, reason);
                false
            }
            _ => true,
        }
    }

    /// Complete the rollback: respawn the exited canary's slot on the
    /// old version, un-promote the decided version, and freeze the
    /// rollout with `RolledBack`.
    fn rollback(&mut self, sup: &mut Supervisor, stats: &mut ServerStats, reason: String) {
        let r = self.active.take().expect("active rollout");
        sup.shared.deploy.clear();
        if r.promoted {
            sup.decided = r.old;
            stats.deploy.current = r.old;
        }
        // §L12: the rollback replacement restores the swapped slot's
        // original footprint (captured when its drain target exited).
        sup.spawn_shaped(r.old, r.unit_tp.max(1));
        stats.deploy.rollbacks += 1;
        self.finish(
            r.seq,
            DeployStatus::RolledBack { version: r.version, swapped: r.swapped, reason },
        );
    }

    /// Abort the in-flight rollout (shutdown or fleet loss) and fail
    /// every queued one. A canary holding at the gate is abandoned (it
    /// exits cleanly); a mid-drain target just finishes the normal §L7
    /// drain with the rest of the fleet.
    pub(crate) fn abort_all(
        &mut self,
        sup: &mut Supervisor,
        stats: &mut ServerStats,
        reason: &str,
    ) {
        if let Some(r) = self.active.take() {
            sup.shared.deploy.clear();
            stats.deploy.aborted += 1;
            self.finish(
                r.seq,
                DeployStatus::Aborted { version: r.version, reason: reason.into() },
            );
        }
        while let Some((seq, _)) = self.ctl.take_next() {
            self.finish(seq, DeployStatus::Aborted { version: 0, reason: reason.into() });
        }
    }

    /// Whether a rollout is currently in flight.
    pub(crate) fn active(&self) -> bool {
        self.active.is_some()
    }

    fn start(&mut self, seq: u64, spec: EngineSpec, sup: &mut Supervisor) {
        let version = sup.specs.keys().max().copied().unwrap_or(0) + 1;
        let old = sup.decided;
        let old_spec = sup.specs.get(&old).expect("decided version spec").clone();
        sup.specs.insert(version, spec.clone());
        let fleet = sup.versions.values().filter(|&&v| v != version).count();
        let probes = sup.opts.deploy.probes;
        let opts = sup.opts.clone();
        let dims = self.dims;
        let (tx, rx) = mpsc::channel();
        // Detached prep thread: artifact validation + baseline probes
        // must not stall the router's supervision loop.
        let _ = std::thread::Builder::new().name("altup-deploy-prep".into()).spawn(
            move || {
                let _ = tx.send(prepare_rollout(&old_spec, &spec, &opts, dims, probes));
            },
        );
        self.ctl.set_progress(DeployStatus::InProgress { version, swapped: 0, fleet });
        self.active = Some(Rollout {
            seq,
            version,
            old,
            swapped: 0,
            fleet,
            promoted: false,
            phase: Phase::Preparing { rx },
            unit_tp: 1,
            baseline: None,
            fleet_p95_ewma: 0.0,
        });
    }

    /// Target the next not-yet-swapped replica, or complete the
    /// rollout when every live replica is on the new version.
    fn advance_or_complete(&mut self, sup: &mut Supervisor, stats: &mut ServerStats) {
        let r = self.active.as_mut().expect("active rollout");
        self.ctl.set_progress(DeployStatus::InProgress {
            version: r.version,
            swapped: r.swapped,
            fleet: r.fleet,
        });
        match sup.next_swap_target(r.version) {
            Some(target) => {
                sup.shared.deploy.request_drain(target);
                r.phase = Phase::Draining { target };
            }
            None => {
                let r = self.active.take().expect("active rollout");
                sup.shared.deploy.clear();
                stats.deploy.completed += 1;
                self.finish(
                    r.seq,
                    DeployStatus::Completed { version: r.version, swapped: r.swapped },
                );
            }
        }
    }

    fn finish(&mut self, seq: u64, status: DeployStatus) {
        self.ctl.finish(seq, status);
        self.active = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_prompts_are_pinned_and_in_vocab() {
        let a = probe_prompts(3, 32);
        let b = probe_prompts(3, 32);
        assert_eq!(a, b, "probe prompts are deterministic");
        assert_eq!(a.len(), 3);
        for (k, row) in a.iter().enumerate() {
            assert_eq!(row.len(), 32 / 2 + k + 1);
            assert!(row.iter().all(|&t| (2..91).contains(&t)), "clear of PAD/EOS, small vocab");
        }
        // Distinct prompts: the parity gate must exercise more than
        // one decode stream.
        assert_ne!(a[0], a[1]);
        // Degenerate geometry never panics or emits empty rows.
        for row in probe_prompts(2, 1) {
            assert_eq!(row.len(), 1);
        }
        assert!(probe_prompts(0, 32).is_empty());
    }

    #[test]
    fn deploy_status_terminal_and_display() {
        assert!(!DeployStatus::Idle.terminal());
        assert!(!DeployStatus::InProgress { version: 1, swapped: 0, fleet: 2 }.terminal());
        assert!(DeployStatus::Completed { version: 1, swapped: 2 }.terminal());
        assert!(DeployStatus::RolledBack {
            version: 1,
            swapped: 0,
            reason: "probe".into()
        }
        .terminal());
        assert!(DeployStatus::Failed { version: 1, reason: "load".into() }.terminal());
        assert!(DeployStatus::Aborted { version: 1, reason: "shutdown".into() }.terminal());
        let s = DeployStatus::RolledBack {
            version: 3,
            swapped: 1,
            reason: "canary failed the token-parity probe".into(),
        }
        .to_string();
        assert!(s.contains("rolled back v3"), "{s}");
    }

    #[test]
    fn deploy_control_submit_wait_finish() {
        let ctl = DeployControl::new();
        let seq = ctl.submit(EngineSpec::Sim(crate::coordinator::server::SimSpec::new(2, 8, 4)));
        assert_eq!(seq, 1);
        assert_eq!(ctl.status(), DeployStatus::Idle);
        let (got_seq, _) = ctl.take_next().expect("queued");
        assert_eq!(got_seq, seq);
        ctl.finish(seq, DeployStatus::Completed { version: 1, swapped: 2 });
        let up = std::sync::atomic::AtomicBool::new(true);
        assert_eq!(ctl.wait(seq, &up), DeployStatus::Completed { version: 1, swapped: 2 });
        // A waiter for a seq the router never ran returns Aborted once
        // the router is down instead of blocking forever.
        let down = std::sync::atomic::AtomicBool::new(false);
        assert!(matches!(ctl.wait(99, &down), DeployStatus::Aborted { .. }));
    }

    #[test]
    fn deploy_options_defaults() {
        let d = DeployOptions::default();
        assert_eq!(d.probation, 16);
        assert_eq!(d.probation_ms, 1500);
        assert_eq!(d.probes, 2);
        assert!((d.max_err - 0.1).abs() < 1e-12);
        assert!((d.lat_factor - 4.0).abs() < 1e-12);
        assert_eq!(d.hold_ms, 5000);
    }
}
