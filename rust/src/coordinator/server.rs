//! Multi-replica inference server: shape-bucketed batching (§Perf L5),
//! slot-based **continuous batching** (§Perf L6), and a **supervised,
//! fault-tolerant serving lifecycle** (§L7).
//!
//! The PJRT session is !Send (Rc-backed FFI handles), so each replica
//! owns its client + session on a dedicated model thread. A router
//! thread admits requests continuously, groups them by sequence-length
//! bucket (`runtime::session::bucket_for`), and emits full-or-expired
//! batches onto a shared job queue; the first replica with capacity
//! picks each job up.
//!
//! Replicas run one of two decode disciplines:
//!
//! - **Continuous (default, §Perf L6):** the replica owns `S` decode
//!   slots, each holding a request's device-resident KV-cache buffers
//!   (`Session::init_decode_slots`). Between decode iterations the slot
//!   scheduler admits pending requests into free slots (one
//!   `prefill@<bucket>` per same-bucket admission group), runs one
//!   fused `decode_token` over every live slot, and retires slots the
//!   moment they emit EOS or hit `dec_len`.
//! - **Batch-level (fallback / `ALTUP_NO_CONT_BATCH=1`):** the §Perf
//!   L5 run-to-completion loop over the monolithic `decode_step`.
//!
//! §L8 — on the continuous path, **speculative decoding**
//! (`ALTUP_SPEC_GAMMA` / `--spec-gamma`, via `coordinator::spec`)
//! replaces each fused `decode_token` iteration with a draft/verify
//! round: a cheap draft session proposes γ tokens per live slot, one
//! fused full-model `verify@γ` accepts the longest greedy-identical
//! prefix and supplies a correction token, and each slot's stream
//! advances by 1..=γ+1 tokens per full-model step — token-for-token
//! identical to plain decode (parity pinned by `tests/server.rs`).
//! Artifacts opt in by shipping a `draft` entry in meta.json; the sim
//! engine models the draft with `SimDraftSpec` (per-step cost + a
//! hash-sampled per-position acceptance coin) so the subsystem tests
//! and benches without a PJRT backend. Replicas fall back to plain
//! decode when no draft is available.
//!
//! §Perf L9 — replicas with a **paged decode contract** serve KV state
//! out of a fixed page pool instead of per-slot monoliths: every slot
//! maps its KV through a page table into refcounted fixed-size pages
//! (`runtime::pages`), admission is pool-aware (a request is admitted
//! only when its pages fit — an impossible request is shed with
//! `FailReason::PoolExhausted`, a transient shortage stalls admission
//! until live slots retire), and a content-addressed **prefix cache**
//! pins page-aligned prompt chunks so shared prefixes map one physical
//! copy and skip their covered prefill work (LRU-evicted under pool
//! pressure, never while any slot still maps the page). Artifacts opt
//! in by shipping the `paged` meta entry plus the
//! `prefill_paged`/`decode_token_paged` HLOs; the sim engine models
//! the pool with [`SimPoolSpec`] (`ALTUP_POOL_PAGES` /
//! `ALTUP_PAGE_SIZE` / `ALTUP_PREFIX_CACHE`). Replicas without the
//! contract keep serving monolithic `DecodeSlots`, token-for-token
//! identical.
//!
//! §L7 — the serving lifecycle is supervised (cf. Pope et al. 2022,
//! where replica failure and load shedding are scheduler states, not
//! fatal errors):
//!
//! - Every replica runs inside a panic boundary (`catch_unwind`). Each
//!   request a replica accepts lives in a per-replica in-flight
//!   [`Ledger`] until its terminal [`Response`] is sent; when a replica
//!   crashes, the supervisor (the router thread) requeues whatever the
//!   ledger still held to surviving replicas — bounded by
//!   `ServerOptions::max_retries` per request, after which the client
//!   receives an explicit `Response::failed` instead of a dropped
//!   channel — and respawns a replacement replica from the shared
//!   `EngineSpec` up to `ServerOptions::replica_restarts`.
//! - Requests carry an optional deadline (`ServerOptions::
//!   request_timeout_ms` / `ALTUP_REQUEST_TIMEOUT_MS`). The router
//!   sheds expired requests before dispatch and the continuous decode
//!   loop retires expired slots between iterations, so one stuck
//!   generation cannot hold a slot forever.
//! - `shutdown()` is a drain, not an abort: admissions stop, partial
//!   groups flush, replicas retire their in-flight slots naturally,
//!   and only then are threads joined. Every admitted request gets a
//!   terminal response — tokens, or an explicit failure.
//!
//! Backends: `EngineSpec::Artifact` serves a compiled artifact through
//! a warmed device cache (§Perf L4); `EngineSpec::Sim` is a
//! deterministic backend-free decode with a per-token cost model,
//! hash-sampled EOS lengths, and an injectable [`FaultSpec`]
//! (deterministic replica kills, hash-sampled panics, stuck
//! generations), so supervision, retry, shedding, and drain are all
//! testable and benchable without a PJRT backend.

use crate::coordinator::admission::{self, AdmissionController, QosAction, TenantSpec};
use crate::coordinator::deploy::{self, DeployControl, DeployOptions, DeployShared, RolloutDriver};
use crate::coordinator::metrics::{
    DeployMeter, LatencyHistogram, OccupancyMeter, PoolMeter, SpecMeter, TenantMeter,
};
use crate::coordinator::spec::{self, SpecDecoder};
use crate::data::tokenizer::EOS;
use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use crate::runtime::pages::{chunk_hashes, pages_for, PagePool, PageTable, PrefixCache};
use crate::runtime::session::{bucket_for, DecodeSlots, Session};
use crate::util::env;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// `Response::replica` value for router-side failures (deadline sheds,
/// drain aborts, dead-server rejections) that never reached a model
/// replica.
pub const ROUTER_ID: usize = usize::MAX;

/// How long the router parks at most between supervision passes, so
/// replica crash events are noticed promptly even while admission is
/// idle or mid-batch-window.
const SUPERVISE_TICK: Duration = Duration::from_millis(25);

/// §L10 scale-down sentinel: a `BatchJob` with this bucket and no
/// requests asks whichever replica pops it to finish its in-flight
/// work and exit cleanly (an autoscale retirement, not a crash — no
/// respawn, no restart-budget spend).
const SCALE_DOWN_BUCKET: usize = usize::MAX;

fn scale_down_job() -> BatchJob {
    BatchJob { bucket: SCALE_DOWN_BUCKET, requests: Vec::new() }
}

fn is_scale_down(job: &BatchJob) -> bool {
    job.bucket == SCALE_DOWN_BUCKET && job.requests.is_empty()
}

/// §L10 cross-thread degradation levers, written by the router's
/// overload controller and read by replicas between decode iterations.
pub(crate) struct QosShared {
    /// Ceiling on the speculative draft length γ; `usize::MAX` = no
    /// cap (the overload controller halves γ under sustained pressure
    /// and restores the cap when calm).
    gamma_cap: AtomicUsize,
    /// §L11 rollout levers (targeted drain, canary probe gate, canary
    /// health), written by the router's rollout driver.
    pub(crate) deploy: DeployShared,
}

impl QosShared {
    fn new() -> QosShared {
        QosShared { gamma_cap: AtomicUsize::new(usize::MAX), deploy: DeployShared::new() }
    }
}

pub struct Request {
    pub enc_tokens: Vec<i32>,
    pub reply: mpsc::Sender<Response>,
    /// When the request was created (client side), so reported latency
    /// includes time spent blocked in the bounded request channel and
    /// queued at the router — not just time after admission.
    /// `Request::new` stamps it; construct requests through it.
    pub t0: Instant,
    /// Optional absolute deadline. Left `None` by `Request::new`, the
    /// router stamps `t0 + ServerOptions::request_timeout_ms` at
    /// admission; a request past its deadline is shed with an explicit
    /// `FailReason::DeadlineExceeded` response instead of occupying a
    /// batch row or decode slot.
    pub deadline: Option<Instant>,
    /// §L10: index into `ServerOptions::tenants` for QoS accounting
    /// (rate limit, priority queue, SLO). Out-of-range indices clamp to
    /// the last configured tenant; 0 with no tenants configured.
    pub tenant: usize,
    /// §L10: scheduling class, clamped to the tenant's configured
    /// priority at admission (a request can deprioritize itself, never
    /// escalate past its tenant's class). Higher drains first.
    pub priority: u8,
}

impl Request {
    pub fn new(enc_tokens: Vec<i32>, reply: mpsc::Sender<Response>) -> Request {
        Request { enc_tokens, reply, t0: Instant::now(), deadline: None, tenant: 0, priority: 1 }
    }

    /// A request with an explicit client-chosen deadline (overrides the
    /// server-wide `request_timeout_ms` default).
    pub fn with_deadline(
        enc_tokens: Vec<i32>,
        reply: mpsc::Sender<Response>,
        deadline: Instant,
    ) -> Request {
        Request { deadline: Some(deadline), ..Request::new(enc_tokens, reply) }
    }

    /// §L10: a request attributed to a tenant/priority for QoS
    /// admission (token bucket, weighted queue, SLO stamp).
    pub fn for_tenant(
        enc_tokens: Vec<i32>,
        reply: mpsc::Sender<Response>,
        tenant: usize,
        priority: u8,
    ) -> Request {
        Request { tenant, priority, ..Request::new(enc_tokens, reply) }
    }

    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Why a request received an explicit terminal failure instead of
/// decoded tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailReason {
    /// The request sat past its deadline and was shed before or during
    /// decode.
    DeadlineExceeded,
    /// Every permitted retry landed on a dying replica.
    RetriesExhausted,
    /// The server has no live replicas (startup failure or restart
    /// budget exhausted).
    NoReplicas,
    /// A replica failed during drain, after the job queue closed, so
    /// there was no requeue path left.
    AbortedOnDrain,
    /// §L9: the request's KV footprint (prompt bucket + decode room)
    /// exceeds the replica page pool's total capacity — it could never
    /// be admitted, even with every page free.
    PoolExhausted,
    /// §L10: shed at admission by the QoS layer — the tenant is over
    /// its token-bucket rate, the admission queue is at capacity (or a
    /// higher class preempted this request's slot), or the overload
    /// controller is shedding the lowest class early.
    QueueFull,
    /// §L10: shed at admission because the estimated queue wait alone
    /// already overshoots the request's deadline/SLO — rejected before
    /// spending a queue slot or prefill on doomed work.
    WouldMissDeadline,
}

impl std::fmt::Display for FailReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FailReason::DeadlineExceeded => "deadline exceeded before completion",
            FailReason::RetriesExhausted => "retry budget exhausted after replica failures",
            FailReason::NoReplicas => "no live replicas (startup failure or restart budget exhausted)",
            FailReason::AbortedOnDrain => "replica failed during drain with no requeue path left",
            FailReason::PoolExhausted => {
                "request needs more KV pages than the replica pool holds"
            }
            FailReason::QueueFull => "admission queue full or tenant over its rate limit",
            FailReason::WouldMissDeadline => {
                "estimated queue wait already overshoots the deadline"
            }
        })
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    /// Decoded tokens, truncated at the first EOS (inclusive) — under
    /// continuous batching the decode actually stopped there (early
    /// exit); under batch-level decode the full row ran and the tail
    /// past EOS is dropped for parity. Empty on explicit failures.
    pub tokens: Vec<i32>,
    /// Time from `Request::new` (includes channel/router queueing).
    pub latency: Duration,
    pub batch_fill: usize,
    /// True when the request's prompt exceeded the model's `enc_len`
    /// and was cut to fit (previously a silent truncation).
    pub truncated: bool,
    /// Sequence-length bucket the request actually executed at.
    pub bucket: usize,
    /// Which model replica served the request (`ROUTER_ID` for
    /// router-side failures that never reached a replica).
    pub replica: usize,
    /// `Some(reason)` marks an explicit terminal failure (deadline
    /// shed, retry-budget exhaustion, drain abort, dead server). §L7:
    /// every admitted request gets a terminal response — this, or
    /// tokens — never a silently dropped reply channel.
    pub failure: Option<FailReason>,
}

impl Response {
    /// An explicit terminal failure (no tokens).
    pub fn failed(reason: FailReason, t0: Instant, replica: usize) -> Response {
        Response {
            tokens: Vec::new(),
            latency: t0.elapsed(),
            batch_fill: 0,
            truncated: false,
            bucket: 0,
            replica,
            failure: Some(reason),
        }
    }

    pub fn is_failure(&self) -> bool {
        self.failure.is_some()
    }
}

#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub batch_window: Duration,
    pub seed: u64,
    /// Optional checkpoint to load weights from.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Number of model threads behind the shared router queue.
    /// `ALTUP_SERVER_REPLICAS` sets the default (else 1); 0 means 1.
    pub replicas: usize,
    /// Shape-bucketed batching (default on; `ALTUP_NO_BUCKETS=1` pads
    /// every batch to the full `enc_len` — the A/B baseline).
    pub bucketed: bool,
    /// Decode slots per replica for continuous batching; 0 = auto (the
    /// engine's `batch_size`). `ALTUP_SERVER_SLOTS` sets the default.
    pub slots: usize,
    /// Iteration-level (continuous) scheduling (default on;
    /// `ALTUP_NO_CONT_BATCH=1` forces run-to-completion batches — the
    /// A/B baseline). Replicas also fall back per-engine when the
    /// artifact ships no split HLO.
    pub continuous: bool,
    /// Capacity of the bounded request channel (admission
    /// backpressure); 0 means 1. Senders block once it fills; that
    /// blocked time still counts toward reported latency because the
    /// clock starts at `Request::new`.
    pub queue_cap: usize,
    /// Per-request deadline in ms from `Request::new`; requests past it
    /// are shed with an explicit failure instead of occupying a batch
    /// row or decode slot. `ALTUP_REQUEST_TIMEOUT_MS` sets the default
    /// (unset or 0 = no deadline).
    pub request_timeout_ms: Option<u64>,
    /// How many times a request may be requeued to another replica
    /// after a crash before it fails explicitly with
    /// `FailReason::RetriesExhausted`.
    pub max_retries: u32,
    /// How many replacement replicas the supervisor may spawn over the
    /// server's lifetime after crashes. `ALTUP_REPLICA_RESTARTS` sets
    /// the default (else 2).
    pub replica_restarts: usize,
    /// Speculative-decoding draft length γ (§L8): each continuous
    /// decode iteration drafts γ tokens per live slot and verifies
    /// them in one fused full-model step. 0 (the default) disables
    /// speculation; `ALTUP_SPEC_GAMMA` sets the default. An artifact
    /// without `verify@<γ>` for this exact γ serves at its compiled
    /// `DraftSpec::gamma` instead (`Engine::effective_spec_gamma`);
    /// with no draft model or no runnable verify at all, replicas fall
    /// back to plain decode.
    pub spec_gamma: usize,
    /// §L10 multi-tenant QoS contracts (token-bucket rates, weighted
    /// priority classes, SLOs). Empty (the default) disables the QoS
    /// layer entirely — admission is a passthrough and serving behaves
    /// exactly as pre-L10. `ALTUP_TENANT_SPEC` sets the default
    /// (`name:priority:weight:rate:burst:slo_ms`, `;`-separated).
    pub tenants: Vec<TenantSpec>,
    /// §L10: how many *extra* replicas the overload controller may
    /// spawn beyond `replicas` under sustained queue pressure (retired
    /// again when calm). 0 disables autoscaling; `ALTUP_AUTOSCALE`
    /// sets the default.
    pub autoscale: usize,
    /// Base delay in ms for the supervisor's exponential respawn
    /// backoff after a replica crash (doubles per consecutive crash,
    /// ±25% deterministic jitter). `ALTUP_RESTART_BACKOFF_MS` sets the
    /// default (else 25); 0 is clamped to 1.
    pub restart_backoff_ms: u64,
    /// §L11 rolling-swap knobs (probation window, probe count, canary
    /// health gates). `ALTUP_DEPLOY_*` set the defaults.
    pub deploy: DeployOptions,
}

impl Default for ServerOptions {
    // All knob defaults resolve through `util::env` (§L8 satellite:
    // one typed parse-with-default helper instead of a hand-rolled
    // chain per knob).
    fn default() -> Self {
        ServerOptions {
            batch_window: Duration::from_millis(5),
            seed: 0,
            checkpoint: None,
            replicas: env::usize_at_least("ALTUP_SERVER_REPLICAS", 1, 1),
            bucketed: !env::flag("ALTUP_NO_BUCKETS"),
            slots: env::usize_or("ALTUP_SERVER_SLOTS", 0),
            continuous: !env::flag("ALTUP_NO_CONT_BATCH"),
            queue_cap: 1024,
            request_timeout_ms: env::opt_u64_nonzero("ALTUP_REQUEST_TIMEOUT_MS"),
            max_retries: 2,
            replica_restarts: env::usize_or("ALTUP_REPLICA_RESTARTS", 2),
            spec_gamma: spec::gamma_from_env(),
            tenants: admission::tenants_from_env(),
            autoscale: env::usize_or("ALTUP_AUTOSCALE", 0),
            restart_backoff_ms: env::u64_or("ALTUP_RESTART_BACKOFF_MS", 25),
            deploy: DeployOptions::default(),
        }
    }
}

/// Which decode backend the replicas run.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// A compiled artifact by suite name (requires a real PJRT backend).
    Artifact { name: String },
    /// Deterministic backend-free decode with a token-proportional cost
    /// model — for scheduler tests/benches on machines without the
    /// xla-rs bindings.
    Sim(SimSpec),
}

/// Injectable faults for the sim engine (§L7). Everything is
/// deterministic — keyed by replica id, engine-call index, or prompt
/// hash — so supervision, retry, shedding, and drain behavior can be
/// pinned by tests and A/B-benched without a real backend.
#[derive(Debug, Clone, Default)]
pub struct FaultSpec {
    /// Kill this replica id: its serving thread panics on engine call
    /// number `kill_after_calls`. Respawned replacements get fresh ids
    /// and therefore serve cleanly.
    pub kill_replica: Option<usize>,
    /// Which engine call (prefill / decode_token / monolithic decode,
    /// 1-based) triggers `kill_replica`; 0 behaves like 1.
    pub kill_after_calls: u64,
    /// §L10: additional deterministic kills beyond the single
    /// `kill_replica` — `(replica id, engine call)` pairs, so a chaos
    /// schedule can take down several replicas at different points of
    /// a trace replay. `ChaosSpec::apply` fills this.
    pub extra_kills: Vec<(usize, u64)>,
    /// Probability that any engine call panics, hash-sampled from
    /// (replica id, call index). 0.0 = never.
    pub panic_rate: f64,
    /// Stuck-generation injection: prompts whose hash falls in the
    /// 1-in-`stuck_every` class never emit EOS (decode runs the full
    /// `dec_len`) — the workload deadlines exist to shed. 0 = off.
    pub stuck_every: u64,
    /// Extra simulated ns per decode step per live stuck row (a stuck
    /// generation is also a slow one).
    pub stuck_step_ns: u64,
}

impl FaultSpec {
    fn stuck(&self, row_hash: u64) -> bool {
        self.stuck_every > 0 && row_hash % self.stuck_every == 0
    }
}

/// §L10: a composable chaos schedule for trace-driven load tests. A
/// `ChaosSpec` bundles the failure modes the sim engine already knows
/// how to inject — deterministic replica kills, stuck generations,
/// page-pool pressure — into one schedule that `apply` composes onto a
/// `SimSpec`, so the bench/CI chaos harness describes "kill replica 1
/// mid-burst while 25% of the pool is withheld" as data, not as
/// hand-edited spec fields.
#[derive(Debug, Clone, Default)]
pub struct ChaosSpec {
    /// Replica kills as `(replica id, engine call ordinal)` — each
    /// listed replica panics on its Nth engine call.
    pub kills: Vec<(usize, u64)>,
    /// Stuck-generation class (`FaultSpec::stuck_every` semantics);
    /// 0 leaves the spec's existing setting alone.
    pub stuck_every: u64,
    /// Extra ns per decode step per stuck row.
    pub stuck_step_ns: u64,
    /// Withhold this fraction of the page pool (simulated external
    /// memory pressure); pool capacity never drops below one slot's
    /// worth of pages.
    pub pool_reserve: f64,
}

impl ChaosSpec {
    /// Compose this schedule onto a sim spec: the first kill lands on
    /// `FaultSpec::kill_replica` (keeping single-kill A/Bs bit-compatible
    /// with the §L7 degraded bench), the rest on `extra_kills`.
    pub fn apply(&self, spec: &mut SimSpec) {
        if let Some(&(replica, after)) = self.kills.first() {
            spec.fault.kill_replica = Some(replica);
            spec.fault.kill_after_calls = after;
        }
        spec.fault.extra_kills.extend(self.kills.iter().skip(1).copied());
        if self.stuck_every > 0 {
            spec.fault.stuck_every = self.stuck_every;
            spec.fault.stuck_step_ns = self.stuck_step_ns;
        }
        if self.pool_reserve > 0.0 {
            if let Some(pool) = spec.pool.as_mut() {
                let keep = (pool.pool_pages as f64 * (1.0 - self.pool_reserve.clamp(0.0, 1.0)))
                    .floor() as usize;
                let floor = pages_for(spec.enc_len + spec.dec_len, pool.page_size);
                pool.pool_pages = keep.max(floor);
            }
        }
    }
}

/// §L11: how a *new* sim version differs from the serving one — the
/// deploy analogue of `ChaosSpec`. `apply` derives the successor
/// version's `SimSpec` from the old one, so swap benches describe "the
/// new checkpoint is 10% cheaper" or "the new checkpoint is broken" as
/// data. Composes with `ChaosSpec`: chaos targets `fault` fields, a
/// swap targets costs and the bad-version injections.
#[derive(Debug, Clone, Default)]
pub struct SimSwapSpec {
    /// Per-token / per-step cost multiplier for the new version (a
    /// re-distilled successor is usually cheaper). 0.0 means 1.0.
    pub cost_mult: f64,
    /// Deterministic bad-version injection, exercised by the rollback
    /// arms.
    pub bad: BadVersionMode,
}

/// What a deliberately broken successor version does. Both modes are
/// deterministic so the rollback benches and parity assertions pin
/// exact behavior.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum BadVersionMode {
    /// The new version is healthy.
    #[default]
    None,
    /// Every engine call panics — the canary crashes at its very first
    /// probe decode (exercises the crash-rollback path).
    Panic,
    /// Decode emits wrong-but-well-formed tokens: the per-row hash is
    /// salted so every non-EOS token differs from the old version while
    /// stream lengths and costs stay identical (exercises the
    /// token-parity probe gate).
    WrongTokens,
}

/// Salt XORed into the sim row hash by `BadVersionMode::WrongTokens`.
/// Only token *values* change — `sim_gen_len` and EOS placement key off
/// the unsalted hash, so a wrong-token version is behaviorally
/// identical except for what it says.
const BAD_VERSION_SALT: u64 = 0x0BAD_5EED_0BAD_5EED;

impl SimSwapSpec {
    /// Derive the new version's spec from the serving one.
    pub fn apply(&self, old: &SimSpec) -> SimSpec {
        let mut spec = old.clone();
        let m = if self.cost_mult > 0.0 { self.cost_mult } else { 1.0 };
        let scale = |ns: u64| -> u64 { ((ns as f64) * m).round().max(0.0) as u64 };
        spec.token_ns = scale(spec.token_ns);
        spec.dtoken_ns = scale(spec.dtoken_ns);
        spec.dstep_ns = scale(spec.dstep_ns);
        if let Some(draft) = spec.draft.as_mut() {
            draft.dtoken_ns = scale(draft.dtoken_ns);
            draft.dstep_ns = scale(draft.dstep_ns);
        }
        match self.bad {
            BadVersionMode::None => {}
            BadVersionMode::Panic => spec.bad_panic = true,
            BadVersionMode::WrongTokens => spec.bad_token_salt = BAD_VERSION_SALT,
        }
        spec
    }
}

#[derive(Debug, Clone)]
pub struct SimSpec {
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    pub vocab_size: usize,
    /// Simulated device nanoseconds per prefill token. A monolithic
    /// `decode_step` batch prefills the full `batch_size x bucket`
    /// geometry; a split `prefill` runs varlen-style over only the
    /// admitted `rows x bucket`. `ALTUP_SIM_TOKEN_NS` sets the default
    /// (else 20000 — ~20 ms per full (8,128) prefill, in the ballpark
    /// of a micro-model CPU decode — so service time, not
    /// router/scheduler overhead, dominates benches even on small
    /// shared machines).
    pub token_ns: u64,
    /// Simulated ns per slot-row per fused decode step (the decoder
    /// reads one token's worth of weights per live row).
    /// `ALTUP_SIM_DTOKEN_NS` sets the default (else `token_ns`).
    pub dtoken_ns: u64,
    /// Fixed dispatch overhead per prefill/decode-step execute.
    /// `ALTUP_SIM_DSTEP_NS` sets the default (else 50000).
    pub dstep_ns: u64,
    /// Pretend the artifact ships the split prefill/decode_token HLO
    /// pair. `false` exercises the batch-level fallback path.
    pub split_decode: bool,
    /// §L8 draft-model cost/acceptance model. `Some` means the sim
    /// "artifact" ships a draft (speculation still needs
    /// `ServerOptions::spec_gamma > 0` to switch on); `None` exercises
    /// the no-draft fallback path.
    pub draft: Option<SimDraftSpec>,
    /// §L9 paged decode-state pool. `Some` means the sim "artifact"
    /// ships the paged contract and replicas serve the continuous path
    /// out of a page pool with host-side allocation, prefix caching,
    /// and pool-aware admission; `None` exercises the monolithic
    /// fallback. `SimSpec::new` reads it from `ALTUP_POOL_PAGES` &
    /// friends.
    pub pool: Option<SimPoolSpec>,
    /// Injected faults (default: none).
    pub fault: FaultSpec,
    /// §L11 bad-version injection: XORed into every row hash at token
    /// emission, so a "wrong weights" version emits different tokens
    /// with identical stream lengths and costs. 0 = healthy.
    /// `SimSwapSpec::apply` sets it; never read from env.
    pub bad_token_salt: u64,
    /// §L11 bad-version injection: every engine call panics (a version
    /// broken badly enough to crash on first execute).
    pub bad_panic: bool,
}

/// §L9 sim page-pool geometry: mirrors the real backend's
/// `paged` meta entry (page size) + `ALTUP_POOL_PAGES` capacity knob.
/// The pool/table/cache machinery itself is host-side and shared with
/// the real backend — only the per-token cost model is simulated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimPoolSpec {
    /// Tokens of KV per page. `ALTUP_PAGE_SIZE` sets the default
    /// (else 16).
    pub page_size: usize,
    /// Physical pages in the replica pool (the §L9 memory budget).
    pub pool_pages: usize,
    /// Cross-request prefix caching (default on;
    /// `ALTUP_PREFIX_CACHE=0` disables — the A/B baseline).
    pub prefix_cache: bool,
}

impl SimPoolSpec {
    /// `Some` iff `ALTUP_POOL_PAGES` is set nonzero — the paged sim
    /// opt-in, mirroring how a real artifact opts in via its `paged`
    /// meta entry.
    pub fn from_env() -> Option<SimPoolSpec> {
        env::opt_u64_nonzero("ALTUP_POOL_PAGES").map(|pages| SimPoolSpec {
            page_size: env::usize_at_least("ALTUP_PAGE_SIZE", 1, 16),
            pool_pages: pages as usize,
            prefix_cache: env::usize_or("ALTUP_PREFIX_CACHE", 1) > 0,
        })
    }
}

/// Sim cost + acceptance model for the §L8 draft model. Defaults
/// mirror a recycled AltUp-lite draft (fig5): roughly an eighth of the
/// full model's per-row decode cost.
#[derive(Debug, Clone)]
pub struct SimDraftSpec {
    /// Simulated ns per slot-row per draft decode step.
    /// `ALTUP_SIM_DRAFT_TOKEN_NS` sets the default (else `dtoken_ns/8`).
    pub dtoken_ns: u64,
    /// Fixed dispatch overhead per draft step (the draft executable is
    /// smaller, so cheaper to launch too). `ALTUP_SIM_DRAFT_STEP_NS`
    /// sets the default (else `dstep_ns/4`).
    pub dstep_ns: u64,
    /// Probability that any single drafted token matches the full
    /// model's greedy choice, hash-sampled per (row, position) — the
    /// accepted prefix is the leading run of matches, so the mean
    /// accepted length is `α(1-α^γ)/(1-α)`. `ALTUP_SIM_ACCEPT_RATE`
    /// sets the default (else 0.8 — the per-token match rate of a
    /// well-matched draft per Leviathan et al., which the fig5
    /// recycled draft is trained to be). 1.0 = accept-all, 0.0 =
    /// reject-all (the parity-test extremes).
    pub accept_rate: f64,
}

impl SimSpec {
    pub fn new(batch_size: usize, enc_len: usize, dec_len: usize) -> SimSpec {
        let token_ns = env::u64_or("ALTUP_SIM_TOKEN_NS", 20000);
        let dtoken_ns = env::u64_or("ALTUP_SIM_DTOKEN_NS", token_ns);
        let dstep_ns = env::u64_or("ALTUP_SIM_DSTEP_NS", 50000);
        SimSpec {
            batch_size,
            enc_len,
            dec_len,
            vocab_size: 512,
            token_ns,
            dtoken_ns,
            dstep_ns,
            split_decode: true,
            draft: Some(SimDraftSpec {
                dtoken_ns: env::u64_or("ALTUP_SIM_DRAFT_TOKEN_NS", dtoken_ns / 8),
                dstep_ns: env::u64_or("ALTUP_SIM_DRAFT_STEP_NS", dstep_ns / 4),
                accept_rate: env::f64_or("ALTUP_SIM_ACCEPT_RATE", 0.8).clamp(0.0, 1.0),
            }),
            pool: SimPoolSpec::from_env(),
            fault: FaultSpec::default(),
            bad_token_salt: 0,
            bad_panic: false,
        }
    }
}

/// Aggregate serving counters; per-replica stats are merged by the
/// supervisor as replicas exit (including crashed incarnations — their
/// partial counters are recovered through the panic boundary).
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// Requests answered with tokens (explicit failures count in
    /// `failed`, not here).
    pub requests: usize,
    /// Decode batches (batch-level) or prefill admission groups
    /// (continuous) — the unit `mean_fill` averages over.
    pub batches: usize,
    pub total_fill: usize,
    /// How many replica stat sets were merged in (crashed incarnations
    /// and their replacements each count once).
    pub replicas: usize,
    /// Real prompt tokens submitted (post-truncation).
    pub prompt_tokens: usize,
    /// Prefill tokens actually executed — `batch_size * bucket` per
    /// monolithic batch, `rows * bucket` per split prefill — the
    /// denominator of the padded-waste ratio.
    pub executed_tokens: usize,
    pub truncated: usize,
    /// Decoded tokens delivered to clients (EOS-truncated rows).
    pub tokens_generated: usize,
    /// Decode tokens the continuous path did NOT run because slots
    /// retired at EOS (`dec_len - row len`, summed). Zero under
    /// batch-level decode — the monolithic step always runs `dec_len`.
    pub tokens_saved: usize,
    /// Fused full-model decode iterations (continuous path only):
    /// `decode_token` executes, or §L8 verify rounds when speculating.
    pub decode_steps: usize,
    /// Split-prefill executions (continuous path only).
    pub prefills: usize,
    /// §L7: requests shed past their deadline (router or replica side).
    /// Subset of `failed`.
    pub sheds: usize,
    /// §L7: requests requeued to another replica after a crash.
    pub retries: usize,
    /// §L7: replacement replicas the supervisor spawned.
    pub restarts: usize,
    /// §L10: autoscale replicas spawned on sustained queue pressure
    /// (beyond the configured fleet; bounded by
    /// `ServerOptions::autoscale`).
    pub scale_ups: usize,
    /// §L10: autoscale replicas retired once pressure subsided.
    pub scale_downs: usize,
    /// §L7: explicit terminal failures delivered (deadline sheds,
    /// retry exhaustion, drain aborts, dead-server rejections).
    pub failed: usize,
    /// §L7: requests completed after admissions closed (the drain
    /// window of `shutdown()`). Counted on the continuous path — the
    /// default discipline; the batch-level loop cannot observe
    /// admission closure (it only ever sees the job queue end) and
    /// reports 0 here.
    pub drained: usize,
    /// §L8 speculative-decoding counters (drafted/accepted tokens,
    /// draft/verify steps, tokens delivered per verify). All-zero when
    /// speculation is off or unsupported.
    pub spec: SpecMeter,
    /// §L9 paged decode-state counters (pool occupancy, prefix cache
    /// hit rate, prefill tokens saved, evictions, admission stalls).
    /// All-zero when the replica serves monolithic slots.
    pub pool: PoolMeter,
    /// Live-slots-per-decode-iteration meter (continuous path only).
    pub occupancy: OccupancyMeter,
    /// Per-request queued+executed latency, log-bucketed (O(1) memory
    /// over a server's lifetime, mergeable across replicas).
    pub latency: LatencyHistogram,
    /// Per-token latency (request latency / tokens delivered).
    pub token_latency: LatencyHistogram,
    /// §L10 per-tenant QoS accounting, indexed by `Request::tenant`
    /// (grown on demand; empty when no tenant ever completed or
    /// failed). Names live in `ServerOptions::tenants` — the stats
    /// carry only indices so replicas stay config-free.
    pub tenants: Vec<TenantMeter>,
    /// §L11 per-version rollout accounting (requests by artifact
    /// version, canary verdicts, rollbacks). `current` tags which
    /// version this stat set's completions/failures land on; the
    /// version rows partition the global counters the same way
    /// `tenants` does.
    pub deploy: DeployMeter,
}

impl ServerStats {
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_fill as f64 / self.batches as f64
        }
    }

    /// Fraction of executed tokens that were padding: 1 - prompt/executed.
    pub fn waste_ratio(&self) -> f64 {
        if self.executed_tokens == 0 {
            0.0
        } else {
            1.0 - self.prompt_tokens as f64 / self.executed_tokens as f64
        }
    }

    /// Fraction of the monolithic decode budget the early exit saved:
    /// saved / (saved + generated).
    pub fn early_exit_ratio(&self) -> f64 {
        let budget = self.tokens_saved + self.tokens_generated;
        if budget == 0 {
            0.0
        } else {
            self.tokens_saved as f64 / budget as f64
        }
    }

    /// Number of latency samples recorded (== requests served).
    pub fn latency_count(&self) -> u64 {
        self.latency.count()
    }

    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        self.latency.percentile_ms(p)
    }
    pub fn p50_ms(&self) -> f64 {
        self.latency_percentile_ms(50.0)
    }
    pub fn p95_ms(&self) -> f64 {
        self.latency_percentile_ms(95.0)
    }
    pub fn p99_ms(&self) -> f64 {
        self.latency_percentile_ms(99.0)
    }
    /// Mean per-token latency in ms (histogram approximation).
    pub fn token_ms(&self) -> f64 {
        self.token_latency.mean_ms()
    }

    /// Record one finished request's bookkeeping (shared by both
    /// decode disciplines).
    fn note_response(
        &mut self,
        latency: Duration,
        generated: usize,
        saved: usize,
        prompt: usize,
        truncated: bool,
    ) {
        let ms = latency.as_secs_f64() * 1e3;
        self.latency.record(ms);
        self.token_latency.record(ms / generated.max(1) as f64);
        self.tokens_generated += generated;
        self.tokens_saved += saved;
        self.prompt_tokens += prompt;
        if truncated {
            self.truncated += 1;
        }
    }

    /// Fold another replica's counters into this aggregate.
    pub fn merge(&mut self, other: &ServerStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.total_fill += other.total_fill;
        self.replicas += other.replicas;
        self.prompt_tokens += other.prompt_tokens;
        self.executed_tokens += other.executed_tokens;
        self.truncated += other.truncated;
        self.tokens_generated += other.tokens_generated;
        self.tokens_saved += other.tokens_saved;
        self.decode_steps += other.decode_steps;
        self.prefills += other.prefills;
        self.sheds += other.sheds;
        self.retries += other.retries;
        self.restarts += other.restarts;
        self.scale_ups += other.scale_ups;
        self.scale_downs += other.scale_downs;
        self.failed += other.failed;
        self.drained += other.drained;
        self.spec.merge(&other.spec);
        self.pool.merge(&other.pool);
        self.occupancy.merge(&other.occupancy);
        self.latency.merge(&other.latency);
        self.token_latency.merge(&other.token_latency);
        for (t, m) in other.tenants.iter().enumerate() {
            self.tenant_mut(t).merge(m);
        }
        self.deploy.merge(&other.deploy);
    }

    /// The meter for tenant `t`, growing the table on first touch so
    /// replicas need no tenant config to account correctly.
    pub fn tenant_mut(&mut self, t: usize) -> &mut TenantMeter {
        if self.tenants.len() <= t {
            self.tenants.resize_with(t + 1, TenantMeter::default);
        }
        &mut self.tenants[t]
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} requests / {} batches on {} replica(s), mean fill {:.2}, \
             padded waste {:.1}%, {} tokens out (early exit saved {:.1}%), \
             mean occupancy {:.2} over {} decode steps, \
             latency p50 {:.2} / p95 {:.2} / p99 {:.2} ms",
            self.requests,
            self.batches,
            self.replicas.max(1),
            self.mean_fill(),
            self.waste_ratio() * 100.0,
            self.tokens_generated,
            self.early_exit_ratio() * 100.0,
            self.occupancy.mean(),
            self.decode_steps,
            self.p50_ms(),
            self.p95_ms(),
            self.p99_ms()
        );
        if self.spec.active() {
            s.push_str(&format!(
                " | spec: {:.1}% acceptance ({}/{} drafted), {:.2} tokens/verify \
                 over {} verify steps",
                self.spec.acceptance_rate() * 100.0,
                self.spec.accepted,
                self.spec.drafted,
                self.spec.tokens_per_verify(),
                self.spec.verify_steps
            ));
        }
        if self.pool.active() {
            s.push_str(&format!(
                " | pool: {:.1}% occupancy (peak {}/{} pages), prefix hit rate {:.1}%, \
                 {} prefill tokens saved, {} evictions, {} stalls",
                self.pool.utilization() * 100.0,
                self.pool.peak_used,
                self.pool.capacity,
                self.pool.hit_rate() * 100.0,
                self.pool.prefill_tokens_saved,
                self.pool.evictions,
                self.pool.alloc_stalls
            ));
        }
        if self.failed + self.retries + self.restarts + self.drained > 0 {
            s.push_str(&format!(
                " | faults: {} shed / {} retried / {} restarts / {} failed / {} drained",
                self.sheds, self.retries, self.restarts, self.failed, self.drained
            ));
        }
        if self.deploy.active() {
            let versions: Vec<String> = self
                .deploy
                .versions
                .iter()
                .enumerate()
                .map(|(v, m)| format!("v{v}:{}", m.requests))
                .collect();
            s.push_str(&format!(
                " | deploy: {} canary pass / {} fail, {} rollback(s), {} completed, \
                 {} aborted, requests by version [{}]",
                self.deploy.canary_pass,
                self.deploy.canary_fail,
                self.deploy.rollbacks,
                self.deploy.completed,
                self.deploy.aborted,
                versions.join(" ")
            ));
        }
        s
    }
}

/// Send an explicit terminal failure for `req` and count it. The send
/// is best-effort: a client that already gave up dropped its receiver.
fn fail_request(stats: &mut ServerStats, req: &Request, reason: FailReason, replica: usize) {
    stats.failed += 1;
    let shed = matches!(
        reason,
        FailReason::DeadlineExceeded | FailReason::QueueFull | FailReason::WouldMissDeadline
    );
    if shed {
        stats.sheds += 1;
    }
    let tm = stats.tenant_mut(req.tenant);
    tm.failed += 1;
    if shed {
        tm.sheds += 1;
    }
    stats.deploy.note_failed(shed);
    let _ = req.reply.send(Response::failed(reason, req.t0, replica));
}

/// A request the router has accepted into a bucket group. Latency is
/// reported from the client-side `Request::t0`; the batch-window
/// deadline runs from `admitted`, so a request that sat in the request
/// channel does not count that wait against its group's window (which
/// would ship burst arrivals as tiny immediately-due batches).
struct Admitted {
    req: Request,
    admitted: Instant,
    /// How many times a crashed replica already held this request (the
    /// supervisor's retry counter).
    attempts: u32,
}

/// A bucket-homogeneous batch ready for a replica.
struct BatchJob {
    bucket: usize,
    requests: Vec<Admitted>,
}

/// §L7: every request a replica has accepted but not yet terminally
/// answered, keyed by ticket. The ledger lives outside the panic
/// boundary, so the supervisor can requeue or explicitly fail whatever
/// a crashed replica was holding — no reply channel is ever silently
/// dropped with a dying thread.
struct Ledger {
    inner: Mutex<LedgerInner>,
}

struct LedgerInner {
    next_ticket: u64,
    held: HashMap<u64, Held>,
}

/// A ledger entry: the original request plus the routing state needed
/// to requeue it (bucket) and cap its retries (attempts).
struct Held {
    bucket: usize,
    attempts: u32,
    req: Request,
}

impl Ledger {
    fn new() -> Ledger {
        Ledger { inner: Mutex::new(LedgerInner { next_ticket: 0, held: HashMap::new() }) }
    }

    /// Poison-proof lock: the ledger is read after a replica panic by
    /// design, and entries are plain data — a poisoned guard is safe to
    /// recover.
    fn lock(&self) -> std::sync::MutexGuard<'_, LedgerInner> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn admit(&self, bucket: usize, attempts: u32, req: Request) -> u64 {
        let mut inner = self.lock();
        let ticket = inner.next_ticket;
        inner.next_ticket += 1;
        inner.held.insert(ticket, Held { bucket, attempts, req });
        ticket
    }

    fn take(&self, ticket: u64) -> Option<Held> {
        self.lock().held.remove(&ticket)
    }

    /// Run `f` over a held request's prompt tokens in place (§L9
    /// prefix-chunk hashing at admission) — no clone, same reasoning
    /// as `pack_rows`. `None` when the ticket was already taken.
    fn with_prompt<R>(&self, ticket: u64, f: impl FnOnce(&[i32]) -> R) -> Option<R> {
        let inner = self.lock();
        inner.held.get(&ticket).map(|h| f(&h.req.enc_tokens))
    }

    fn drain(&self) -> Vec<Held> {
        self.lock().held.drain().map(|(_, h)| h).collect()
    }

    /// Pack the held requests behind `tickets` into the (batch_size,
    /// len) geometry, borrowing their prompt rows in place — the hot
    /// path never clones a prompt just because ownership sits in the
    /// ledger. Row order follows `tickets`; a ticket already taken
    /// packs as an empty row (cannot happen on the owning replica).
    fn pack_rows(
        &self,
        tickets: &[u64],
        batch_size: usize,
        len: usize,
        enc: &mut Vec<i32>,
        truncated: &mut Vec<bool>,
    ) {
        let inner = self.lock();
        let rows: Vec<&[i32]> = tickets
            .iter()
            .map(|t| inner.held.get(t).map_or(&[][..], |h| h.req.enc_tokens.as_slice()))
            .collect();
        pack_requests_into(&rows, batch_size, len, enc, truncated);
    }
}

/// What a replica thread reports to the supervisor as its last act —
/// its stats (partial if it crashed), the crash cause if any, and every
/// in-flight request its ledger still held.
struct ReplicaExit {
    id: usize,
    stats: ServerStats,
    /// `Some` when the replica crashed (panic or error) rather than
    /// drained cleanly.
    error: Option<String>,
    unfinished: Vec<Held>,
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: <non-string payload>".to_string()
    }
}

/// Spawn one replica thread behind the §L7 panic boundary. The thread's
/// terminal `ReplicaExit` event — stats, crash cause, unfinished
/// ledger — always reaches the supervisor, panic or not.
fn spawn_replica(
    id: usize,
    spec: &EngineSpec,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
    events: &mpsc::Sender<ReplicaExit>,
    shared: &Arc<QosShared>,
    version: u32,
) -> std::thread::JoinHandle<()> {
    let spec = spec.clone();
    let jobs = Arc::clone(jobs);
    let opts = opts.clone();
    let events = events.clone();
    let shared = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("altup-replica-{id}"))
        .spawn(move || {
            let ledger = Ledger::new();
            let mut stats = ServerStats { replicas: 1, ..Default::default() };
            // §L11: everything this incarnation completes or fails is
            // accounted to its artifact version.
            stats.deploy.current = version;
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                serve_replica(id, &spec, &jobs, &opts, &ledger, &mut stats, &shared)
            }));
            let error = match outcome {
                Ok(Ok(())) => None,
                Ok(Err(e)) => Some(format!("{e:#}")),
                Err(payload) => Some(panic_message(payload.as_ref())),
            };
            let unfinished = ledger.drain();
            let _ = events.send(ReplicaExit { id, stats, error, unfinished });
        })
        .expect("spawn replica")
}

pub struct ServerHandle {
    /// Bounded: `send` blocks once `ServerOptions::queue_cap` requests
    /// are in flight ahead of the router (admission backpressure).
    pub sender: mpsc::SyncSender<Request>,
    router: Option<std::thread::JoinHandle<Result<ServerStats>>>,
    /// Cleared the moment the router thread exits (even by panic), so
    /// `infer` can reject new work immediately instead of touching a
    /// channel whose receiver is gone.
    router_up: Arc<AtomicBool>,
    /// §L11 rollout mailbox shared with the router's rollout driver.
    deploy_ctl: Arc<DeployControl>,
}

/// Clears the router-liveness flag on drop — including on unwind.
struct RouterGuard(Arc<AtomicBool>);

impl Drop for RouterGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

impl ServerHandle {
    /// Spawn router + replicas serving the named artifact.
    pub fn spawn(artifact_name: &str, opts: ServerOptions) -> ServerHandle {
        ServerHandle::spawn_engine(
            EngineSpec::Artifact { name: artifact_name.to_string() },
            opts,
        )
    }

    /// Spawn supervisor/router + replicas over an explicit decode
    /// backend.
    pub fn spawn_engine(engine: EngineSpec, opts: ServerOptions) -> ServerHandle {
        let n = opts.replicas.max(1);
        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(opts.queue_cap.max(1));
        // Bounded job queue = backpressure: when every replica is busy
        // and the queue is full, the router keeps accumulating instead
        // of window-flushing tiny partial batches at a wall of busy
        // replicas (which craters fill and wastes executed tokens).
        // §L10: the job queue is sized for the autoscaled fleet, so a
        // scaled-up replica never starves the queue of slots and the
        // scale-down sentinel always has room.
        let (job_tx, job_rx) = mpsc::sync_channel::<BatchJob>(n + opts.autoscale);
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (events_tx, events_rx) = mpsc::channel::<ReplicaExit>();
        let shared = Arc::new(QosShared::new());

        let handles: Vec<_> = (0..n)
            .map(|i| spawn_replica(i, &engine, &job_rx, &opts, &events_tx, &shared, 0))
            .collect();
        let router_up = Arc::new(AtomicBool::new(true));
        let deploy_ctl = Arc::new(DeployControl::new());
        let router = {
            let spec = engine.clone();
            let ropts = opts.clone();
            let flag = Arc::clone(&router_up);
            let ctl = Arc::clone(&deploy_ctl);
            std::thread::Builder::new()
                .name("altup-router".into())
                .spawn(move || {
                    let _guard = RouterGuard(flag);
                    route(
                        &spec, req_rx, job_tx, job_rx, events_rx, events_tx, &ropts, handles,
                        shared, ctl,
                    )
                })
                .expect("spawn router")
        };
        ServerHandle { sender: req_tx, router: Some(router), router_up, deploy_ctl }
    }

    /// Submit a request and block for the response; explicit failure
    /// responses are mapped to `Err`. The latency clock starts before
    /// the (possibly blocking) send into the bounded request channel,
    /// so backpressured requests report their queueing time.
    pub fn infer(&self, enc_tokens: Vec<i32>) -> Result<Response> {
        let resp = self.infer_response(enc_tokens)?;
        match resp.failure {
            Some(reason) => Err(anyhow!("request failed: {reason}")),
            None => Ok(resp),
        }
    }

    /// Like `infer`, but returns explicit-failure responses as
    /// `Ok(Response)` so callers can inspect `Response::failure`.
    /// Errors only when the server machinery itself is gone (router
    /// dead before admission, reply channel dropped).
    pub fn infer_response(&self, enc_tokens: Vec<i32>) -> Result<Response> {
        if !self.router_up.load(Ordering::Acquire) {
            bail!("server router is down; request not admitted");
        }
        let (tx, rx) = mpsc::channel();
        self.sender
            .send(Request::new(enc_tokens, tx))
            .map_err(|_| anyhow!("server router is down; request not admitted"))?;
        rx.recv().map_err(|_| {
            anyhow!("server dropped the reply channel (shutdown() reports the cause)")
        })
    }

    /// §L11: roll the fleet onto a new engine version, one replica at a
    /// time behind the canary health gates. Blocks until the rollout
    /// reaches a terminal [`DeployStatus`] (completed, rolled back,
    /// failed validation, or aborted by shutdown). Rollouts queue:
    /// concurrent calls run strictly one at a time.
    pub fn deploy(&self, engine: EngineSpec) -> DeployStatus {
        let seq = self.deploy_start(engine);
        self.deploy_wait(seq)
    }

    /// §L11: enqueue a rollout without blocking; returns a ticket for
    /// `deploy_wait`. Lets a caller overlap a rollout with its own
    /// work (or shut the server down mid-rollout — the ticket then
    /// resolves to `Aborted`).
    pub fn deploy_start(&self, engine: EngineSpec) -> u64 {
        self.deploy_ctl.submit(engine)
    }

    /// §L11: block until the rollout behind `seq` reaches a terminal
    /// [`DeployStatus`].
    pub fn deploy_wait(&self, seq: u64) -> DeployStatus {
        self.deploy_ctl.wait(seq, &self.router_up)
    }

    /// §L11: `deploy` for a compiled artifact by suite name — the
    /// `Server::deploy(artifact_dir)` entry point (artifact names
    /// resolve to directories via the suite registry, and
    /// `Artifact::load` verifies the version fingerprint + checksums
    /// before the fleet ever sees the new weights).
    pub fn deploy_artifact(&self, name: &str) -> DeployStatus {
        self.deploy(EngineSpec::Artifact { name: name.to_string() })
    }

    /// §L11: live rollout status snapshot (`Idle` before any deploy).
    pub fn deploy_status(&self) -> DeployStatus {
        self.deploy_ctl.status()
    }

    /// Drain and shut down: stop admissions, flush partial groups, let
    /// replicas retire their in-flight slots naturally, join every
    /// thread, and return the merged stats. Every admitted request gets
    /// a terminal response before this returns. An in-flight rollout is
    /// aborted cleanly (reported as `Aborted` to its waiter and in the
    /// stats' deploy section).
    pub fn shutdown(self) -> Result<ServerStats> {
        let ServerHandle { sender, router, router_up: _, deploy_ctl: _ } = self;
        let router = router.expect("router handle");
        drop(sender); // stop admissions; the router begins its drain
        match router.join() {
            Ok(result) => result,
            Err(_) => Err(anyhow!("router thread panicked")),
        }
    }
}

/// (batch_size, enc_len) of the serving geometry. For artifacts this
/// runs the full `Artifact::load` (including §L11 checksum
/// verification), so the §L11 prep thread reuses it as the new
/// version's load-time validation.
pub(crate) fn engine_dims(spec: &EngineSpec) -> Result<(usize, usize)> {
    match spec {
        EngineSpec::Artifact { name } => {
            let artifact = load_named(name)?;
            Ok((artifact.config.batch_size, artifact.config.enc_len))
        }
        EngineSpec::Sim(s) => Ok((s.batch_size, s.enc_len)),
    }
}

/// The supervisor's replica bookkeeping: what it needs to respawn a
/// replacement (specs by version, options, the shared job queue, the
/// event channel) plus the live count and restart budget. `pub(crate)`
/// so the §L11 rollout driver (coordinator/deploy.rs) can drive
/// targeted drains and version-pinned spawns through it.
pub(crate) struct Supervisor {
    /// Engine spec per artifact version; version 0 is the spec the
    /// server booted on, each §L11 rollout registers the next.
    pub(crate) specs: BTreeMap<u32, EngineSpec>,
    /// §L11: the version every *new* spawn (crash respawn, autoscale,
    /// rollout replacement) lands on. Starts at 0, flips to the new
    /// version when a rollout's first canary passes, reverts on
    /// rollback.
    pub(crate) decided: u32,
    /// §L11: which version each live replica id is serving (ids are
    /// never reused; entries are removed on exit).
    pub(crate) versions: HashMap<usize, u32>,
    pub(crate) opts: ServerOptions,
    jobs: Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    events_tx: mpsc::Sender<ReplicaExit>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub(crate) live: usize,
    restarts_left: usize,
    pub(crate) next_id: usize,
    last_error: Option<String>,
    /// Set when the fleet died while admissions were still open (last
    /// crash with the job queue open and no restart budget left) —
    /// recorded at event-processing time, so `shutdown()` reports it
    /// deterministically no matter how the client disconnect races
    /// the exit events.
    died: Option<String>,
    /// §L10 satellite: respawns scheduled but not yet due. Replacing
    /// the old spawn-on-crash with a backoff queue means a poison-pill
    /// artifact burns the restart budget over seconds, not
    /// milliseconds — `tick_respawns` drains this from the router
    /// loop. A non-empty queue counts as "fleet coming back" for the
    /// died/NoReplicas checks.
    pending_respawns: Vec<Instant>,
    /// Crashes that consumed restart budget — the backoff exponent.
    crashes: u32,
    /// §L10/§L11: the degradation + rollout levers handed to every
    /// replica this supervisor spawns (respawns and autoscale replicas
    /// included).
    pub(crate) shared: Arc<QosShared>,
}

impl Supervisor {
    /// Fold a replica exit into the aggregate: merge its stats, requeue
    /// or explicitly fail its in-flight requests, and respawn a
    /// replacement when it crashed and the budget allows. `job_open`
    /// is whether the job queue can still carry requeued work (false
    /// once the drain has closed it). `allow_respawn` is false when the
    /// §L11 rollout driver already owns this exit (it spawned the
    /// replacement itself — no restart budget is spent and a rollout
    /// lifecycle exit can never be mistaken for fleet death).
    fn on_exit(
        &mut self,
        ev: ReplicaExit,
        stats: &mut ServerStats,
        groups: &mut BTreeMap<usize, Vec<Admitted>>,
        job_open: bool,
        allow_respawn: bool,
    ) {
        self.live = self.live.saturating_sub(1);
        self.versions.remove(&ev.id);
        stats.merge(&ev.stats);
        let crashed = ev.error.is_some();
        if let Some(err) = ev.error {
            self.last_error = Some(format!("replica {}: {}", ev.id, err));
        }
        for held in ev.unfinished {
            let attempts = held.attempts + 1;
            if !job_open {
                fail_request(stats, &held.req, FailReason::AbortedOnDrain, ROUTER_ID);
            } else if attempts > self.opts.max_retries {
                fail_request(stats, &held.req, FailReason::RetriesExhausted, ROUTER_ID);
            } else {
                stats.retries += 1;
                groups.entry(held.bucket).or_default().push(Admitted {
                    req: held.req,
                    admitted: Instant::now(),
                    attempts,
                });
            }
        }
        if crashed && allow_respawn && job_open && self.restarts_left > 0 {
            // §L10 satellite: schedule the replacement behind an
            // exponential backoff instead of spawning it here — a
            // persistently-failing artifact must not crash-loop
            // through its whole restart budget in one supervision
            // pass.
            self.restarts_left -= 1;
            let delay = self.backoff_delay();
            self.crashes += 1;
            self.pending_respawns.push(Instant::now() + delay);
        }
        if crashed
            && allow_respawn
            && job_open
            && self.live == 0
            && self.pending_respawns.is_empty()
            && self.died.is_none()
        {
            self.died = Some(
                self.last_error.clone().unwrap_or_else(|| "replica crash".to_string()),
            );
        }
    }

    /// Exponential backoff with deterministic jitter for the next
    /// respawn: `restart_backoff_ms * 2^crashes` (exponent capped at
    /// 6), jittered into [0.75, 1.25) of nominal so a fleet of
    /// supervisors does not thundering-herd its restarts.
    fn backoff_delay(&self) -> Duration {
        let base = self.opts.restart_backoff_ms.max(1);
        let nominal = base.saturating_mul(1u64 << self.crashes.min(6));
        let h = sim_mix(self.opts.seed ^ 0x51C0_u64.wrapping_add(self.crashes as u64));
        let jittered = (nominal - nominal / 4).saturating_add(h % (nominal / 2 + 1));
        Duration::from_millis(jittered)
    }

    /// Spawn every scheduled respawn whose backoff has elapsed. With
    /// the job queue closed (drain) pending respawns are dropped — a
    /// replacement would only pop `Popped::Gone` and exit.
    fn tick_respawns(&mut self, stats: &mut ServerStats, job_open: bool) {
        if !job_open {
            self.pending_respawns.clear();
            return;
        }
        let now = Instant::now();
        let mut i = 0;
        while i < self.pending_respawns.len() {
            if self.pending_respawns[i] <= now {
                self.pending_respawns.swap_remove(i);
                stats.restarts += 1;
                self.spawn_one();
            } else {
                i += 1;
            }
        }
    }

    /// Spawn one replica with a fresh id (respawn or §L10 autoscale) on
    /// the rollout-decided version.
    fn spawn_one(&mut self) {
        let v = self.decided;
        self.spawn_version(v);
    }

    /// §L11: spawn one replica with a fresh id pinned to version `v`
    /// (canaries, rollback replacements, and — via `spawn_one` — every
    /// respawn and autoscale spawn). Returns the new replica id.
    pub(crate) fn spawn_version(&mut self, v: u32) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        let spec = self
            .specs
            .get(&v)
            .or_else(|| self.specs.get(&self.decided))
            .expect("version spec registered")
            .clone();
        self.versions.insert(id, v);
        self.handles.push(spawn_replica(
            id,
            &spec,
            &self.jobs,
            &self.opts,
            &self.events_tx,
            &self.shared,
            v,
        ));
        self.live += 1;
        id
    }

    /// §L11: the next replica a rollout to `version` should drain — the
    /// lowest-id live replica still on a different version.
    pub(crate) fn next_swap_target(&self, version: u32) -> Option<usize> {
        self.versions.iter().filter(|&(_, &v)| v != version).map(|(&id, _)| id).min()
    }

    /// Whether the fleet can still serve or come back: live replicas
    /// now, or a respawn already scheduled.
    fn can_serve(&self) -> bool {
        self.live > 0 || !self.pending_respawns.is_empty()
    }
}

/// Shed every request already past its deadline out of the router's
/// bucket groups, answering each with an explicit failure.
fn shed_expired(groups: &mut BTreeMap<usize, Vec<Admitted>>, stats: &mut ServerStats) {
    let now = Instant::now();
    for group in groups.values_mut() {
        group.retain(|a| {
            if a.req.expired(now) {
                fail_request(stats, &a.req, FailReason::DeadlineExceeded, ROUTER_ID);
                false
            } else {
                true
            }
        });
    }
    groups.retain(|_, g| !g.is_empty());
}

/// Router + supervisor loop (§L5 admission/bucketing + §L7 lifecycle).
///
/// Admission: group requests by bucket, ship full groups immediately
/// and window-expired partial groups best-effort, shedding anything
/// past its deadline before dispatch. Every send is a `try_send` — a
/// full queue parks the router briefly instead of blocking it, so
/// supervision (replica exits, requeues, respawns) is never starved.
///
/// Supervision: replica exit events are folded in every pass; crashed
/// replicas' in-flight requests are requeued (bounded per-request
/// retries) and replacements respawned within the restart budget. With
/// no live replicas and no budget left the router answers every
/// request with an explicit failure until clients hang up, then
/// reports the crash from `shutdown()`.
///
/// Drain: once every client sender is gone, remaining groups flush,
/// the job queue closes (replicas retire in-flight slots and exit),
/// exit events are collected, and all threads are joined.
#[allow(clippy::too_many_arguments)]
fn route(
    spec: &EngineSpec,
    rx: mpsc::Receiver<Request>,
    job_tx: mpsc::SyncSender<BatchJob>,
    job_rx: Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    events_rx: mpsc::Receiver<ReplicaExit>,
    events_tx: mpsc::Sender<ReplicaExit>,
    opts: &ServerOptions,
    handles: Vec<std::thread::JoinHandle<()>>,
    shared: Arc<QosShared>,
    deploy_ctl: Arc<DeployControl>,
) -> Result<ServerStats> {
    let mut sup = Supervisor {
        specs: BTreeMap::from([(0u32, spec.clone())]),
        decided: 0,
        versions: (0..handles.len()).map(|i| (i, 0u32)).collect(),
        opts: opts.clone(),
        jobs: job_rx,
        events_tx,
        live: handles.len(),
        next_id: handles.len(),
        restarts_left: opts.replica_restarts,
        last_error: None,
        died: None,
        pending_respawns: Vec::new(),
        crashes: 0,
        shared: Arc::clone(&shared),
        handles,
    };
    let mut stats = ServerStats::default();
    let mut fatal: Option<anyhow::Error> = None;

    let (batch_size, enc_len) = match engine_dims(spec) {
        Ok(dims) => dims,
        Err(e) => {
            // Without the serving geometry nothing can be dispatched:
            // stop restarts and fail every request until clients hang
            // up. The replicas hit the same load error and exit on
            // their own.
            fatal = Some(e);
            sup.restarts_left = 0;
            (1, 1)
        }
    };
    let mut job_tx = if fatal.is_none() { Some(job_tx) } else { None };
    // §L11 rollout driver: advances the swap state machine from the
    // supervision pass and intercepts rollout-owned replica exits.
    let mut rollout = RolloutDriver::new(deploy_ctl, (batch_size, enc_len));
    let timeout = opts.request_timeout_ms.map(Duration::from_millis);
    let mut groups: BTreeMap<usize, Vec<Admitted>> = BTreeMap::new();
    let mut disconnected = false;
    // §L10 QoS admission layer. With no tenants configured it is a
    // strict passthrough: `offer` hands every request straight back
    // and the overload controller never engages.
    let mut qos = AdmissionController::new(
        opts.tenants.clone(),
        opts.queue_cap.max(1),
        opts.spec_gamma,
        Instant::now(),
    );
    // Autoscale replicas currently up (bounded by `opts.autoscale`).
    let mut extra_live: usize = 0;
    let mut qos_actions: Vec<QosAction> = Vec::new();

    loop {
        // Supervision pass: fold in replica exits (requeue/fail their
        // in-flight work, respawn within budget once each backoff
        // elapses). §L11 rollout-owned exits (drain target gone ->
        // spawn canary; canary gone -> rollback) are intercepted first.
        while let Ok(ev) = events_rx.try_recv() {
            let respawn =
                rollout.observe_exit(ev.id, ev.error.is_some(), &mut sup, &mut stats);
            sup.on_exit(ev, &mut stats, &mut groups, job_tx.is_some(), respawn);
        }
        sup.tick_respawns(&mut stats, job_tx.is_some());
        // §L11: advance the rollout state machine; a server that is
        // draining or has lost its fleet aborts instead.
        if disconnected || job_tx.is_none() {
            let reason = if disconnected {
                "server shut down during the rollout"
            } else {
                "no serving fleet left for the rollout"
            };
            rollout.abort_all(&mut sup, &mut stats, reason);
        } else {
            rollout.tick(&mut sup, &mut stats);
        }
        if !sup.can_serve() {
            if fatal.is_none() {
                if let Some(err) = sup.died.take() {
                    fatal = Some(anyhow!(
                        "serving stopped: no live replicas and restart budget exhausted ({err})"
                    ));
                }
            }
            job_tx = None;
            for (_, group) in std::mem::take(&mut groups) {
                for a in group {
                    fail_request(&mut stats, &a.req, FailReason::NoReplicas, ROUTER_ID);
                }
            }
            // §L10: requests still parked in tenant queues have no
            // fleet left to wait for either.
            if qos.queued() > 0 {
                let mut parked = Vec::new();
                qos.release(qos.queued(), &mut parked);
                for req in parked {
                    fail_request(&mut stats, &req, FailReason::NoReplicas, ROUTER_ID);
                }
            }
            // Strand recovery: jobs already sitting in the queue when
            // the last replica died have no consumer left — fail them
            // explicitly instead of leaving their clients blocked.
            while let Ok(Popped::Job(job)) = pop_job(&sup.jobs, false) {
                for a in job.requests {
                    fail_request(&mut stats, &a.req, FailReason::NoReplicas, ROUTER_ID);
                }
            }
            if disconnected {
                break;
            }
        }

        // Deadline pass: shed expired requests before dispatch.
        shed_expired(&mut groups, &mut stats);

        // §L10 QoS pass: expire parked requests, walk the overload
        // ladder on sustained pressure, execute its degradation
        // actions, and release parked work into bucket groups in
        // weighted-priority order. No-op in passthrough mode.
        if !qos.passthrough() {
            let now = Instant::now();
            let mut expired = Vec::new();
            qos.take_expired(now, &mut expired);
            for req in &expired {
                fail_request(&mut stats, req, FailReason::DeadlineExceeded, ROUTER_ID);
            }
            let downstream: usize = groups.values().map(|g| g.len()).sum();
            qos_actions.clear();
            qos.tick(now, downstream, sup.live.max(1) * batch_size, &mut qos_actions);
            for action in qos_actions.drain(..) {
                match action {
                    QosAction::GammaCap(cap) => {
                        shared.gamma_cap.store(cap, Ordering::Relaxed);
                    }
                    QosAction::ScaleUp => {
                        if extra_live < opts.autoscale && job_tx.is_some() {
                            sup.spawn_one();
                            extra_live += 1;
                            stats.scale_ups += 1;
                        }
                    }
                    QosAction::ScaleDown => {
                        if extra_live > 0 {
                            if let Some(tx) = &job_tx {
                                if tx.try_send(scale_down_job()).is_ok() {
                                    extra_live -= 1;
                                    stats.scale_downs += 1;
                                }
                            }
                        }
                    }
                }
            }
            // Release bounded to ~two waves of fleet work: the backlog
            // beyond that stays in the tenant queues, where priority
            // and SLO decisions still apply, instead of FIFO-frozen in
            // bucket groups.
            if job_tx.is_some() && sup.live > 0 {
                let room = (sup.live * batch_size * 2).saturating_sub(downstream);
                if room > 0 {
                    let mut released = Vec::new();
                    qos.release(room, &mut released);
                    let admitted = Instant::now();
                    for req in released {
                        let bucket = if opts.bucketed {
                            bucket_for(req.enc_tokens.len(), enc_len)
                        } else {
                            enc_len
                        };
                        groups
                            .entry(bucket)
                            .or_default()
                            .push(Admitted { req, admitted, attempts: 0 });
                    }
                }
            }
        }

        // Flush pass. Every ship is a `try_send` (a blocking send here
        // could deadlock the supervisor against a dead replica set and
        // would starve crash handling), but the pre-L7 backpressure
        // semantics are preserved: full groups ship first — fullest
        // bucket first, in batch_size chunks — and while a full group
        // cannot ship, admission pauses (below) so clients stack up in
        // the bounded request channel exactly as the old blocking send
        // made them, and due partial groups do not steal the next
        // freed queue slot.
        let mut full_unsent = false;
        let mut due_unsent = false;
        if let Some(tx) = &job_tx {
            let now = Instant::now();
            let mut buckets: Vec<usize> = groups.keys().copied().collect();
            buckets.sort_by_key(|b| std::cmp::Reverse(groups[b].len()));
            for bucket in buckets {
                let Some(group) = groups.get(&bucket) else { continue };
                if group.len() < batch_size && !disconnected {
                    continue;
                }
                let mut requests = groups.remove(&bucket).expect("group present");
                while !requests.is_empty() {
                    let take = requests.len().min(batch_size);
                    let chunk: Vec<Admitted> = requests.drain(..take).collect();
                    match tx.try_send(BatchJob { bucket, requests: chunk }) {
                        Ok(()) => {}
                        Err(mpsc::TrySendError::Full(job))
                        | Err(mpsc::TrySendError::Disconnected(job)) => {
                            // Queue full (park and retry) or every
                            // replica receiver gone (their exit events
                            // are already on the way — the supervision
                            // pass above handles them).
                            let mut back = job.requests;
                            back.append(&mut requests);
                            groups.insert(bucket, back);
                            full_unsent = true;
                            break;
                        }
                    }
                }
                if full_unsent {
                    break; // queue full: no point probing other groups
                }
            }
            // Window-expired partial groups ship best-effort, and only
            // when no full group is still waiting for capacity.
            if !full_unsent {
                let buckets: Vec<usize> = groups.keys().copied().collect();
                for bucket in buckets {
                    let Some(group) = groups.get(&bucket) else { continue };
                    let due = group
                        .first()
                        .is_some_and(|a| now >= a.admitted + opts.batch_window);
                    if !due {
                        continue;
                    }
                    let requests = groups.remove(&bucket).expect("group present");
                    match tx.try_send(BatchJob { bucket, requests }) {
                        Ok(()) => {}
                        Err(mpsc::TrySendError::Full(job))
                        | Err(mpsc::TrySendError::Disconnected(job)) => {
                            groups.insert(bucket, job.requests);
                            due_unsent = true;
                            break;
                        }
                    }
                }
            }
        }

        // Drain: admissions closed and everything flushed — close the
        // job queue so replicas retire their slots and exit, then wait
        // for their exit events.
        if disconnected {
            // §L10: every parked request must still reach a terminal
            // response — release the lot into bucket groups while a
            // fleet exists, fail it explicitly otherwise.
            if qos.queued() > 0 {
                let mut parked = Vec::new();
                qos.release(qos.queued(), &mut parked);
                if sup.can_serve() && job_tx.is_some() {
                    let admitted = Instant::now();
                    for req in parked {
                        let bucket = if opts.bucketed {
                            bucket_for(req.enc_tokens.len(), enc_len)
                        } else {
                            enc_len
                        };
                        groups
                            .entry(bucket)
                            .or_default()
                            .push(Admitted { req, admitted, attempts: 0 });
                    }
                } else {
                    for req in parked {
                        fail_request(&mut stats, &req, FailReason::NoReplicas, ROUTER_ID);
                    }
                }
                continue; // flush the freshly-released groups first
            }
            if groups.is_empty() {
                job_tx = None;
            }
            if sup.live == 0 && groups.is_empty() {
                break;
            }
            if let Ok(ev) = events_rx.recv_timeout(Duration::from_millis(50)) {
                let respawn =
                    rollout.observe_exit(ev.id, ev.error.is_some(), &mut sup, &mut stats);
                sup.on_exit(ev, &mut stats, &mut groups, job_tx.is_some(), respawn);
            }
            continue;
        }

        // Admit pass: park until the next request or group deadline,
        // capped at the supervision tick so replica exits are noticed
        // promptly.
        let wait = if full_unsent || due_unsent {
            // Floor the park so a zero batch window cannot busy-spin
            // while replicas are saturated and the job queue is full.
            opts.batch_window.max(Duration::from_micros(200))
        } else if groups.is_empty() {
            SUPERVISE_TICK
        } else {
            let oldest = groups
                .values()
                .filter_map(|g| g.first())
                .map(|a| a.admitted)
                .min()
                .expect("non-empty groups");
            (oldest + opts.batch_window).saturating_duration_since(Instant::now())
        };
        let message = if wait.is_zero() {
            None // a group came due during the flush pass
        } else if full_unsent {
            // Admission paused: a full group is waiting for queue
            // capacity. Park without draining the request channel so
            // clients feel the backpressure, then retry the flush.
            std::thread::sleep(wait.min(SUPERVISE_TICK));
            None
        } else {
            match rx.recv_timeout(wait.min(SUPERVISE_TICK)) {
                Ok(r) => Some(r),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    None
                }
            }
        };
        if let Some(mut req) = message {
            if req.deadline.is_none() {
                req.deadline = timeout.map(|t| req.t0 + t);
            }
            // Admission-time shed comes FIRST: a request already past
            // its deadline (zero timeout, client clock skew, a long
            // stall in the bounded request channel) must never enter a
            // bucket group — and the shed is reported as the
            // deterministic `DeadlineExceeded` even when the fleet is
            // simultaneously dead.
            if req.expired(Instant::now()) {
                fail_request(&mut stats, &req, FailReason::DeadlineExceeded, ROUTER_ID);
            } else if !sup.can_serve() || job_tx.is_none() {
                fail_request(&mut stats, &req, FailReason::NoReplicas, ROUTER_ID);
            } else {
                // §L10: the admission controller rules first — rate
                // limit, early SLO shed, queue cap/preemption. In
                // passthrough mode (no tenants) it hands the request
                // straight back and admission is exactly pre-L10.
                let downstream: usize = groups.values().map(|g| g.len()).sum();
                match qos.offer(req, Instant::now(), downstream) {
                    Ok(Some(req)) => {
                        let bucket = if opts.bucketed {
                            bucket_for(req.enc_tokens.len(), enc_len)
                        } else {
                            enc_len
                        };
                        groups
                            .entry(bucket)
                            .or_default()
                            .push(Admitted { req, admitted: Instant::now(), attempts: 0 });
                    }
                    Ok(None) => {} // parked in a tenant queue
                    Err((victim, reason)) => {
                        fail_request(&mut stats, &victim, reason, ROUTER_ID);
                    }
                }
            }
        }
    }

    // Join every replica thread (initial + respawned replacements).
    for handle in sup.handles.drain(..) {
        let _ = handle.join();
    }
    if fatal.is_none() {
        if let Some(err) = sup.died.take() {
            fatal = Some(anyhow!(
                "serving stopped: no live replicas and restart budget exhausted ({err})"
            ));
        }
    }
    match fatal {
        Some(e) => Err(e),
        None => Ok(stats),
    }
}

/// The per-replica decode backend (built inside the replica thread:
/// `Session` is !Send). `pub(crate)` so `coordinator::spec` can drive
/// the §L8 draft/verify round; not part of the public API.
pub(crate) enum Engine {
    Real {
        client: Client,
        session: Session,
        /// §L8 draft-model session, loaded from the artifact's
        /// meta.json `draft` entry when speculation is requested.
        draft: Option<Session>,
    },
    Sim(SimEngine),
}

/// Sim backend instance: the spec plus per-replica fault bookkeeping
/// (the engine-call counter drives deterministic kill injection).
pub(crate) struct SimEngine {
    spec: SimSpec,
    replica: usize,
    calls: u64,
}

impl SimEngine {
    fn new(spec: SimSpec, replica: usize) -> SimEngine {
        SimEngine { spec, replica, calls: 0 }
    }

    /// Count one engine execute and trigger any injected fault due at
    /// this call. Panics deliberately — exercising the replica panic
    /// boundary exactly the way a real backend crash would.
    fn on_call(&mut self) {
        self.calls += 1;
        if self.spec.bad_panic {
            // §L11 bad-version injection: a version broken badly enough
            // to crash on its very first execute — the canary dies at
            // its probe decode, before any live traffic.
            panic!(
                "injected sim fault: bad version panics on replica {} call {} \
                 (expected during §L11 rollback tests/benches)",
                self.replica, self.calls
            );
        }
        let f = &self.spec.fault;
        let killed_here = (f.kill_replica == Some(self.replica)
            && self.calls >= f.kill_after_calls.max(1))
            || f.extra_kills
                .iter()
                .any(|&(r, after)| r == self.replica && self.calls >= after.max(1));
        if killed_here {
            panic!(
                "injected sim fault: replica {} killed at engine call {} \
                 (expected during fault-injection tests/benches)",
                self.replica, self.calls
            );
        }
        if f.panic_rate > 0.0 {
            let h = sim_mix(((self.replica as u64) << 32) ^ self.calls);
            if (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < f.panic_rate {
                panic!(
                    "injected sim fault: hash-sampled panic on replica {} call {} \
                     (expected during fault-injection tests/benches)",
                    self.replica, self.calls
                );
            }
        }
    }
}

/// Per-replica slot state for the continuous path: device-resident KV
/// buffers for the real backend, per-slot decode cursors for the sim.
pub(crate) enum SlotState {
    Real {
        /// `Option` so the `DecodeSlots` can be moved through the
        /// donating `Session::prefill`/`decode_token`/`verify` calls
        /// and put back.
        main: Option<DecodeSlots>,
        /// §L8 draft-model slot state, kept prefix-synced with `main`
        /// by `draft_accept` after every verify. `None` when the
        /// engine carries no draft session.
        draft: Option<DecodeSlots>,
    },
    Sim(Vec<Option<SimSlot>>),
}

/// One live sim request: prompt hash (the whole decode stream derives
/// from it), next position, the hash-sampled generation length, and
/// whether fault injection marked it a stuck (never-EOS) generation.
#[derive(Clone, Copy)]
pub(crate) struct SimSlot {
    h: u64,
    pos: usize,
    gen_len: usize,
    stuck: bool,
}

/// §L8 γ resolution against a (real-backend) session — the single
/// predicate shared by the draft loader (`Engine::build`) and the
/// serve-time activation check (`Engine::effective_spec_gamma`): the
/// requested γ when the artifact ships `verify@<requested>`, else the
/// artifact's compiled `DraftSpec::gamma`, else 0 (plain decode).
fn resolve_spec_gamma(session: &Session, requested: usize) -> usize {
    if requested == 0 {
        return 0;
    }
    let Some(d) = &session.artifact.draft else { return 0 };
    if session.has_verify(requested) {
        requested
    } else if session.has_verify(d.gamma) {
        d.gamma
    } else {
        0
    }
}

impl SimSlot {
    /// The deterministic "true" (greedy full-model) token at absolute
    /// decode position `j`: EOS exactly at the sampled generation end
    /// (stuck rows never reach it), `sim_token` everywhere else. The
    /// single source of truth shared by plain decode, drafting, and
    /// verify — which is what makes sim spec decoding exact-by-
    /// construction, mirroring the real greedy-verify guarantee.
    /// `salt` is the §L11 bad-version salt (0 = healthy): it perturbs
    /// token values only — EOS placement keys off the unsalted hash,
    /// so a wrong-token version stays cost-identical.
    fn token_at(&self, j: usize, vocab: usize, salt: u64) -> i32 {
        if !self.stuck && j + 1 == self.gen_len {
            EOS
        } else {
            sim_token(self.h ^ salt, j, vocab)
        }
    }
}

impl Engine {
    pub(crate) fn build(replica: usize, spec: &EngineSpec, opts: &ServerOptions) -> Result<Engine> {
        match spec {
            EngineSpec::Artifact { name } => {
                let client = Client::cpu()?;
                let artifact = load_named(name)?;
                let mut session = Session::open_eval(&client, artifact, opts.seed)?;
                if let Some(ckpt) = &opts.checkpoint {
                    session.store =
                        crate::runtime::params::ParamStore::load(ckpt, &session.artifact)?;
                    session.invalidate_state();
                }
                session.ensure_decode(&client)?;
                // §Perf L4: upload the weights once; every batch reuses
                // the device-resident buffers.
                session.warm_device_cache(&client)?;
                // §L8: load the draft session only when speculation
                // will actually engage (`resolve_spec_gamma` — the
                // same predicate `effective_spec_gamma` applies at
                // serve time, so "draft loaded" and "speculation runs"
                // cannot drift apart) — otherwise the replica serves
                // plain decode and must not pay draft memory/prefill
                // for nothing. A named draft that fails to load or
                // mismatches the serving geometry is a real error.
                let draft = match &session.artifact.draft {
                    Some(d) if resolve_spec_gamma(&session, opts.spec_gamma) > 0 => {
                        let dartifact = load_named(&d.artifact)?;
                        let (mc, dc) = (&session.artifact.config, &dartifact.config);
                        if dc.enc_len != mc.enc_len
                            || dc.dec_len != mc.dec_len
                            || dc.vocab_size != mc.vocab_size
                        {
                            bail!(
                                "draft artifact {} geometry mismatch: enc_len {} vs {}, \
                                 dec_len {} vs {}, vocab {} vs {} (the draft must share \
                                 the main artifact's serving geometry)",
                                d.artifact,
                                dc.enc_len,
                                mc.enc_len,
                                dc.dec_len,
                                mc.dec_len,
                                dc.vocab_size,
                                mc.vocab_size
                            );
                        }
                        let mut dsession =
                            Session::open_eval(&client, dartifact, opts.seed)?;
                        if !dsession.has_split_decode() {
                            bail!(
                                "draft artifact {} ships no split-decode HLO pair",
                                d.artifact
                            );
                        }
                        dsession.warm_device_cache(&client)?;
                        Some(dsession)
                    }
                    _ => None,
                };
                Ok(Engine::Real { client, session, draft })
            }
            EngineSpec::Sim(s) => Ok(Engine::Sim(SimEngine::new(s.clone(), replica))),
        }
    }

    /// (batch_size, enc_len) of the serving geometry.
    pub(crate) fn dims(&self) -> (usize, usize) {
        match self {
            Engine::Real { session, .. } => {
                (session.artifact.config.batch_size, session.artifact.config.enc_len)
            }
            Engine::Sim(e) => (e.spec.batch_size, e.spec.enc_len),
        }
    }

    /// Maximum tokens a request may generate.
    fn dec_len(&self) -> usize {
        match self {
            Engine::Real { session, .. } => session.artifact.config.dec_len,
            Engine::Sim(e) => e.spec.dec_len,
        }
    }

    /// Whether this engine can run the split prefill/decode_token
    /// discipline (the artifact ships the HLO pair — monolithic-slot
    /// or §L9 paged; the sim can opt out to exercise the fallback).
    fn supports_continuous(&self) -> bool {
        match self {
            Engine::Real { session, .. } => {
                session.has_split_decode() || session.has_paged_decode()
            }
            Engine::Sim(e) => e.spec.split_decode,
        }
    }

    /// §L9: the paged serving geometry — `(page_size, pool_pages,
    /// prefix_cache)` — when this engine carries the paged decode
    /// contract. `None` means the replica serves monolithic
    /// `DecodeSlots` (the documented fallback). The real backend reads
    /// pool capacity from `ALTUP_POOL_PAGES` (default: the monolithic
    /// batch's worth of pages) and the prefix-cache switch from
    /// `ALTUP_PREFIX_CACHE`; the sim carries both in its spec.
    fn paged_geometry(&self) -> Option<(usize, usize, bool)> {
        match self {
            Engine::Real { session, .. } => {
                if !session.has_paged_decode() {
                    return None;
                }
                let page_size = session.page_size()?;
                let max_pages = session.max_pages().ok()?;
                let pool_pages = env::opt_u64_nonzero("ALTUP_POOL_PAGES")
                    .map_or(session.artifact.config.batch_size * max_pages, |v| v as usize);
                Some((page_size, pool_pages, env::usize_or("ALTUP_PREFIX_CACHE", 1) > 0))
            }
            Engine::Sim(e) => {
                e.spec.pool.as_ref().map(|p| (p.page_size, p.pool_pages, p.prefix_cache))
            }
        }
    }

    /// The sequence length a monolithic job at `bucket` actually
    /// executes at (the real backend falls back to `enc_len` when the
    /// artifact has no shape-specialized HLO for the bucket).
    fn effective_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_bucket(bucket),
            Engine::Sim(e) => bucket.min(e.spec.enc_len),
        }
    }

    /// Same, for the split prefill family.
    fn effective_prefill_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_prefill_bucket(bucket),
            Engine::Sim(e) => bucket.min(e.spec.enc_len),
        }
    }

    /// Same, for the §L9 `prefill_paged` family.
    fn effective_paged_prefill_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_paged_prefill_bucket(bucket),
            Engine::Sim(e) => bucket.min(e.spec.enc_len),
        }
    }

    /// Monolithic decode of a (batch_size, bucket) packed batch.
    pub(crate) fn decode(&mut self, enc: &[i32], bucket: usize) -> Result<Vec<Vec<i32>>> {
        match self {
            Engine::Real { client, session, .. } => {
                session.decode_bucketed(client, enc, bucket)
            }
            Engine::Sim(e) => {
                e.on_call();
                Ok(sim_decode(&e.spec, enc, bucket))
            }
        }
    }

    /// Allocate the per-replica slot state for `n` concurrent requests
    /// (plus the mirrored draft-model slot state when speculating).
    fn init_slots(&mut self, n: usize) -> Result<SlotState> {
        match self {
            Engine::Real { client, session, draft } => {
                let main = Some(session.init_decode_slots(client, n)?);
                let draft = match draft {
                    Some(ds) => Some(ds.init_decode_slots(client, n)?),
                    None => None,
                };
                Ok(SlotState::Real { main, draft })
            }
            Engine::Sim(_) => Ok(SlotState::Sim(vec![None; n])),
        }
    }

    /// §L9: allocate the device-resident page pool (`pool_pages`
    /// physical pages) for `n` concurrent requests. The draft-model
    /// slot state stays monolithic — prefix reuse applies to the main
    /// model's KV, not the draft's.
    fn init_slots_paged(&mut self, n: usize, pool_pages: usize) -> Result<SlotState> {
        match self {
            Engine::Real { client, session, draft } => {
                let main = Some(session.init_paged_slots(client, pool_pages)?);
                let draft = match draft {
                    Some(ds) => Some(ds.init_decode_slots(client, n)?),
                    None => None,
                };
                Ok(SlotState::Real { main, draft })
            }
            Engine::Sim(_) => Ok(SlotState::Sim(vec![None; n])),
        }
    }

    /// Prefill a same-bucket admission group, `enc` packed row-major at
    /// (slot_ids.len(), bucket), into slot rows `slot_ids`.
    fn prefill(
        &mut self,
        state: &mut SlotState,
        enc: &[i32],
        bucket: usize,
        slot_ids: &[usize],
    ) -> Result<()> {
        match (self, state) {
            (Engine::Real { client, session, draft }, SlotState::Real { main, draft: dslots }) => {
                let held = main
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let ids: Vec<i32> = slot_ids.iter().map(|&s| s as i32).collect();
                *main = Some(session.prefill(client, held, enc, bucket, &ids)?);
                // §L8: the draft model prefills the same prompts into
                // the same slot rows, so both KV caches start from an
                // identical prefix.
                if let Some(ds) = draft {
                    let dheld = dslots
                        .take()
                        .context("draft slot state lost after an earlier error")?;
                    *dslots = Some(ds.prefill(client, dheld, enc, bucket, &ids)?);
                }
                Ok(())
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let spec = &e.spec;
                for (row, &sid) in enc.chunks(bucket).zip(slot_ids.iter()) {
                    let h = sim_row_hash(row);
                    slots[sid] = Some(SimSlot {
                        h,
                        pos: 0,
                        gen_len: sim_gen_len(h, spec.dec_len),
                        stuck: spec.fault.stuck(h),
                    });
                }
                // Varlen-style split prefill: dispatch overhead + cost
                // over the admitted rows only (no dead padding rows).
                sim_sleep(
                    spec.dstep_ns
                        + spec.token_ns.saturating_mul((slot_ids.len() * bucket) as u64),
                );
                Ok(())
            }
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// §L9 paged prefill: like `prefill`, plus the group's flattened
    /// (rows, max_pages) page-table operand and the prompt tokens the
    /// prefix cache already covers. On the real backend shared prefix
    /// pages may be rewritten by the HLO — with bit-identical KV, since
    /// a prefix's KV depends only on its tokens — so sharing stays
    /// sound; the sim charges the compute saving (`saved_tokens` of
    /// per-token work skipped), which is what the twin and benches
    /// measure.
    fn prefill_paged(
        &mut self,
        state: &mut SlotState,
        enc: &[i32],
        bucket: usize,
        slot_ids: &[usize],
        page_table: &[i32],
        saved_tokens: usize,
    ) -> Result<()> {
        match (self, state) {
            (Engine::Real { client, session, draft }, SlotState::Real { main, draft: dslots }) => {
                let held = main
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let ids: Vec<i32> = slot_ids.iter().map(|&s| s as i32).collect();
                *main = Some(session.prefill_paged(client, held, enc, bucket, &ids, page_table)?);
                // §L8: the draft model's KV stays monolithic — same
                // prompts, same slot rows, no prefix sharing.
                if let Some(ds) = draft {
                    let dheld = dslots
                        .take()
                        .context("draft slot state lost after an earlier error")?;
                    *dslots = Some(ds.prefill(client, dheld, enc, bucket, &ids)?);
                }
                Ok(())
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let spec = &e.spec;
                for (row, &sid) in enc.chunks(bucket).zip(slot_ids.iter()) {
                    let h = sim_row_hash(row);
                    slots[sid] = Some(SimSlot {
                        h,
                        pos: 0,
                        gen_len: sim_gen_len(h, spec.dec_len),
                        stuck: spec.fault.stuck(h),
                    });
                }
                // Prefix hits skip their covered prompt tokens: the
                // varlen prefill runs `rows*bucket - saved` tokens'
                // worth of work. Tokens still derive from the full row
                // hash — output parity with the unpaged path is by
                // construction.
                sim_sleep(
                    spec.dstep_ns
                        + spec.token_ns.saturating_mul(
                            (slot_ids.len() * bucket).saturating_sub(saved_tokens) as u64,
                        ),
                );
                Ok(())
            }
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// One fused decode iteration over the whole slot geometry:
    /// advances every slot with `live[s] == true` by one token and
    /// returns the (slots,) token row (dead rows carry garbage).
    fn decode_token(&mut self, state: &mut SlotState, live: &[bool]) -> Result<Vec<i32>> {
        match (self, state) {
            (Engine::Real { client, session, .. }, SlotState::Real { main, .. }) => {
                let held = main
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let (held, tokens) = session.decode_token(client, held, live)?;
                *main = Some(held);
                Ok(tokens)
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let spec = &e.spec;
                let mut out = vec![0i32; slots.len()];
                let mut stuck_live = 0u64;
                for (s, slot) in slots.iter_mut().enumerate() {
                    if !live[s] {
                        continue;
                    }
                    let sl = slot.as_mut().context("live mask set on an empty sim slot")?;
                    out[s] = sl.token_at(sl.pos, spec.vocab_size, spec.bad_token_salt);
                    sl.pos += 1;
                    if sl.stuck {
                        stuck_live += 1;
                    }
                }
                // Fused step over the full static slot geometry; stuck
                // rows are also slow rows.
                sim_sleep(
                    spec.dstep_ns
                        + spec.dtoken_ns.saturating_mul(slots.len() as u64)
                        + spec.fault.stuck_step_ns.saturating_mul(stuck_live),
                );
                Ok(out)
            }
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// §L9 paged decode iteration: `decode_token` with the flattened
    /// (slots, max_pages) page-table operand. The sim delegates to the
    /// monolithic step — the slot-to-page mapping is host-side
    /// bookkeeping there, and decode cost is per live row either way.
    fn decode_token_paged(
        &mut self,
        state: &mut SlotState,
        live: &[bool],
        page_table: &[i32],
    ) -> Result<Vec<i32>> {
        if let Engine::Real { client, session, .. } = self {
            let SlotState::Real { main, .. } = state else {
                bail!("engine/slot-state backend mismatch");
            };
            let held = main
                .take()
                .context("slot state lost after an earlier prefill/decode error")?;
            let (held, tokens) = session.decode_token_paged(client, held, live, page_table)?;
            *main = Some(held);
            return Ok(tokens);
        }
        self.decode_token(state, live)
    }

    /// §L8: the draft length this engine will actually speculate at
    /// for a requested `--spec-gamma` (`resolve_spec_gamma` on the
    /// real backend — requested γ, or the artifact's compiled
    /// fallback). 0 means speculation is unavailable (no draft
    /// session, no runnable verify, or not requested) and the replica
    /// silently runs plain decode — the documented fallback.
    fn effective_spec_gamma(&self, requested: usize) -> usize {
        match self {
            Engine::Real { session, draft, .. } => {
                if draft.is_none() {
                    0
                } else {
                    resolve_spec_gamma(session, requested)
                }
            }
            Engine::Sim(e) => {
                // The sim has no compiled-γ constraint: any requested
                // length runs, given a draft cost model.
                if requested > 0 && e.spec.draft.is_some() {
                    requested
                } else {
                    0
                }
            }
        }
    }

    /// §L8: draft `gamma` tokens per live slot — γ cheap draft-model
    /// decode steps. Returns one row per slot; dead slots get empty
    /// rows. The draft state runs ahead speculatively; `verify`
    /// re-syncs it to what the full model accepts.
    pub(crate) fn draft_tokens(
        &mut self,
        state: &mut SlotState,
        live: &[bool],
        gamma: usize,
    ) -> Result<Vec<Vec<i32>>> {
        match (self, state) {
            (
                Engine::Real { client, draft: Some(ds), .. },
                SlotState::Real { draft: dslots, .. },
            ) => {
                let mut out: Vec<Vec<i32>> = vec![Vec::new(); live.len()];
                for _ in 0..gamma {
                    let held = dslots
                        .take()
                        .context("draft slot state lost after an earlier error")?;
                    let (held, toks) = ds.decode_token(client, held, live)?;
                    *dslots = Some(held);
                    for (s, row) in out.iter_mut().enumerate() {
                        if live[s] {
                            row.push(toks[s]);
                        }
                    }
                }
                Ok(out)
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let Some(d) = e.spec.draft.as_ref() else {
                    bail!("sim spec ships no draft model");
                };
                let mut out: Vec<Vec<i32>> = vec![Vec::new(); slots.len()];
                for (s, slot) in slots.iter().enumerate() {
                    if !live[s] {
                        continue;
                    }
                    let sl = slot.as_ref().context("live mask set on an empty sim slot")?;
                    out[s] = (0..gamma)
                        .map(|j| sl.token_at(sl.pos + j, e.spec.vocab_size, e.spec.bad_token_salt))
                        .collect();
                }
                // γ draft steps over the static slot geometry, charged
                // as one wait. The sim drafts the TRUE greedy tokens;
                // draft fallibility is modeled in `verify`'s acceptance
                // sampling instead, which mirrors the real guarantee
                // that accepted tokens are exactly the full model's.
                sim_sleep((gamma as u64).saturating_mul(
                    d.dstep_ns + d.dtoken_ns.saturating_mul(slots.len() as u64),
                ));
                Ok(out)
            }
            (Engine::Real { draft: None, .. }, _) => bail!("engine has no draft session"),
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// §L8: one fused verify across all live slots — the full model
    /// scores the drafted tokens in a single step, each live slot
    /// advances by its accepted prefix + 1 correction token, and (real
    /// backend) the draft state re-syncs via `draft_accept`. Returns
    /// per-slot `(accept_len, correction)` rows.
    pub(crate) fn verify(
        &mut self,
        state: &mut SlotState,
        drafted: &[Vec<i32>],
        live: &[bool],
        gamma: usize,
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        match (self, state) {
            (
                Engine::Real { client, session, draft: Some(ds) },
                SlotState::Real { main, draft: dslots },
            ) => {
                // Flatten to the (S, γ) geometry the HLO expects; dead
                // rows pad with zeros (ignored under the live mask).
                let mut flat = vec![0i32; live.len() * gamma];
                for (s, row) in drafted.iter().enumerate() {
                    let n = row.len().min(gamma);
                    flat[s * gamma..s * gamma + n].copy_from_slice(&row[..n]);
                }
                let held = main
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let (held, accept, correction) =
                    session.verify(client, held, &flat, live, gamma)?;
                *main = Some(held);
                let dheld = dslots
                    .take()
                    .context("draft slot state lost after an earlier error")?;
                *dslots = Some(ds.spec_accept(client, dheld, &accept, &correction, live)?);
                Ok((accept, correction))
            }
            (Engine::Sim(e), SlotState::Sim(slots)) => {
                e.on_call();
                let spec = &e.spec;
                let Some(d) = spec.draft.as_ref() else {
                    bail!("sim spec ships no draft model");
                };
                let mut accept = vec![0i32; slots.len()];
                let mut correction = vec![0i32; slots.len()];
                let mut stuck_live = 0u64;
                for (s, slot) in slots.iter_mut().enumerate() {
                    if !live[s] {
                        continue;
                    }
                    let sl = slot.as_mut().context("live mask set on an empty sim slot")?;
                    let a = sim_accept_len(sl.h, sl.pos, gamma, d.accept_rate);
                    accept[s] = a as i32;
                    correction[s] = sl.token_at(sl.pos + a, spec.vocab_size, spec.bad_token_salt);
                    sl.pos += a + 1;
                    if sl.stuck {
                        stuck_live += 1;
                    }
                }
                // One fused full-model step over the static slot
                // geometry: decode is weight-bound, so scoring γ+1
                // positions costs ~one `decode_token` step (and stuck
                // rows stay slow rows).
                sim_sleep(
                    spec.dstep_ns
                        + spec.dtoken_ns.saturating_mul(slots.len() as u64)
                        + spec.fault.stuck_step_ns.saturating_mul(stuck_live),
                );
                Ok((accept, correction))
            }
            (Engine::Real { draft: None, .. }, _) => bail!("engine has no draft session"),
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// §L9 paged verify (§L8 speculation on the paged path): `verify`
    /// with the flattened page-table operand. The sim delegates to the
    /// monolithic verify — acceptance sampling and cost are
    /// page-layout-independent.
    pub(crate) fn verify_paged(
        &mut self,
        state: &mut SlotState,
        drafted: &[Vec<i32>],
        live: &[bool],
        gamma: usize,
        page_table: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        if let Engine::Real { client, session, draft } = self {
            let Some(ds) = draft else { bail!("engine has no draft session") };
            let SlotState::Real { main, draft: dslots } = state else {
                bail!("engine/slot-state backend mismatch");
            };
            let mut flat = vec![0i32; live.len() * gamma];
            for (s, row) in drafted.iter().enumerate() {
                let n = row.len().min(gamma);
                flat[s * gamma..s * gamma + n].copy_from_slice(&row[..n]);
            }
            let held = main
                .take()
                .context("slot state lost after an earlier prefill/decode error")?;
            let (held, accept, correction) =
                session.verify_paged(client, held, &flat, live, gamma, page_table)?;
            *main = Some(held);
            let dheld = dslots
                .take()
                .context("draft slot state lost after an earlier error")?;
            *dslots = Some(ds.spec_accept(client, dheld, &accept, &correction, live)?);
            return Ok((accept, correction));
        }
        self.verify(state, drafted, live, gamma)
    }
}

/// §L9 host-side paged-serving state: the replica's page pool, one
/// page table per decode slot, and (when enabled) the cross-request
/// prefix cache. Backend-agnostic — the sim and real engines share
/// this allocator; only the device calls differ.
struct PoolServing {
    pool: PagePool,
    tables: Vec<PageTable>,
    cache: Option<PrefixCache>,
    /// Page-table width of every paged entry point:
    /// `ceil((enc_len + dec_len) / page_size)`.
    max_pages: usize,
}

/// Flatten per-slot page tables (rows picked by `slot_ids`, in order)
/// into the row-major (rows, max_pages) i32 operand the paged HLOs
/// take; unmapped entries are -1.
fn flatten_page_tables(tables: &[PageTable], slot_ids: &[usize], max_pages: usize) -> Vec<i32> {
    let mut flat = vec![-1i32; slot_ids.len() * max_pages];
    for (i, &sid) in slot_ids.iter().enumerate() {
        for (k, &page) in tables[sid].pages().iter().enumerate().take(max_pages) {
            flat[i * max_pages + k] = page as i32;
        }
    }
    flat
}

/// FNV-1a over a row's non-padding prompt tokens only, so decode
/// streams are identical no matter which bucket executed the prompt
/// (the parity contract real bucketed decode must also satisfy).
fn sim_row_hash(row: &[i32]) -> u64 {
    let used = row.iter().rposition(|&t| t != 0).map_or(0, |i| i + 1);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in &row[..used] {
        h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// 64-bit finalizer (murmur3-style) shared by the gen-length sampler
/// and the hash-sampled panic injector.
fn sim_mix(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^ (x >> 29)
}

/// Hash-sampled generation length in [1, dec_len] — the "EOS
/// distribution" of the sim workload. The row's final token is EOS.
fn sim_gen_len(h: u64, dec_len: usize) -> usize {
    1 + (sim_mix(h) % dec_len.max(1) as u64) as usize
}

/// §L8 sim acceptance model: drafted token j (absolute decode position
/// `pos + j`) matches the full model's greedy choice iff a hash coin
/// keyed on (row hash, position) lands under `rate`; the accepted
/// prefix is the leading run of matches, so the mean accepted length
/// is `rate(1-rate^γ)/(1-rate)`. `rate` 1.0 accepts everything, 0.0
/// rejects everything (the parity-test extremes). Deterministic in
/// (h, pos): a retried decode accepts identically, preserving §L7
/// crash-recovery determinism. Mirrored bit-for-bit by
/// `python/tools/server_throughput_twin.py`.
fn sim_accept_len(h: u64, pos: usize, gamma: usize, rate: f64) -> usize {
    let mut n = 0;
    while n < gamma {
        let x = sim_mix(h ^ ((pos + n) as u64).wrapping_mul(0xD1B5_4A32_D192_ED03));
        if (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64) >= rate {
            break;
        }
        n += 1;
    }
    n
}

/// Deterministic non-EOS token for decode position `j`: in
/// [2, vocab) — ids 0 (PAD) and 1 (EOS) stay reserved.
fn sim_token(h: u64, j: usize, vocab: usize) -> i32 {
    let mut x = h.wrapping_mul(j as u64 + 1).wrapping_add(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 29;
    2 + (x % (vocab.max(3) as u64 - 2)) as i32
}

/// Precise simulated-device wait. Kernels round `thread::sleep` up to
/// their timer quantum (~1 ms on some hosts), which would tax the
/// continuous path's many sub-ms fused decode steps while leaving the
/// batch path's few ~20 ms sleeps untouched — so coarse-sleep the bulk
/// and yield-spin the final stretch.
fn sim_sleep(ns: u64) {
    if ns == 0 {
        return;
    }
    let end = Instant::now() + Duration::from_nanos(ns);
    loop {
        let now = Instant::now();
        if now >= end {
            return;
        }
        let rem = end - now;
        if rem > Duration::from_micros(1500) {
            std::thread::sleep(rem - Duration::from_micros(1200));
        } else {
            std::thread::yield_now();
        }
    }
}

/// Deterministic stand-in monolithic decode: each output row derives
/// from the row's non-padding prompt tokens only and ends at its
/// hash-sampled EOS — except injected stuck generations, which run the
/// full `dec_len` without ever emitting EOS. Costs the full geometry —
/// `batch_size x bucket` prefill plus all `dec_len` decode steps for
/// every row, early exit or not — which is exactly what the split
/// path's A/B measures against.
fn sim_decode(spec: &SimSpec, enc: &[i32], bucket: usize) -> Vec<Vec<i32>> {
    let mut out = Vec::with_capacity(spec.batch_size);
    let mut stuck_rows = 0u64;
    for row in enc.chunks(bucket) {
        let h = sim_row_hash(row);
        // §L11: the bad-version salt perturbs token values only —
        // stuck class, generation length, and EOS placement key off
        // the unsalted hash, so a wrong-token version is
        // cost-identical to the healthy one.
        let th = h ^ spec.bad_token_salt;
        if spec.fault.stuck(h) {
            stuck_rows += 1;
            out.push((0..spec.dec_len).map(|j| sim_token(th, j, spec.vocab_size)).collect());
            continue;
        }
        let gen_len = sim_gen_len(h, spec.dec_len);
        let mut tokens = Vec::with_capacity(gen_len);
        for j in 0..gen_len {
            tokens.push(if j + 1 == gen_len { EOS } else { sim_token(th, j, spec.vocab_size) });
        }
        out.push(tokens);
    }
    let prefill = spec.token_ns.saturating_mul((spec.batch_size * bucket) as u64);
    let decode = (spec.dec_len as u64)
        .saturating_mul(spec.dstep_ns + spec.dtoken_ns.saturating_mul(spec.batch_size as u64));
    let stuck_tax =
        stuck_rows.saturating_mul(spec.dec_len as u64).saturating_mul(spec.fault.stuck_step_ns);
    sim_sleep(prefill + decode + stuck_tax);
    out
}

/// Truncate a decoded row at its first EOS (inclusive), aligning the
/// monolithic path's output with what the continuous path actually
/// generated before retiring the slot.
pub(crate) fn truncate_at_eos(tokens: &mut Vec<i32>) {
    if let Some(p) = tokens.iter().position(|&t| t == EOS) {
        tokens.truncate(p + 1);
    }
}

/// Replica entry: build the engine, then run whichever decode
/// discipline it supports (continuous wants the split HLO pair; the
/// batch-level loop works against every artifact). Runs inside the
/// panic boundary of `spawn_replica`; in-flight requests live in
/// `ledger` until terminally answered.
fn serve_replica(
    id: usize,
    spec: &EngineSpec,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
    ledger: &Ledger,
    stats: &mut ServerStats,
    shared: &Arc<QosShared>,
) -> Result<()> {
    let mut engine = Engine::build(id, spec, opts)?;
    // §L11 canary gate: a rollout canary decodes the pinned probe set
    // and holds for the router's token-parity verdict before serving
    // any live traffic. Abandoned at the gate -> clean exit, zero
    // requests served (a bad version never answers a client).
    if shared.deploy.canary_id.load(Ordering::Acquire) == id
        && !deploy::canary_gate(&mut engine, opts, &shared.deploy)?
    {
        return Ok(());
    }
    if opts.continuous && engine.supports_continuous() {
        // §L8: speculation is strictly opt-in (spec_gamma > 0) and
        // runs at the engine's effective draft length (the requested γ
        // or the artifact's compiled fallback); anything missing falls
        // back to plain per-token decode.
        let gamma = engine.effective_spec_gamma(opts.spec_gamma);
        let spec_dec = (gamma > 0).then(|| SpecDecoder::new(gamma));
        serve_continuous(id, &mut engine, jobs, opts, ledger, stats, spec_dec, shared)
    } else {
        serve_batches(id, &mut engine, jobs, ledger, stats, &opts.tenants, shared)
    }
}

/// Non-blocking / blocking pop off the shared job queue.
enum Popped {
    Job(BatchJob),
    Empty,
    Gone,
}

fn pop_job(jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>, block: bool) -> Result<Popped> {
    // Hold the queue lock only for the pop; decode runs unlocked so
    // other replicas pull the next job meanwhile. (A blocking pop only
    // happens when this replica is idle.) A poisoned lock is recovered:
    // replicas panic inside engine calls, never while holding this
    // guard, and the receiver itself stays sound either way.
    if block {
        let queue = match jobs.lock() {
            Ok(q) => q,
            Err(poisoned) => poisoned.into_inner(),
        };
        // Bounded wait, not `recv()`: an idle replica must resurface at
        // the supervision cadence to notice cross-thread levers (the
        // §L11 targeted drain), so a timed-out wait is `Empty`, not
        // `Gone`.
        match queue.recv_timeout(SUPERVISE_TICK) {
            Ok(job) => Ok(Popped::Job(job)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(Popped::Empty),
            Err(mpsc::RecvTimeoutError::Disconnected) => Ok(Popped::Gone),
        }
    } else {
        // try_lock, not lock: an idle replica parks inside `recv`
        // holding the mutex, and a replica with live slots must keep
        // decoding rather than stall on that hold until the next job
        // arrives.
        let queue = match jobs.try_lock() {
            Ok(q) => q,
            Err(std::sync::TryLockError::WouldBlock) => return Ok(Popped::Empty),
            Err(std::sync::TryLockError::Poisoned(poisoned)) => poisoned.into_inner(),
        };
        match queue.try_recv() {
            Ok(job) => Ok(Popped::Job(job)),
            Err(mpsc::TryRecvError::Empty) => Ok(Popped::Empty),
            Err(mpsc::TryRecvError::Disconnected) => Ok(Popped::Gone),
        }
    }
}

/// Run-to-completion batch loop (§Perf L5, and the fallback when the
/// artifact ships no split HLO): pop bucket-homogeneous jobs, shed
/// expired requests, admit the rest into the in-flight ledger, pack at
/// the (effective) bucket geometry into a reused scratch buffer,
/// decode to full `dec_len`, and move each output row into its reply.
fn serve_batches(
    id: usize,
    engine: &mut Engine,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    ledger: &Ledger,
    stats: &mut ServerStats,
    tenants: &[TenantSpec],
    shared: &Arc<QosShared>,
) -> Result<()> {
    let (batch_size, _enc_len) = engine.dims();
    // Packing scratch reused across every batch on this hot path: the
    // fresh-allocation-per-batch version showed up in router/replica
    // profiles once decode itself got cheap.
    let mut enc_scratch: Vec<i32> = Vec::new();
    let mut trunc_scratch: Vec<bool> = Vec::new();
    loop {
        // §L11: a targeted rollout drain retires this replica between
        // batches (run-to-completion means no slots to let retire);
        // a probation canary publishes its health each pass.
        if shared.deploy.take_drain(id) {
            return Ok(());
        }
        if shared.deploy.canary_id.load(Ordering::Relaxed) == id {
            shared.deploy.publish_canary_health(stats);
        }
        let job = match pop_job(jobs, true)? {
            Popped::Job(job) => job,
            Popped::Empty => continue, // timed pop: re-check the levers
            Popped::Gone => break,     // router gone and queue drained
        };
        if is_scale_down(&job) {
            return Ok(()); // §L10 autoscale retirement: a clean exit
        }
        let bucket = engine.effective_bucket(job.bucket);
        let routed_bucket = job.bucket;
        // Admission: ledger entries survive a decode panic so the
        // supervisor can requeue them; expired requests are shed now
        // rather than padded into the batch.
        let now = Instant::now();
        let mut batch: Vec<(u64, Instant, usize)> = Vec::with_capacity(job.requests.len());
        for admitted in job.requests {
            let Admitted { req, attempts, .. } = admitted;
            if req.expired(now) {
                fail_request(stats, &req, FailReason::DeadlineExceeded, id);
                continue;
            }
            let t0 = req.t0;
            let enc_len = req.enc_tokens.len();
            let ticket = ledger.admit(routed_bucket, attempts, req);
            batch.push((ticket, t0, enc_len));
        }
        if batch.is_empty() {
            continue;
        }
        let fill = batch.len();
        {
            let tickets: Vec<u64> = batch.iter().map(|(t, _, _)| *t).collect();
            ledger.pack_rows(&tickets, batch_size, bucket, &mut enc_scratch, &mut trunc_scratch);
        }
        let decoded = engine.decode(&enc_scratch, bucket)?;
        let mut decoded = decoded.into_iter();
        for (i, (ticket, t0, enc_len)) in batch.into_iter().enumerate() {
            let Some(held) = ledger.take(ticket) else { continue };
            let latency = t0.elapsed();
            let mut tokens = decoded.next().unwrap_or_default();
            truncate_at_eos(&mut tokens);
            stats.note_response(
                latency,
                tokens.len(),
                0, // monolithic decode ran the full dec_len regardless
                enc_len.min(bucket),
                trunc_scratch[i],
            );
            stats.requests += 1;
            let slo_ms = tenants.get(held.req.tenant).map_or(0, |t| t.slo_ms);
            stats
                .tenant_mut(held.req.tenant)
                .note_done(latency.as_secs_f64() * 1e3, tokens.len(), slo_ms);
            stats.deploy.note_done(latency.as_secs_f64() * 1e3, tokens.len());
            let _ = held.req.reply.send(Response {
                tokens,
                latency,
                batch_fill: fill,
                truncated: trunc_scratch[i],
                bucket,
                replica: id,
                failure: None,
            });
        }
        stats.batches += 1;
        stats.total_fill += fill;
        stats.executed_tokens += batch_size * bucket;
    }
    Ok(())
}

/// A request waiting for a free decode slot (already in the ledger —
/// which also owns the prompt tokens; see `Ledger::pack_rows`).
struct Pend {
    ticket: u64,
    t0: Instant,
    deadline: Option<Instant>,
    enc_len: usize,
}

/// A request occupying a decode slot (already in the ledger).
struct Active {
    ticket: u64,
    t0: Instant,
    deadline: Option<Instant>,
    tokens: Vec<i32>,
    bucket: usize,
    fill: usize,
    truncated: bool,
    prompt_len: usize,
}

/// Unpack a router job into the replica's pending queue via the
/// in-flight ledger, shedding anything already past its deadline.
fn stash(
    ledger: &Ledger,
    pending: &mut VecDeque<(usize, Pend)>,
    job: BatchJob,
    stats: &mut ServerStats,
    id: usize,
) {
    let BatchJob { bucket, requests } = job;
    let now = Instant::now();
    for admitted in requests {
        let Admitted { req, attempts, .. } = admitted;
        if req.expired(now) {
            fail_request(stats, &req, FailReason::DeadlineExceeded, id);
            continue;
        }
        let t0 = req.t0;
        let deadline = req.deadline;
        let enc_len = req.enc_tokens.len();
        let ticket = ledger.admit(bucket, attempts, req);
        pending.push_back((bucket, Pend { ticket, t0, deadline, enc_len }));
    }
}

/// Slot-based continuous batching (§Perf L6): between fused
/// `decode_token` iterations the scheduler admits pending requests
/// into free slots (one batched prefill per same-bucket group),
/// retires slots the moment they emit EOS or hit `dec_len`, and —
/// §L7 — sheds expired pending requests and retires expired slots so
/// one stuck generation cannot hold a slot forever. With a
/// `SpecDecoder` (§L8) each decode iteration becomes a draft/verify
/// round delivering 1..=γ+1 tokens per live slot; admission,
/// deadlines, retirement, and drain are identical.
#[allow(clippy::too_many_arguments)]
fn serve_continuous(
    id: usize,
    engine: &mut Engine,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
    ledger: &Ledger,
    stats: &mut ServerStats,
    mut spec_dec: Option<SpecDecoder>,
    shared: &Arc<QosShared>,
) -> Result<()> {
    let (batch_size, enc_len) = engine.dims();
    let dec_len = engine.dec_len();
    let slots_n = if opts.slots > 0 { opts.slots } else { batch_size };
    // §L9: serve out of a page pool when the engine carries the paged
    // contract; otherwise monolithic per-slot state (the fallback —
    // token-for-token identical, pinned by tests/server.rs).
    let mut paged: Option<PoolServing> = engine.paged_geometry().map(
        |(page_size, pool_pages, prefix_cache)| PoolServing {
            pool: PagePool::new(page_size, pool_pages),
            tables: (0..slots_n).map(|_| PageTable::new()).collect(),
            cache: prefix_cache.then(PrefixCache::new),
            max_pages: pages_for(enc_len + dec_len, page_size),
        },
    );
    let mut state = match &paged {
        Some(ps) => {
            stats.pool.capacity = ps.pool.capacity();
            engine.init_slots_paged(slots_n, ps.pool.capacity())?
        }
        None => engine.init_slots(slots_n)?,
    };
    let all_slots: Vec<usize> = (0..slots_n).collect();
    let mut active: Vec<Option<Active>> = (0..slots_n).map(|_| None).collect();
    let mut pending: VecDeque<(usize, Pend)> = VecDeque::new();
    let mut router_gone = false;
    // §L10 autoscale retirement: once this replica pops the
    // scale-down sentinel it stops pulling work, finishes what it
    // holds, and exits cleanly.
    let mut retiring = false;
    // §L8 base draft length; the §L10 γ-cap lever can only shrink it.
    let base_gamma = spec_dec.as_ref().map_or(0, |sd| sd.gamma());
    let mut enc_scratch: Vec<i32> = Vec::new();
    let mut trunc_scratch: Vec<bool> = Vec::new();
    loop {
        let n_live = active.iter().filter(|s| s.is_some()).count();

        // §L11: a targeted rollout drain retires this replica exactly
        // like an autoscale retirement — stop pulling work, let the
        // in-flight slots finish naturally (releasing their §L9 pages),
        // exit cleanly. A probation canary publishes its live health
        // each iteration for the router's gates.
        if !retiring && shared.deploy.take_drain(id) {
            retiring = true;
        }
        if shared.deploy.canary_id.load(Ordering::Relaxed) == id {
            shared.deploy.publish_canary_health(stats);
        }

        // Pull new work: block when fully idle (nothing to decode),
        // poll otherwise so in-flight slots keep stepping.
        if !router_gone && !retiring {
            if n_live == 0 && pending.is_empty() {
                match pop_job(jobs, true)? {
                    Popped::Job(job) if is_scale_down(&job) => retiring = true,
                    Popped::Job(job) => stash(ledger, &mut pending, job, stats, id),
                    Popped::Empty => {} // timed pop: re-check the levers
                    Popped::Gone => router_gone = true,
                }
            }
            while pending.len() < slots_n && !router_gone && !retiring {
                match pop_job(jobs, false)? {
                    Popped::Job(job) if is_scale_down(&job) => retiring = true,
                    Popped::Job(job) => stash(ledger, &mut pending, job, stats, id),
                    Popped::Empty => break,
                    Popped::Gone => router_gone = true,
                }
            }
        }

        // §L10: apply the overload controller's current γ cap before
        // this iteration's draft/verify round.
        if let Some(sd) = spec_dec.as_mut() {
            let eff = base_gamma.min(shared.gamma_cap.load(Ordering::Relaxed)).max(1);
            if sd.gamma() != eff {
                sd.set_gamma(eff);
            }
        }

        // §L7 deadline pass, run between decode iterations (so a shed
        // costs at most one fused step of extra latency): drop expired
        // pending requests and retire expired slots with explicit
        // failures.
        let now = Instant::now();
        pending.retain(|(_, p)| {
            if p.deadline.is_some_and(|d| now >= d) {
                if let Some(held) = ledger.take(p.ticket) {
                    fail_request(stats, &held.req, FailReason::DeadlineExceeded, id);
                }
                false
            } else {
                true
            }
        });
        for slot in active.iter_mut() {
            let expired =
                slot.as_ref().is_some_and(|a| a.deadline.is_some_and(|d| now >= d));
            if expired {
                let act = slot.take().expect("expired slot");
                if let Some(held) = ledger.take(act.ticket) {
                    fail_request(stats, &held.req, FailReason::DeadlineExceeded, id);
                }
            }
        }

        // §L9: release retired slots' page tables before admission, so
        // pages freed by EOS/deadline retirement are allocatable this
        // pass. A released page drops to refcount 1 while the prefix
        // cache still holds it (evictable, reusable) and to 0 (free)
        // otherwise.
        if let Some(ps) = paged.as_mut() {
            for (s, slot) in active.iter().enumerate() {
                if slot.is_none() && !ps.tables[s].is_empty() {
                    ps.tables[s].release(&mut ps.pool)?;
                }
            }
        }

        // Admit pending requests into free slots, one batched prefill
        // per same-bucket run (bounded by the prefill geometry and —
        // §L9 — by page-pool capacity).
        let mut free: VecDeque<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect();
        let mut stalled = false;
        while !free.is_empty() && !pending.is_empty() && !stalled {
            let bucket = pending.front().expect("non-empty pending").0;
            let eff = if paged.is_some() {
                engine.effective_paged_prefill_bucket(bucket)
            } else {
                engine.effective_prefill_bucket(bucket)
            };
            let mut group: Vec<Pend> = Vec::new();
            let mut slot_ids: Vec<usize> = Vec::new();
            let mut group_saved = 0usize;
            while group.len() < batch_size.min(free.len() + group.len()) {
                let (ticket, cand_deadline) = match pending.front() {
                    Some((b, p)) if *b == bucket => (p.ticket, p.deadline),
                    _ => break,
                };
                // §L10 satellite (pre-expiry audit): a candidate can
                // expire *during this admission pass* — an earlier
                // group's prefill slept — so re-check against a fresh
                // clock before the §L9 pool gate spends prefix-cache
                // probes or page reservations on doomed work. The
                // monolithic arm shares the check for parity.
                if cand_deadline.is_some_and(|d| Instant::now() >= d) {
                    let (_, p) = pending.pop_front().expect("front present");
                    if let Some(held) = ledger.take(p.ticket) {
                        fail_request(stats, &held.req, FailReason::DeadlineExceeded, id);
                    }
                    continue;
                }
                if let Some(ps) = paged.as_mut() {
                    // §L9 pool gate: reserve this request's pages —
                    // shared prefix pages first, fresh pages for the
                    // uncovered prompt tail + decode room — before
                    // taking a slot.
                    let page_size = ps.pool.page_size();
                    let total = pages_for(eff + dec_len, page_size);
                    if total > ps.pool.capacity() {
                        // Can never fit, even with every page free:
                        // an explicit terminal failure, not an
                        // eternal stall.
                        let (_, p) = pending.pop_front().expect("front present");
                        if let Some(held) = ledger.take(p.ticket) {
                            fail_request(stats, &held.req, FailReason::PoolExhausted, id);
                        }
                        continue;
                    }
                    let hashes = match ps.cache.as_ref() {
                        Some(_) => ledger
                            .with_prompt(ticket, |toks| {
                                chunk_hashes(&toks[..toks.len().min(eff)], page_size)
                            })
                            .unwrap_or_default(),
                        None => Vec::new(),
                    };
                    let hits = ps.cache.as_ref().map_or(0, |c| c.match_len(&hashes));
                    let need = total - hits;
                    if let Some(cache) = ps.cache.as_mut() {
                        while ps.pool.free_pages() < need && cache.evict_lru(&mut ps.pool)? {
                            stats.pool.evictions += 1;
                        }
                    }
                    if ps.pool.free_pages() < need {
                        // Pool pressure with every unpinned cache page
                        // already evicted: wait for live slots to
                        // retire. The request stays pending (a stall,
                        // not a failure) — with zero live slots every
                        // cached page is evictable, so `total <=
                        // capacity` always unblocks eventually.
                        stats.pool.alloc_stalls += 1;
                        stalled = true;
                        break;
                    }
                    let (_, p) = pending.pop_front().expect("front present");
                    let sid = free.pop_front().expect("free slot");
                    let table = &mut ps.tables[sid];
                    for &h in &hashes[..hits] {
                        let page = ps
                            .cache
                            .as_mut()
                            .and_then(|c| c.hit(h))
                            .context("matched prefix chunk vanished")?;
                        table.push_shared(&mut ps.pool, page)?;
                    }
                    if !table.ensure(&mut ps.pool, total) {
                        bail!("page pool exhausted after its reservation check");
                    }
                    if let Some(cache) = ps.cache.as_mut() {
                        stats.pool.prefix_lookups += hashes.len() as u64;
                        stats.pool.prefix_hits += hits as u64;
                        // Publish this prompt's fresh chunks so later
                        // requests share them.
                        for k in hits..hashes.len() {
                            cache.insert(&mut ps.pool, hashes[k], table.pages()[k])?;
                        }
                    }
                    group_saved += hits * page_size;
                    slot_ids.push(sid);
                    group.push(p);
                } else {
                    let (_, p) = pending.pop_front().expect("front present");
                    slot_ids.push(free.pop_front().expect("free slot"));
                    group.push(p);
                }
            }
            if group.is_empty() {
                break; // no free capacity for this bucket run
            }
            {
                let tickets: Vec<u64> = group.iter().map(|p| p.ticket).collect();
                ledger.pack_rows(&tickets, group.len(), eff, &mut enc_scratch, &mut trunc_scratch);
            }
            match paged.as_ref() {
                Some(ps) => {
                    let flat = flatten_page_tables(&ps.tables, &slot_ids, ps.max_pages);
                    engine.prefill_paged(
                        &mut state,
                        &enc_scratch,
                        eff,
                        &slot_ids,
                        &flat,
                        group_saved,
                    )?;
                    stats.executed_tokens += group.len() * eff - group_saved;
                    stats.pool.prefill_tokens_saved += group_saved as u64;
                }
                None => {
                    engine.prefill(&mut state, &enc_scratch, eff, &slot_ids)?;
                    stats.executed_tokens += group.len() * eff;
                }
            }
            stats.prefills += 1;
            stats.batches += 1;
            stats.total_fill += group.len();
            for (i, p) in group.into_iter().enumerate() {
                let prompt_len = p.enc_len.min(eff);
                active[slot_ids[i]] = Some(Active {
                    ticket: p.ticket,
                    t0: p.t0,
                    deadline: p.deadline,
                    tokens: Vec::with_capacity(dec_len),
                    bucket: eff,
                    fill: slot_ids.len(),
                    truncated: trunc_scratch[i],
                    prompt_len,
                });
            }
        }

        let n_live = active.iter().filter(|s| s.is_some()).count();
        if n_live == 0 {
            if (router_gone || retiring) && pending.is_empty() {
                break; // drained (or §L10 autoscale retirement)
            }
            continue;
        }

        // One full-model decode iteration over the whole slot
        // geometry: a §L8 draft/verify round (1..=γ+1 tokens per live
        // slot) when speculating, else one fused `decode_token`. On
        // the §L9 paged path the step takes the flattened
        // (slots, max_pages) table and the pool meter samples
        // occupancy once per iteration.
        let live: Vec<bool> = active.iter().map(|s| s.is_some()).collect();
        let flat_table = paged.as_ref().map(|ps| {
            stats.pool.record(ps.pool.used_pages(), n_live);
            flatten_page_tables(&ps.tables, &all_slots, ps.max_pages)
        });
        if let Some(sd) = spec_dec.as_mut() {
            let emissions =
                sd.round(engine, &mut state, &live, flat_table.as_deref(), &mut stats.spec)?;
            stats.decode_steps += 1;
            stats.occupancy.record(n_live);
            for (s, slot) in active.iter_mut().enumerate() {
                let Some(act) = slot.as_mut() else { continue };
                // Push the round's tokens in stream order, truncating
                // at EOS / dec_len exactly like plain decode — tokens
                // the verify accepted past a retirement point are
                // discarded, never delivered.
                let mut pushed = 0u64;
                let mut done = false;
                for &tok in &emissions[s] {
                    act.tokens.push(tok);
                    pushed += 1;
                    if tok == EOS || act.tokens.len() >= dec_len {
                        done = true;
                        break;
                    }
                }
                // The meter's delivered-tokens half is the serving
                // loop's to report: only it knows the truncation.
                stats.spec.note_delivered(pushed);
                if done {
                    finish_slot(slot, ledger, stats, dec_len, id, router_gone, &opts.tenants);
                }
            }
        } else {
            let tokens = match flat_table.as_deref() {
                Some(flat) => engine.decode_token_paged(&mut state, &live, flat)?,
                None => engine.decode_token(&mut state, &live)?,
            };
            stats.decode_steps += 1;
            stats.occupancy.record(n_live);
            for (s, slot) in active.iter_mut().enumerate() {
                let Some(act) = slot.as_mut() else { continue };
                act.tokens.push(tokens[s]);
                if tokens[s] == EOS || act.tokens.len() >= dec_len {
                    finish_slot(slot, ledger, stats, dec_len, id, router_gone, &opts.tenants);
                }
            }
        }
    }
    Ok(())
}

/// Retire a finished slot: move its request out of the ledger, record
/// the response bookkeeping, and send the terminal token response.
/// Shared by the plain and §L8 speculative decode paths — retirement
/// semantics (early-exit accounting, drain counting, ledger removal)
/// must not depend on which path generated the tokens.
#[allow(clippy::too_many_arguments)]
fn finish_slot(
    slot: &mut Option<Active>,
    ledger: &Ledger,
    stats: &mut ServerStats,
    dec_len: usize,
    id: usize,
    router_gone: bool,
    tenants: &[TenantSpec],
) {
    let Some(act) = slot.take() else { return };
    let Some(held) = ledger.take(act.ticket) else { return };
    let latency = act.t0.elapsed();
    stats.note_response(
        latency,
        act.tokens.len(),
        dec_len - act.tokens.len(), // early-exit savings
        act.prompt_len,
        act.truncated,
    );
    stats.requests += 1;
    let slo_ms = tenants.get(held.req.tenant).map_or(0, |t| t.slo_ms);
    stats
        .tenant_mut(held.req.tenant)
        .note_done(latency.as_secs_f64() * 1e3, act.tokens.len(), slo_ms);
    stats.deploy.note_done(latency.as_secs_f64() * 1e3, act.tokens.len());
    if router_gone {
        stats.drained += 1;
    }
    let _ = held.req.reply.send(Response {
        tokens: act.tokens,
        latency,
        batch_fill: act.fill,
        truncated: act.truncated,
        bucket: act.bucket,
        replica: id,
        failure: None,
    });
}

/// Pack request token rows into a fixed (batch_size, len) geometry:
/// short rows are zero-padded, long rows are cut to fit. `len` is the
/// full `enc_len` or any smaller bucket the group was routed to.
/// Returns the flat batch plus a per-row truncation flag.
pub fn pack_requests(
    rows: &[&[i32]],
    batch_size: usize,
    len: usize,
) -> (Vec<i32>, Vec<bool>) {
    let mut enc = Vec::new();
    let mut truncated = Vec::new();
    pack_requests_into(rows, batch_size, len, &mut enc, &mut truncated);
    (enc, truncated)
}

/// `pack_requests` into caller-provided scratch buffers, so the
/// replica hot loop reuses one allocation across every batch instead
/// of building a fresh padded matrix per job. The scratch is cleared
/// and zero-filled to the new geometry on every call — no stale tokens
/// survive a reuse at a different shape.
pub fn pack_requests_into(
    rows: &[&[i32]],
    batch_size: usize,
    len: usize,
    enc: &mut Vec<i32>,
    truncated: &mut Vec<bool>,
) {
    enc.clear();
    enc.resize(batch_size * len, 0);
    truncated.clear();
    truncated.resize(rows.len(), false);
    for (i, row) in rows.iter().take(batch_size).enumerate() {
        let n = row.len().min(len);
        enc[i * len..i * len + n].copy_from_slice(&row[..n]);
        truncated[i] = row.len() > len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_spec() -> SimSpec {
        SimSpec {
            batch_size: 2,
            enc_len: 32,
            dec_len: 6,
            vocab_size: 97,
            token_ns: 0,
            dtoken_ns: 0,
            dstep_ns: 0,
            split_decode: true,
            draft: Some(SimDraftSpec { dtoken_ns: 0, dstep_ns: 0, accept_rate: 0.75 }),
            pool: None,
            fault: FaultSpec::default(),
            bad_token_salt: 0,
            bad_panic: false,
        }
    }

    /// §L10: a chaos schedule composes onto a sim spec — first kill on
    /// the legacy single-kill fields, the rest on `extra_kills`, stuck
    /// class passed through, pool pressure floored at one slot's pages.
    #[test]
    fn chaos_spec_composes_onto_sim_spec() {
        let mut spec = quiet_spec();
        spec.pool = Some(SimPoolSpec { page_size: 8, pool_pages: 100, prefix_cache: false });
        let chaos = ChaosSpec {
            kills: vec![(1, 5), (2, 9)],
            stuck_every: 7,
            stuck_step_ns: 11,
            pool_reserve: 0.25,
        };
        chaos.apply(&mut spec);
        assert_eq!(spec.fault.kill_replica, Some(1));
        assert_eq!(spec.fault.kill_after_calls, 5);
        assert_eq!(spec.fault.extra_kills, vec![(2, 9)]);
        assert_eq!(spec.fault.stuck_every, 7);
        assert_eq!(spec.fault.stuck_step_ns, 11);
        assert_eq!(spec.pool.as_ref().unwrap().pool_pages, 75, "25% withheld");
        // Extreme pressure still leaves one slot's worth of pages.
        let mut spec = quiet_spec();
        spec.pool = Some(SimPoolSpec { page_size: 8, pool_pages: 100, prefix_cache: false });
        ChaosSpec { pool_reserve: 1.0, ..ChaosSpec::default() }.apply(&mut spec);
        let floor = pages_for(spec.enc_len + spec.dec_len, 8);
        assert_eq!(spec.pool.as_ref().unwrap().pool_pages, floor);
        // An empty schedule is the identity.
        let mut spec = quiet_spec();
        ChaosSpec::default().apply(&mut spec);
        assert_eq!(spec.fault.kill_replica, None);
        assert!(spec.fault.extra_kills.is_empty());
    }

    /// §L10 satellite: the respawn backoff doubles per consecutive
    /// crash with jitter bounded to [0.75, 1.25) of nominal, so delay
    /// ranges for successive crashes never overlap.
    #[test]
    fn respawn_backoff_grows_exponentially_with_bounded_jitter() {
        let (_job_tx, job_rx) = mpsc::sync_channel::<BatchJob>(1);
        let (events_tx, _events_rx) = mpsc::channel();
        let mut sup = Supervisor {
            specs: BTreeMap::from([(0u32, EngineSpec::Sim(quiet_spec()))]),
            decided: 0,
            versions: HashMap::from([(0usize, 0u32)]),
            opts: ServerOptions { restart_backoff_ms: 40, seed: 7, ..ServerOptions::default() },
            jobs: Arc::new(Mutex::new(job_rx)),
            events_tx,
            handles: Vec::new(),
            live: 1,
            restarts_left: 3,
            next_id: 1,
            last_error: None,
            died: None,
            pending_respawns: Vec::new(),
            crashes: 0,
            shared: Arc::new(QosShared::new()),
        };
        let mut prev = 0u64;
        for c in 0..4u32 {
            sup.crashes = c;
            let d = sup.backoff_delay().as_millis() as u64;
            let nominal = 40u64 << c;
            assert!(
                d >= nominal - nominal / 4 && d <= nominal + nominal / 2,
                "crash {c}: delay {d} outside jitter band of nominal {nominal}"
            );
            assert!(d > prev, "crash {c}: backoff must grow ({d} <= {prev})");
            prev = d;
        }
        // The exponent saturates instead of overflowing the shift.
        sup.crashes = u32::MAX;
        assert!(sup.backoff_delay() <= Duration::from_millis(40 * 64 * 2));
    }

    #[test]
    fn pack_requests_pads_and_flags_truncation() {
        let short = vec![1, 2, 3];
        let exact = vec![5, 6, 7, 8];
        let long = vec![9, 10, 11, 12, 13, 14];
        let rows: Vec<&[i32]> = vec![&short, &exact, &long];
        let (enc, truncated) = pack_requests(&rows, 4, 4);
        assert_eq!(enc.len(), 16);
        assert_eq!(&enc[0..4], &[1, 2, 3, 0], "short row zero-padded");
        assert_eq!(&enc[4..8], &[5, 6, 7, 8], "exact row untouched");
        assert_eq!(&enc[8..12], &[9, 10, 11, 12], "long row cut to enc_len");
        assert_eq!(&enc[12..16], &[0, 0, 0, 0], "unfilled slot stays zero");
        assert_eq!(truncated, vec![false, false, true]);
    }

    #[test]
    fn pack_requests_empty_and_full() {
        let (enc, truncated) = pack_requests(&[], 2, 3);
        assert_eq!(enc, vec![0; 6]);
        assert!(truncated.is_empty());
        let a = vec![1i32; 3];
        let b = vec![2i32; 4];
        let rows: Vec<&[i32]> = vec![&a, &b];
        let (enc, truncated) = pack_requests(&rows, 2, 3);
        assert_eq!(&enc[3..6], &[2, 2, 2]);
        assert_eq!(truncated, vec![false, true]);
    }

    #[test]
    fn pack_requests_at_smaller_bucket() {
        let a = vec![1, 2, 3];
        let rows: Vec<&[i32]> = vec![&a];
        let (enc, truncated) = pack_requests(&rows, 2, 8);
        assert_eq!(enc.len(), 16, "bucket stride, not enc_len stride");
        assert_eq!(&enc[0..4], &[1, 2, 3, 0]);
        assert_eq!(truncated, vec![false]);
    }

    /// Reusing one scratch across geometry changes must behave exactly
    /// like a fresh allocation: no stale tokens from a previous (and
    /// larger) batch may leak into the next packing.
    #[test]
    fn pack_scratch_reuse_leaves_no_stale_data() {
        let mut enc = Vec::new();
        let mut trunc = Vec::new();
        let big = vec![7i32; 8];
        let rows: Vec<&[i32]> = vec![&big, &big, &big];
        pack_requests_into(&rows, 3, 8, &mut enc, &mut trunc);
        assert_eq!(enc.len(), 24);
        assert!(enc.iter().all(|&t| t == 7));

        let small = vec![1i32, 2];
        let rows: Vec<&[i32]> = vec![&small];
        pack_requests_into(&rows, 2, 4, &mut enc, &mut trunc);
        let (fresh, fresh_trunc) = pack_requests(&rows, 2, 4);
        assert_eq!(enc, fresh, "reused scratch == fresh allocation");
        assert_eq!(trunc, fresh_trunc);
        assert_eq!(&enc[2..8], &[0, 0, 0, 0, 0, 0], "old 7s cleared");
        // Growing again after shrinking also matches.
        let rows: Vec<&[i32]> = vec![&big];
        pack_requests_into(&rows, 2, 8, &mut enc, &mut trunc);
        assert_eq!(enc, pack_requests(&rows, 2, 8).0);
    }

    #[test]
    fn sim_decode_is_bucket_invariant_and_deterministic() {
        let spec = quiet_spec();
        let prompt: Vec<i32> = vec![4, 9, 1, 7];
        let pad_to = |len: usize| {
            let mut v = prompt.clone();
            v.resize(len, 0);
            v
        };
        let mut small = pad_to(8);
        small.extend(pad_to(8));
        let mut full = pad_to(32);
        full.extend(pad_to(32));
        let a = sim_decode(&spec, &small, 8);
        let b = sim_decode(&spec, &full, 32);
        assert_eq!(a, b, "output depends only on the unpadded prompt");
        assert!(!a[0].is_empty() && a[0].len() <= spec.dec_len);
        assert_eq!(*a[0].last().unwrap(), EOS, "rows end at their sampled EOS");
        assert!(a[0][..a[0].len() - 1]
            .iter()
            .all(|&t| t >= 2 && (t as usize) < 97), "non-final tokens stay off PAD/EOS");
        // Different prompts decode differently (not a constant).
        let mut other = vec![5i32, 5, 5, 0, 0, 0, 0, 0];
        other.extend(pad_to(8));
        assert_ne!(sim_decode(&spec, &other, 8)[0], a[0]);
    }

    /// The slot-based stream must equal the monolithic row token for
    /// token: prefill one row, step `decode_token` to EOS, compare.
    #[test]
    fn sim_slot_stream_matches_monolithic_rows() {
        let spec = quiet_spec();
        let mut engine = Engine::Sim(SimEngine::new(spec.clone(), 0));
        let mut state = engine.init_slots(3).unwrap();
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        engine.prefill(&mut state, &prompt, 8, &[1]).unwrap();
        let mut live = vec![false, true, false];
        let mut stream = Vec::new();
        for _ in 0..spec.dec_len {
            let toks = engine.decode_token(&mut state, &live).unwrap();
            stream.push(toks[1]);
            if toks[1] == EOS {
                live[1] = false;
                break;
            }
        }
        let mut batch = prompt.clone();
        batch.extend(vec![0i32; 8]);
        let rows = sim_decode(&spec, &batch, 8);
        assert_eq!(stream, rows[0], "per-token stream == monolithic row");
        assert_eq!(*stream.last().unwrap(), EOS);
    }

    /// Stuck-generation injection: a stuck row never emits EOS, runs
    /// the full dec_len on both decode paths, and produces identical
    /// tokens on both.
    #[test]
    fn sim_stuck_rows_never_emit_eos_on_either_path() {
        let mut spec = quiet_spec();
        spec.fault.stuck_every = 1; // every prompt is stuck
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        let mut batch = prompt.clone();
        batch.extend(vec![0i32; 8]);
        let rows = sim_decode(&spec, &batch, 8);
        assert_eq!(rows[0].len(), spec.dec_len, "stuck row runs the full dec_len");
        assert!(!rows[0].contains(&EOS), "stuck row never emits EOS");

        let mut engine = Engine::Sim(SimEngine::new(spec.clone(), 0));
        let mut state = engine.init_slots(2).unwrap();
        engine.prefill(&mut state, &prompt, 8, &[0]).unwrap();
        let live = vec![true, false];
        let mut stream = Vec::new();
        for _ in 0..spec.dec_len {
            stream.push(engine.decode_token(&mut state, &live).unwrap()[0]);
        }
        assert_eq!(stream, rows[0], "slot stream == monolithic stuck row");
    }

    /// §L8 core invariant at the round level: driving the sim engine
    /// through `SpecDecoder` rounds yields exactly the plain
    /// `decode_token` stream, at every acceptance rate — reject-all,
    /// mixed, and accept-all.
    #[test]
    fn sim_spec_rounds_match_plain_stream() {
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        let plain = {
            let spec = quiet_spec();
            let mut engine = Engine::Sim(SimEngine::new(spec.clone(), 0));
            let mut state = engine.init_slots(2).unwrap();
            engine.prefill(&mut state, &prompt, 8, &[0]).unwrap();
            let live = vec![true, false];
            let mut stream = Vec::new();
            for _ in 0..spec.dec_len {
                let t = engine.decode_token(&mut state, &live).unwrap()[0];
                stream.push(t);
                if t == EOS {
                    break;
                }
            }
            stream
        };
        assert_eq!(*plain.last().unwrap(), EOS);

        for rate in [0.0, 0.5, 1.0] {
            let mut spec = quiet_spec();
            spec.draft.as_mut().unwrap().accept_rate = rate;
            let dec_len = spec.dec_len;
            let mut engine = Engine::Sim(SimEngine::new(spec, 0));
            let mut state = engine.init_slots(2).unwrap();
            engine.prefill(&mut state, &prompt, 8, &[0]).unwrap();
            let mut sd = SpecDecoder::new(3);
            let mut meter = SpecMeter::default();
            let live = vec![true, false];
            let mut stream = Vec::new();
            'rounds: for _ in 0..dec_len {
                let em = sd.round(&mut engine, &mut state, &live, None, &mut meter).unwrap();
                assert!(em[1].is_empty(), "dead slot must emit nothing");
                assert!(!em[0].is_empty() && em[0].len() <= 3 + 1);
                for &t in &em[0] {
                    stream.push(t);
                    if t == EOS || stream.len() >= dec_len {
                        break 'rounds;
                    }
                }
            }
            assert_eq!(stream, plain, "spec stream != plain stream at rate {rate}");
            assert!(meter.verify_steps > 0 && meter.draft_steps == 3 * meter.verify_steps);
            assert_eq!(meter.drafted, 3 * meter.verify_steps);
            if rate == 0.0 {
                assert_eq!(meter.accepted, 0, "reject-all accepts nothing");
            }
            if rate == 1.0 {
                assert!(
                    (meter.acceptance_rate() - 1.0).abs() < 1e-12,
                    "accept-all accepts everything"
                );
            }
        }
    }

    /// §L8 acceptance sampling: exact at the extremes, bounded and
    /// deterministic in between, with a mean near the geometric-run
    /// expectation.
    #[test]
    fn sim_accept_len_sampling() {
        for pos in 0..20 {
            assert_eq!(sim_accept_len(0x1234, pos, 4, 1.0), 4, "rate 1.0 accepts all");
            assert_eq!(sim_accept_len(0x1234, pos, 4, 0.0), 0, "rate 0.0 rejects all");
        }
        assert_eq!(sim_accept_len(7, 3, 0, 1.0), 0, "gamma 0 accepts nothing");
        let mut seen = std::collections::BTreeSet::new();
        for pos in 0..200 {
            let a = sim_accept_len(0xABCDE, pos, 4, 0.75);
            assert!(a <= 4);
            assert_eq!(a, sim_accept_len(0xABCDE, pos, 4, 0.75), "deterministic");
            seen.insert(a);
        }
        assert!(seen.len() >= 3, "acceptance lengths too concentrated: {seen:?}");
        // Mean near α(1-α^γ)/(1-α) = 0.75(1-0.75^4)/0.25 ≈ 2.05.
        let total: usize = (0..2000).map(|p| sim_accept_len(0x5EED, p, 4, 0.75)).sum();
        let mean = total as f64 / 2000.0;
        assert!((1.6..=2.5).contains(&mean), "mean accept length {mean}");
    }

    /// §L9 capability detection: the sim opts in through its pool
    /// spec, and the flattened page-table operand lays out row-major
    /// with -1 in unmapped entries.
    #[test]
    fn paged_geometry_and_flatten_layout() {
        let mut spec = quiet_spec();
        spec.pool = Some(SimPoolSpec { page_size: 4, pool_pages: 12, prefix_cache: true });
        let engine = Engine::Sim(SimEngine::new(spec, 0));
        assert_eq!(engine.paged_geometry(), Some((4, 12, true)));
        let none = Engine::Sim(SimEngine::new(quiet_spec(), 0));
        assert_eq!(none.paged_geometry(), None, "no pool spec: monolithic fallback");

        let mut pool = PagePool::new(4, 8);
        let mut t0 = PageTable::new();
        assert!(t0.ensure(&mut pool, 2));
        let mut t1 = PageTable::new();
        assert!(t1.ensure(&mut pool, 1));
        let flat = flatten_page_tables(&[t0, t1], &[0, 1], 3);
        assert_eq!(flat, vec![0, 1, -1, 2, -1, -1]);
        let pool_dim = pool.capacity();
        assert!(flat.iter().all(|&p| p == -1 || (p as usize) < pool_dim));
    }

    /// §L9 sim parity at the engine level: the paged prefill (with
    /// prefix-covered tokens skipped) and paged decode steps emit the
    /// exact stream of the monolithic path — saved work never changes
    /// tokens.
    #[test]
    fn sim_paged_prefill_stream_matches_monolithic() {
        let spec = quiet_spec();
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        let run = |paged: bool| {
            let mut engine = Engine::Sim(SimEngine::new(spec.clone(), 0));
            let mut state = engine.init_slots(2).unwrap();
            if paged {
                // 4 of the 8 prompt tokens covered by prefix hits.
                engine.prefill_paged(&mut state, &prompt, 8, &[0], &[0, 1, 2], 4).unwrap();
            } else {
                engine.prefill(&mut state, &prompt, 8, &[0]).unwrap();
            }
            let live = vec![true, false];
            let mut stream = Vec::new();
            for _ in 0..spec.dec_len {
                let t = if paged {
                    engine.decode_token_paged(&mut state, &live, &[0, 1, 2]).unwrap()[0]
                } else {
                    engine.decode_token(&mut state, &live).unwrap()[0]
                };
                stream.push(t);
                if t == EOS {
                    break;
                }
            }
            stream
        };
        assert_eq!(run(true), run(false), "paged stream == monolithic stream");
    }

    /// §L8 capability detection + the no-draft error paths.
    #[test]
    fn engine_spec_support_requires_draft() {
        let with = Engine::Sim(SimEngine::new(quiet_spec(), 0));
        assert_eq!(with.effective_spec_gamma(4), 4);
        assert_eq!(with.effective_spec_gamma(0), 0, "gamma 0 never speculates");

        let mut spec = quiet_spec();
        spec.draft = None;
        let mut without = Engine::Sim(SimEngine::new(spec, 0));
        assert_eq!(without.effective_spec_gamma(4), 0);
        let mut state = without.init_slots(1).unwrap();
        assert!(without.draft_tokens(&mut state, &[false], 2).is_err());
        assert!(without.verify(&mut state, &[Vec::new()], &[false], 2).is_err());
    }

    /// §L8 γ resolution on the real backend: the requested γ when its
    /// verify HLO exists, the artifact's compiled `DraftSpec::gamma`
    /// as the fallback, and 0 (plain decode) without a draft session.
    #[test]
    fn real_engine_spec_gamma_resolution() {
        use crate::runtime::artifact::DraftSpec;
        use crate::runtime::params::tests::toy_artifact;
        let client = Client::cpu().unwrap();
        let mut a = toy_artifact();
        a.hlo_files.push(("verify@4".into(), std::path::PathBuf::from("/nonexistent")));
        a.draft = Some(DraftSpec { artifact: "toy-lite".into(), gamma: 4 });
        let session = Session::open_eval(&client, a, 0).unwrap();
        let dsession = Session::open_eval(&client, toy_artifact(), 0).unwrap();
        let engine = Engine::Real { client, session, draft: Some(dsession) };
        assert_eq!(engine.effective_spec_gamma(4), 4, "exact verify@4 HLO wins");
        assert_eq!(
            engine.effective_spec_gamma(2),
            4,
            "no verify@2: falls back to the artifact's compiled gamma"
        );
        assert_eq!(engine.effective_spec_gamma(0), 0, "speculation stays opt-in");
        let Engine::Real { client, session, .. } = engine else { unreachable!() };
        let engine = Engine::Real { client, session, draft: None };
        assert_eq!(engine.effective_spec_gamma(4), 0, "no draft session: plain decode");
    }

    /// The deterministic kill fault must fire as a panic on exactly the
    /// configured engine call, and only on the configured replica id.
    #[test]
    fn sim_kill_fault_panics_on_configured_call() {
        let mut spec = quiet_spec();
        spec.fault.kill_replica = Some(3);
        spec.fault.kill_after_calls = 2;
        let run = |replica: usize| {
            catch_unwind(AssertUnwindSafe(|| {
                let mut engine = Engine::Sim(SimEngine::new(spec.clone(), replica));
                let mut state = engine.init_slots(1).unwrap();
                let prompt = vec![9i32, 2, 4, 0];
                engine.prefill(&mut state, &prompt, 4, &[0]).unwrap(); // call 1
                engine.decode_token(&mut state, &[true]).unwrap(); // call 2
            }))
        };
        assert!(run(0).is_ok(), "non-matching replica id serves cleanly");
        assert!(run(3).is_err(), "matching replica id panics at call 2");
    }

    /// The in-flight ledger: admit/take/drain, and drain returns
    /// exactly what was never taken (the crash-recovery contract).
    #[test]
    fn ledger_tracks_in_flight_requests() {
        let ledger = Ledger::new();
        let (tx, _rx) = mpsc::channel();
        let t1 = ledger.admit(8, 0, Request::new(vec![1, 2], tx.clone()));
        let t2 = ledger.admit(16, 1, Request::new(vec![3], tx.clone()));
        let t3 = ledger.admit(8, 0, Request::new(vec![4, 5, 6], tx));
        assert_ne!(t1, t2);
        let held = ledger.take(t2).expect("present");
        assert_eq!(held.bucket, 16);
        assert_eq!(held.attempts, 1);
        assert_eq!(held.req.enc_tokens, vec![3]);
        assert!(ledger.take(t2).is_none(), "take is exactly-once");
        let mut rest = ledger.drain();
        rest.sort_by_key(|h| h.req.enc_tokens.len());
        assert_eq!(rest.len(), 2);
        assert_eq!(rest[0].req.enc_tokens, vec![1, 2]);
        assert_eq!(rest[1].req.enc_tokens, vec![4, 5, 6]);
        let _ = t3;
        assert!(ledger.drain().is_empty(), "drain empties the ledger");
    }

    /// Explicit failure responses: terminal, empty, reasoned, counted.
    #[test]
    fn fail_request_sends_terminal_response_and_counts() {
        let mut stats = ServerStats::default();
        let (tx, rx) = mpsc::channel();
        let req = Request::new(vec![1, 2, 3], tx);
        fail_request(&mut stats, &req, FailReason::DeadlineExceeded, ROUTER_ID);
        let resp = rx.recv().expect("terminal response delivered");
        assert!(resp.is_failure());
        assert_eq!(resp.failure, Some(FailReason::DeadlineExceeded));
        assert!(resp.tokens.is_empty());
        assert_eq!(resp.replica, ROUTER_ID);
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.sheds, 1);

        // Non-deadline failures count in failed but not sheds.
        let (tx, rx) = mpsc::channel();
        let req = Request::new(vec![7], tx);
        fail_request(&mut stats, &req, FailReason::RetriesExhausted, ROUTER_ID);
        assert_eq!(rx.recv().unwrap().failure, Some(FailReason::RetriesExhausted));
        assert_eq!(stats.failed, 2);
        assert_eq!(stats.sheds, 1);
        // §L10 admission rejections are sheds too, and land on the
        // per-tenant meter of the request's tenant.
        let (tx, rx) = mpsc::channel();
        let req = Request::for_tenant(vec![8], tx, 1, 0);
        fail_request(&mut stats, &req, FailReason::QueueFull, ROUTER_ID);
        assert_eq!(rx.recv().unwrap().failure, Some(FailReason::QueueFull));
        assert_eq!(stats.failed, 3);
        assert_eq!(stats.sheds, 2);
        assert_eq!(stats.tenants[1].failed, 1);
        assert_eq!(stats.tenants[1].sheds, 1);
        // Every reason renders a non-empty human message.
        for reason in [
            FailReason::DeadlineExceeded,
            FailReason::RetriesExhausted,
            FailReason::NoReplicas,
            FailReason::AbortedOnDrain,
            FailReason::PoolExhausted,
            FailReason::QueueFull,
            FailReason::WouldMissDeadline,
        ] {
            assert!(!reason.to_string().is_empty());
        }
    }

    #[test]
    fn request_deadline_expiry() {
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        let req = Request::with_deadline(vec![1], tx.clone(), now + Duration::from_secs(60));
        assert!(!req.expired(now));
        assert!(req.expired(now + Duration::from_secs(61)));
        let no_deadline = Request::new(vec![1], tx);
        assert!(!no_deadline.expired(now + Duration::from_secs(3600)));
    }

    #[test]
    fn sim_gen_lengths_cover_the_range() {
        // EOS-distributed lengths: over many prompts the sampled
        // generation lengths must span [1, dec_len], not collapse.
        let dec_len = 8;
        let mut seen = std::collections::BTreeSet::new();
        for p in 0..200u64 {
            let h = sim_row_hash(&[(p as i32) + 1, 7, 9]);
            let g = sim_gen_len(h, dec_len);
            assert!((1..=dec_len).contains(&g));
            seen.insert(g);
        }
        assert!(seen.len() >= dec_len / 2, "lengths too concentrated: {seen:?}");
    }

    #[test]
    fn truncate_at_eos_is_inclusive_and_idempotent() {
        let mut row = vec![5, 9, EOS, 7, 8];
        truncate_at_eos(&mut row);
        assert_eq!(row, vec![5, 9, EOS]);
        truncate_at_eos(&mut row);
        assert_eq!(row, vec![5, 9, EOS]);
        let mut none = vec![5, 9, 7];
        truncate_at_eos(&mut none);
        assert_eq!(none, vec![5, 9, 7], "no EOS: row untouched");
    }

    #[test]
    fn server_stats_merge_waste_and_percentiles() {
        let mut a = ServerStats {
            requests: 4,
            batches: 2,
            total_fill: 4,
            replicas: 1,
            prompt_tokens: 40,
            executed_tokens: 64,
            truncated: 1,
            ..Default::default()
        };
        for ms in [1.0, 2.0, 3.0, 4.0] {
            a.latency.record(ms);
        }
        let mut b = ServerStats {
            requests: 2,
            batches: 1,
            total_fill: 2,
            replicas: 1,
            prompt_tokens: 10,
            executed_tokens: 36,
            truncated: 0,
            tokens_generated: 30,
            tokens_saved: 10,
            decode_steps: 5,
            prefills: 2,
            sheds: 1,
            retries: 2,
            restarts: 1,
            failed: 3,
            drained: 4,
            ..Default::default()
        };
        b.latency.record(10.0);
        b.latency.record(20.0);
        b.occupancy.record(4);
        a.merge(&b);
        assert_eq!(a.requests, 6);
        assert_eq!(a.batches, 3);
        assert_eq!(a.replicas, 2);
        assert_eq!(a.truncated, 1);
        assert_eq!(a.tokens_generated, 30);
        assert_eq!(a.tokens_saved, 10);
        assert_eq!(a.decode_steps, 5);
        assert_eq!(a.prefills, 2);
        assert_eq!(a.sheds, 1);
        assert_eq!(a.retries, 2);
        assert_eq!(a.restarts, 1);
        assert_eq!(a.failed, 3);
        assert_eq!(a.drained, 4);
        assert!(a.summary().contains("faults:"), "fault counters surface in the summary");
        assert!((a.early_exit_ratio() - 0.25).abs() < 1e-12);
        assert_eq!(a.occupancy.steps(), 1);
        assert_eq!(a.latency_count(), 6);
        assert!((a.waste_ratio() - 0.5).abs() < 1e-12, "50/100 executed tokens were padding");
        // Log-bucketed estimates: within the histogram's ~9% error.
        let p50 = a.p50_ms();
        assert!((p50 - 3.0).abs() / 3.0 < 0.10, "p50={p50}");
        let p100 = a.latency_percentile_ms(100.0);
        assert!((p100 - 20.0).abs() / 20.0 < 0.10, "p100={p100}");
        assert_eq!(ServerStats::default().waste_ratio(), 0.0);
        assert_eq!(ServerStats::default().p99_ms(), 0.0);
        assert_eq!(ServerStats::default().early_exit_ratio(), 0.0);
        assert!(
            !ServerStats::default().summary().contains("faults:"),
            "fault-free summary stays compact"
        );
    }

    #[test]
    fn note_response_accounting() {
        let mut s = ServerStats::default();
        s.note_response(Duration::from_millis(10), 5, 3, 7, true);
        assert_eq!(s.tokens_generated, 5);
        assert_eq!(s.tokens_saved, 3);
        assert_eq!(s.prompt_tokens, 7);
        assert_eq!(s.truncated, 1);
        assert_eq!(s.latency_count(), 1);
        assert_eq!(s.token_latency.count(), 1);
        let per_tok = s.token_ms();
        assert!((per_tok - 2.0).abs() / 2.0 < 0.10, "10ms/5tok ~ 2ms: {per_tok}");
        // Zero generated tokens must not divide by zero.
        s.note_response(Duration::from_millis(1), 0, 0, 0, false);
        assert_eq!(s.token_latency.count(), 2);
    }
}
