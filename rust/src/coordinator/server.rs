//! Multi-replica inference server: shape-bucketed batching (§Perf L5)
//! plus slot-based **continuous batching** (§Perf L6).
//!
//! The PJRT session is !Send (Rc-backed FFI handles), so each replica
//! owns its client + session on a dedicated model thread. A router
//! thread admits requests continuously, groups them by sequence-length
//! bucket (`runtime::session::bucket_for`), and emits full-or-expired
//! batches onto a shared job queue; the first replica with capacity
//! picks each job up.
//!
//! Replicas run one of two decode disciplines:
//!
//! - **Continuous (default, §Perf L6):** the replica owns `S` decode
//!   slots, each holding a request's device-resident KV-cache buffers
//!   (`Session::init_decode_slots` — the same PJRT-residency pattern
//!   as the §Perf L4 param cache). Between decode iterations the slot
//!   scheduler admits pending requests into free slots (one
//!   `prefill@<bucket>` per same-bucket admission group), runs one
//!   fused `decode_token` over every live slot, and retires slots the
//!   moment they emit EOS or hit `dec_len` — short generations stop
//!   paying for long ones, and new requests enter mid-flight instead
//!   of waiting for a whole batch to finish. Requires the artifact to
//!   ship the split HLO pair (`Session::has_split_decode`).
//! - **Batch-level (fallback / `ALTUP_NO_CONT_BATCH=1`):** the §Perf
//!   L5 run-to-completion loop over the monolithic `decode_step`.
//!   Replicas fall back automatically when the artifact has no split
//!   HLO, so the server works against every artifact either way.
//!
//! Backends: `EngineSpec::Artifact` serves a compiled artifact through
//! a warmed device cache (§Perf L4); `EngineSpec::Sim` is a
//! deterministic backend-free decode with a per-token cost model and
//! hash-sampled EOS lengths, so the slot scheduler, bucketing, and
//! replica machinery can be exercised and benchmarked without linking
//! the real xla-rs bindings. Both disciplines produce identical token
//! rows for the same prompts (EOS-truncated) — the parity contract
//! `tests/server.rs` pins down.

use crate::coordinator::metrics::{LatencyHistogram, OccupancyMeter};
use crate::data::tokenizer::EOS;
use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use crate::runtime::session::{bucket_for, DecodeSlots, Session};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub struct Request {
    pub enc_tokens: Vec<i32>,
    pub reply: mpsc::Sender<Response>,
    /// When the request was created (client side), so reported latency
    /// includes time spent blocked in the bounded request channel and
    /// queued at the router — not just time after admission.
    /// `Request::new` stamps it; construct requests through it.
    pub t0: Instant,
}

impl Request {
    pub fn new(enc_tokens: Vec<i32>, reply: mpsc::Sender<Response>) -> Request {
        Request { enc_tokens, reply, t0: Instant::now() }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    /// Decoded tokens, truncated at the first EOS (inclusive) — under
    /// continuous batching the decode actually stopped there (early
    /// exit); under batch-level decode the full row ran and the tail
    /// past EOS is dropped for parity.
    pub tokens: Vec<i32>,
    /// Time from `Request::new` (includes channel/router queueing).
    pub latency: Duration,
    pub batch_fill: usize,
    /// True when the request's prompt exceeded the model's `enc_len`
    /// and was cut to fit (previously a silent truncation).
    pub truncated: bool,
    /// Sequence-length bucket the request actually executed at.
    pub bucket: usize,
    /// Which model replica served the request.
    pub replica: usize,
}

#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub batch_window: Duration,
    pub seed: u64,
    /// Optional checkpoint to load weights from.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Number of model threads behind the shared router queue.
    /// `ALTUP_SERVER_REPLICAS` sets the default (else 1); 0 means 1.
    pub replicas: usize,
    /// Shape-bucketed batching (default on; `ALTUP_NO_BUCKETS=1` pads
    /// every batch to the full `enc_len` — the A/B baseline).
    pub bucketed: bool,
    /// Decode slots per replica for continuous batching; 0 = auto (the
    /// engine's `batch_size`). `ALTUP_SERVER_SLOTS` sets the default.
    pub slots: usize,
    /// Iteration-level (continuous) scheduling (default on;
    /// `ALTUP_NO_CONT_BATCH=1` forces run-to-completion batches — the
    /// A/B baseline). Replicas also fall back per-engine when the
    /// artifact ships no split HLO.
    pub continuous: bool,
    /// Capacity of the bounded request channel (admission
    /// backpressure); 0 means 1. Senders block once it fills; that
    /// blocked time still counts toward reported latency because the
    /// clock starts at `Request::new`.
    pub queue_cap: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            batch_window: Duration::from_millis(5),
            seed: 0,
            checkpoint: None,
            replicas: replicas_from_env(),
            bucketed: std::env::var_os("ALTUP_NO_BUCKETS").is_none(),
            slots: slots_from_env(),
            continuous: std::env::var_os("ALTUP_NO_CONT_BATCH").is_none(),
            queue_cap: 1024,
        }
    }
}

fn replicas_from_env() -> usize {
    std::env::var("ALTUP_SERVER_REPLICAS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

fn slots_from_env() -> usize {
    std::env::var("ALTUP_SERVER_SLOTS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(0)
}

/// Which decode backend the replicas run.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// A compiled artifact by suite name (requires a real PJRT backend).
    Artifact { name: String },
    /// Deterministic backend-free decode with a token-proportional cost
    /// model — for scheduler tests/benches on machines without the
    /// xla-rs bindings.
    Sim(SimSpec),
}

#[derive(Debug, Clone)]
pub struct SimSpec {
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    pub vocab_size: usize,
    /// Simulated device nanoseconds per prefill token. A monolithic
    /// `decode_step` batch prefills the full `batch_size x bucket`
    /// geometry; a split `prefill` runs varlen-style over only the
    /// admitted `rows x bucket`. `ALTUP_SIM_TOKEN_NS` sets the default
    /// (else 20000 — ~20 ms per full (8,128) prefill, in the ballpark
    /// of a micro-model CPU decode — so service time, not
    /// router/scheduler overhead, dominates benches even on small
    /// shared machines).
    pub token_ns: u64,
    /// Simulated ns per slot-row per fused decode step (the decoder
    /// reads one token's worth of weights per live row).
    /// `ALTUP_SIM_DTOKEN_NS` sets the default (else `token_ns`).
    pub dtoken_ns: u64,
    /// Fixed dispatch overhead per prefill/decode-step execute.
    /// `ALTUP_SIM_DSTEP_NS` sets the default (else 50000).
    pub dstep_ns: u64,
    /// Pretend the artifact ships the split prefill/decode_token HLO
    /// pair. `false` exercises the batch-level fallback path.
    pub split_decode: bool,
}

impl SimSpec {
    pub fn new(batch_size: usize, enc_len: usize, dec_len: usize) -> SimSpec {
        let env_ns = |key: &str, default: u64| {
            std::env::var(key).ok().and_then(|s| s.parse::<u64>().ok()).unwrap_or(default)
        };
        let token_ns = env_ns("ALTUP_SIM_TOKEN_NS", 20000);
        SimSpec {
            batch_size,
            enc_len,
            dec_len,
            vocab_size: 512,
            token_ns,
            dtoken_ns: env_ns("ALTUP_SIM_DTOKEN_NS", token_ns),
            dstep_ns: env_ns("ALTUP_SIM_DSTEP_NS", 50000),
            split_decode: true,
        }
    }
}

/// Aggregate serving counters; per-replica stats are merged by
/// `ServerHandle::shutdown`.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: usize,
    /// Decode batches (batch-level) or prefill admission groups
    /// (continuous) — the unit `mean_fill` averages over.
    pub batches: usize,
    pub total_fill: usize,
    /// How many replica stat sets were merged in.
    pub replicas: usize,
    /// Real prompt tokens submitted (post-truncation).
    pub prompt_tokens: usize,
    /// Prefill tokens actually executed — `batch_size * bucket` per
    /// monolithic batch, `rows * bucket` per split prefill — the
    /// denominator of the padded-waste ratio.
    pub executed_tokens: usize,
    pub truncated: usize,
    /// Decoded tokens delivered to clients (EOS-truncated rows).
    pub tokens_generated: usize,
    /// Decode tokens the continuous path did NOT run because slots
    /// retired at EOS (`dec_len - row len`, summed). Zero under
    /// batch-level decode — the monolithic step always runs `dec_len`.
    pub tokens_saved: usize,
    /// Fused `decode_token` iterations (continuous path only).
    pub decode_steps: usize,
    /// Split-prefill executions (continuous path only).
    pub prefills: usize,
    /// Live-slots-per-decode-iteration meter (continuous path only).
    pub occupancy: OccupancyMeter,
    /// Per-request queued+executed latency, log-bucketed (O(1) memory
    /// over a server's lifetime, mergeable across replicas).
    pub latency: LatencyHistogram,
    /// Per-token latency (request latency / tokens delivered).
    pub token_latency: LatencyHistogram,
}

impl ServerStats {
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_fill as f64 / self.batches as f64
        }
    }

    /// Fraction of executed tokens that were padding: 1 - prompt/executed.
    pub fn waste_ratio(&self) -> f64 {
        if self.executed_tokens == 0 {
            0.0
        } else {
            1.0 - self.prompt_tokens as f64 / self.executed_tokens as f64
        }
    }

    /// Fraction of the monolithic decode budget the early exit saved:
    /// saved / (saved + generated).
    pub fn early_exit_ratio(&self) -> f64 {
        let budget = self.tokens_saved + self.tokens_generated;
        if budget == 0 {
            0.0
        } else {
            self.tokens_saved as f64 / budget as f64
        }
    }

    /// Number of latency samples recorded (== requests served).
    pub fn latency_count(&self) -> u64 {
        self.latency.count()
    }

    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        self.latency.percentile_ms(p)
    }
    pub fn p50_ms(&self) -> f64 {
        self.latency_percentile_ms(50.0)
    }
    pub fn p95_ms(&self) -> f64 {
        self.latency_percentile_ms(95.0)
    }
    pub fn p99_ms(&self) -> f64 {
        self.latency_percentile_ms(99.0)
    }
    /// Mean per-token latency in ms (histogram approximation).
    pub fn token_ms(&self) -> f64 {
        self.token_latency.mean_ms()
    }

    /// Record one finished request's bookkeeping (shared by both
    /// decode disciplines).
    fn note_response(
        &mut self,
        latency: Duration,
        generated: usize,
        saved: usize,
        prompt: usize,
        truncated: bool,
    ) {
        let ms = latency.as_secs_f64() * 1e3;
        self.latency.record(ms);
        self.token_latency.record(ms / generated.max(1) as f64);
        self.tokens_generated += generated;
        self.tokens_saved += saved;
        self.prompt_tokens += prompt;
        if truncated {
            self.truncated += 1;
        }
    }

    /// Fold another replica's counters into this aggregate.
    pub fn merge(&mut self, other: &ServerStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.total_fill += other.total_fill;
        self.replicas += other.replicas;
        self.prompt_tokens += other.prompt_tokens;
        self.executed_tokens += other.executed_tokens;
        self.truncated += other.truncated;
        self.tokens_generated += other.tokens_generated;
        self.tokens_saved += other.tokens_saved;
        self.decode_steps += other.decode_steps;
        self.prefills += other.prefills;
        self.occupancy.merge(&other.occupancy);
        self.latency.merge(&other.latency);
        self.token_latency.merge(&other.token_latency);
    }

    pub fn summary(&self) -> String {
        format!(
            "{} requests / {} batches on {} replica(s), mean fill {:.2}, \
             padded waste {:.1}%, {} tokens out (early exit saved {:.1}%), \
             mean occupancy {:.2} over {} decode steps, \
             latency p50 {:.2} / p95 {:.2} / p99 {:.2} ms",
            self.requests,
            self.batches,
            self.replicas.max(1),
            self.mean_fill(),
            self.waste_ratio() * 100.0,
            self.tokens_generated,
            self.early_exit_ratio() * 100.0,
            self.occupancy.mean(),
            self.decode_steps,
            self.p50_ms(),
            self.p95_ms(),
            self.p99_ms()
        )
    }
}

/// A request the router has accepted into a bucket group. Latency is
/// reported from the client-side `Request::t0`; the batch-window
/// deadline runs from `admitted`, so a request that sat in the request
/// channel does not count that wait against its group's window (which
/// would ship burst arrivals as tiny immediately-due batches).
struct Admitted {
    req: Request,
    admitted: Instant,
}

/// A bucket-homogeneous batch ready for a replica.
struct BatchJob {
    bucket: usize,
    requests: Vec<Admitted>,
}

pub struct ServerHandle {
    /// Bounded: `send` blocks once `ServerOptions::queue_cap` requests
    /// are in flight ahead of the router (admission backpressure).
    pub sender: mpsc::SyncSender<Request>,
    router: Option<std::thread::JoinHandle<Result<()>>>,
    replicas: Vec<std::thread::JoinHandle<Result<ServerStats>>>,
}

impl ServerHandle {
    /// Spawn router + replicas serving the named artifact.
    pub fn spawn(artifact_name: &str, opts: ServerOptions) -> ServerHandle {
        ServerHandle::spawn_engine(
            EngineSpec::Artifact { name: artifact_name.to_string() },
            opts,
        )
    }

    /// Spawn router + replicas over an explicit decode backend.
    pub fn spawn_engine(engine: EngineSpec, opts: ServerOptions) -> ServerHandle {
        let n = opts.replicas.max(1);
        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(opts.queue_cap.max(1));
        // Bounded job queue = backpressure: when every replica is busy
        // and the queue is full, the router keeps accumulating instead
        // of window-flushing tiny partial batches at a wall of busy
        // replicas (which craters fill and wastes executed tokens).
        let (job_tx, job_rx) = mpsc::sync_channel::<BatchJob>(n);
        let job_rx = Arc::new(Mutex::new(job_rx));

        let router = {
            let spec = engine.clone();
            let ropts = opts.clone();
            std::thread::Builder::new()
                .name("altup-router".into())
                .spawn(move || route(&spec, req_rx, job_tx, &ropts))
                .expect("spawn router")
        };
        let replicas = (0..n)
            .map(|i| {
                let spec = engine.clone();
                let jobs = Arc::clone(&job_rx);
                let sopts = opts.clone();
                std::thread::Builder::new()
                    .name(format!("altup-replica-{i}"))
                    .spawn(move || serve_replica(i, &spec, &jobs, &sopts))
                    .expect("spawn replica")
            })
            .collect();
        ServerHandle { sender: req_tx, router: Some(router), replicas }
    }

    /// Submit a request and block for the response. The latency clock
    /// starts before the (possibly blocking) send into the bounded
    /// request channel, so backpressured requests report their queueing
    /// time. Returns an error (rather than hanging) when the router or
    /// the serving replica has died — the reply channel is dropped with
    /// the request.
    pub fn infer(&self, enc_tokens: Vec<i32>) -> Result<Response> {
        let (tx, rx) = mpsc::channel();
        self.sender
            .send(Request::new(enc_tokens, tx))
            .map_err(|_| anyhow!("server router is down; request not admitted"))?;
        rx.recv().map_err(|_| {
            anyhow!("model replica died before replying (shutdown() reports the cause)")
        })
    }

    /// Shut down (drop sender, drain, join) and return merged stats
    /// from every replica.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        let router = self.router.take().expect("router handle");
        let replicas = std::mem::take(&mut self.replicas);
        drop(self.sender);
        let mut first_err: Option<anyhow::Error> = None;
        match router.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => first_err = Some(e),
            Err(_) => first_err = Some(anyhow!("router thread panicked")),
        }
        let mut merged = ServerStats::default();
        for handle in replicas {
            match handle.join() {
                Ok(Ok(stats)) => merged.merge(&stats),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("replica thread panicked"));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(merged),
        }
    }
}

/// Router loop: admit continuously, group by bucket, and hand batches
/// to the replicas. A group ships as soon as it fills (blocking send —
/// genuine backpressure once the bounded job queue is full). A group
/// whose oldest request has waited out the batch window ships
/// best-effort (`try_send`): if every replica is busy and the queue is
/// full it simply keeps accumulating — arriving requests top it up
/// toward a full batch instead of the router spraying tiny partial
/// batches at a wall of busy replicas.
fn route(
    spec: &EngineSpec,
    rx: mpsc::Receiver<Request>,
    tx: mpsc::SyncSender<BatchJob>,
    opts: &ServerOptions,
) -> Result<()> {
    let (batch_size, enc_len) = match spec {
        EngineSpec::Artifact { name } => {
            let artifact = load_named(name)?;
            (artifact.config.batch_size, artifact.config.enc_len)
        }
        EngineSpec::Sim(s) => (s.batch_size, s.enc_len),
    };
    let mut groups: BTreeMap<usize, Vec<Admitted>> = BTreeMap::new();
    let mut disconnected = false;
    while !(disconnected && groups.is_empty()) {
        // Flush pass. In drain mode (clients gone) everything ships
        // with a blocking send.
        let now = Instant::now();
        let mut due_unsent = false;
        let buckets: Vec<usize> = groups.keys().copied().collect();
        for bucket in buckets {
            let group = groups.get(&bucket).expect("group present");
            let full = group.len() >= batch_size;
            let due =
                group.first().map_or(false, |a| now >= a.admitted + opts.batch_window);
            if full || disconnected {
                let requests = groups.remove(&bucket).expect("group present");
                if tx.send(BatchJob { bucket, requests }).is_err() {
                    return Ok(()); // every replica is gone
                }
            } else if due {
                let requests = groups.remove(&bucket).expect("group present");
                match tx.try_send(BatchJob { bucket, requests }) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(job)) => {
                        groups.insert(bucket, job.requests);
                        due_unsent = true;
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return Ok(()),
                }
            }
        }
        if disconnected {
            continue; // drain until groups run dry
        }

        // Admit pass: block until the next request, the next group
        // deadline, or (when a due group couldn't ship) a short park so
        // the flush retries once a replica frees up.
        let message = if groups.is_empty() {
            match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => {
                    disconnected = true;
                    None
                }
            }
        } else {
            let wait = if due_unsent {
                // Floor the park so a zero batch window cannot busy-spin
                // while replicas are saturated and the job queue is full.
                opts.batch_window.max(Duration::from_micros(200))
            } else {
                let oldest = groups
                    .values()
                    .filter_map(|g| g.first())
                    .map(|a| a.admitted)
                    .min()
                    .expect("non-empty groups");
                (oldest + opts.batch_window).saturating_duration_since(Instant::now())
            };
            if wait.is_zero() {
                None // a group came due during the flush pass
            } else {
                match rx.recv_timeout(wait) {
                    Ok(r) => Some(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            }
        };
        if let Some(req) = message {
            let bucket = if opts.bucketed {
                bucket_for(req.enc_tokens.len(), enc_len)
            } else {
                enc_len
            };
            groups
                .entry(bucket)
                .or_default()
                .push(Admitted { req, admitted: Instant::now() });
        }
    }
    Ok(())
}

/// The per-replica decode backend (built inside the replica thread:
/// `Session` is !Send).
enum Engine {
    Real { client: Client, session: Session },
    Sim(SimSpec),
}

/// Per-replica slot state for the continuous path: device-resident KV
/// buffers for the real backend, per-slot decode cursors for the sim.
enum SlotState {
    /// `Option` so the `DecodeSlots` can be moved through the donating
    /// `Session::prefill`/`decode_token` calls and put back.
    Real(Option<DecodeSlots>),
    Sim(Vec<Option<SimSlot>>),
}

/// One live sim request: prompt hash (the whole decode stream derives
/// from it), next position, and the hash-sampled generation length.
#[derive(Clone, Copy)]
struct SimSlot {
    h: u64,
    pos: usize,
    gen_len: usize,
}

impl Engine {
    fn build(spec: &EngineSpec, opts: &ServerOptions) -> Result<Engine> {
        match spec {
            EngineSpec::Artifact { name } => {
                let client = Client::cpu()?;
                let artifact = load_named(name)?;
                let mut session = Session::open_eval(&client, artifact, opts.seed)?;
                if let Some(ckpt) = &opts.checkpoint {
                    session.store =
                        crate::runtime::params::ParamStore::load(ckpt, &session.artifact)?;
                    session.invalidate_state();
                }
                session.ensure_decode(&client)?;
                // §Perf L4: upload the weights once; every batch reuses
                // the device-resident buffers.
                session.warm_device_cache(&client)?;
                Ok(Engine::Real { client, session })
            }
            EngineSpec::Sim(s) => Ok(Engine::Sim(s.clone())),
        }
    }

    /// (batch_size, enc_len) of the serving geometry.
    fn dims(&self) -> (usize, usize) {
        match self {
            Engine::Real { session, .. } => {
                (session.artifact.config.batch_size, session.artifact.config.enc_len)
            }
            Engine::Sim(s) => (s.batch_size, s.enc_len),
        }
    }

    /// Maximum tokens a request may generate.
    fn dec_len(&self) -> usize {
        match self {
            Engine::Real { session, .. } => session.artifact.config.dec_len,
            Engine::Sim(s) => s.dec_len,
        }
    }

    /// Whether this engine can run the split prefill/decode_token
    /// discipline (the artifact ships the HLO pair; the sim can opt
    /// out to exercise the fallback).
    fn supports_continuous(&self) -> bool {
        match self {
            Engine::Real { session, .. } => session.has_split_decode(),
            Engine::Sim(s) => s.split_decode,
        }
    }

    /// The sequence length a monolithic job at `bucket` actually
    /// executes at (the real backend falls back to `enc_len` when the
    /// artifact has no shape-specialized HLO for the bucket).
    fn effective_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_bucket(bucket),
            Engine::Sim(s) => bucket.min(s.enc_len),
        }
    }

    /// Same, for the split prefill family.
    fn effective_prefill_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_prefill_bucket(bucket),
            Engine::Sim(s) => bucket.min(s.enc_len),
        }
    }

    /// Monolithic decode of a (batch_size, bucket) packed batch.
    fn decode(&mut self, enc: &[i32], bucket: usize) -> Result<Vec<Vec<i32>>> {
        match self {
            Engine::Real { client, session } => session.decode_bucketed(client, enc, bucket),
            Engine::Sim(s) => Ok(sim_decode(s, enc, bucket)),
        }
    }

    /// Allocate the per-replica slot state for `n` concurrent requests.
    fn init_slots(&mut self, n: usize) -> Result<SlotState> {
        match self {
            Engine::Real { client, session } => {
                Ok(SlotState::Real(Some(session.init_decode_slots(client, n)?)))
            }
            Engine::Sim(_) => Ok(SlotState::Sim(vec![None; n])),
        }
    }

    /// Prefill a same-bucket admission group, `enc` packed row-major at
    /// (slot_ids.len(), bucket), into slot rows `slot_ids`.
    fn prefill(
        &mut self,
        state: &mut SlotState,
        enc: &[i32],
        bucket: usize,
        slot_ids: &[usize],
    ) -> Result<()> {
        match (self, state) {
            (Engine::Real { client, session }, SlotState::Real(slots)) => {
                let held = slots
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let ids: Vec<i32> = slot_ids.iter().map(|&s| s as i32).collect();
                *slots = Some(session.prefill(client, held, enc, bucket, &ids)?);
                Ok(())
            }
            (Engine::Sim(spec), SlotState::Sim(slots)) => {
                for (row, &sid) in enc.chunks(bucket).zip(slot_ids.iter()) {
                    let h = sim_row_hash(row);
                    slots[sid] =
                        Some(SimSlot { h, pos: 0, gen_len: sim_gen_len(h, spec.dec_len) });
                }
                // Varlen-style split prefill: dispatch overhead + cost
                // over the admitted rows only (no dead padding rows).
                sim_sleep(
                    spec.dstep_ns
                        + spec.token_ns.saturating_mul((slot_ids.len() * bucket) as u64),
                );
                Ok(())
            }
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }

    /// One fused decode iteration over the whole slot geometry:
    /// advances every slot with `live[s] == true` by one token and
    /// returns the (slots,) token row (dead rows carry garbage).
    fn decode_token(&mut self, state: &mut SlotState, live: &[bool]) -> Result<Vec<i32>> {
        match (self, state) {
            (Engine::Real { client, session }, SlotState::Real(slots)) => {
                let held = slots
                    .take()
                    .context("slot state lost after an earlier prefill/decode error")?;
                let (held, tokens) = session.decode_token(client, held, live)?;
                *slots = Some(held);
                Ok(tokens)
            }
            (Engine::Sim(spec), SlotState::Sim(slots)) => {
                let mut out = vec![0i32; slots.len()];
                for (s, slot) in slots.iter_mut().enumerate() {
                    if !live[s] {
                        continue;
                    }
                    let sl = slot.as_mut().context("live mask set on an empty sim slot")?;
                    out[s] = if sl.pos + 1 == sl.gen_len {
                        EOS
                    } else {
                        sim_token(sl.h, sl.pos, spec.vocab_size)
                    };
                    sl.pos += 1;
                }
                // Fused step over the full static slot geometry.
                sim_sleep(
                    spec.dstep_ns + spec.dtoken_ns.saturating_mul(slots.len() as u64),
                );
                Ok(out)
            }
            _ => bail!("engine/slot-state backend mismatch"),
        }
    }
}

/// FNV-1a over a row's non-padding prompt tokens only, so decode
/// streams are identical no matter which bucket executed the prompt
/// (the parity contract real bucketed decode must also satisfy).
fn sim_row_hash(row: &[i32]) -> u64 {
    let used = row.iter().rposition(|&t| t != 0).map_or(0, |i| i + 1);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in &row[..used] {
        h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Hash-sampled generation length in [1, dec_len] — the "EOS
/// distribution" of the sim workload. The row's final token is EOS.
fn sim_gen_len(h: u64, dec_len: usize) -> usize {
    let mut x = h ^ (h >> 33);
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 29;
    1 + (x % dec_len.max(1) as u64) as usize
}

/// Deterministic non-EOS token for decode position `j`: in
/// [2, vocab) — ids 0 (PAD) and 1 (EOS) stay reserved.
fn sim_token(h: u64, j: usize, vocab: usize) -> i32 {
    let mut x = h.wrapping_mul(j as u64 + 1).wrapping_add(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 29;
    2 + (x % (vocab.max(3) as u64 - 2)) as i32
}

/// Precise simulated-device wait. Kernels round `thread::sleep` up to
/// their timer quantum (~1 ms on some hosts), which would tax the
/// continuous path's many sub-ms fused decode steps while leaving the
/// batch path's few ~20 ms sleeps untouched — so coarse-sleep the bulk
/// and yield-spin the final stretch.
fn sim_sleep(ns: u64) {
    if ns == 0 {
        return;
    }
    let end = Instant::now() + Duration::from_nanos(ns);
    loop {
        let now = Instant::now();
        if now >= end {
            return;
        }
        let rem = end - now;
        if rem > Duration::from_micros(1500) {
            std::thread::sleep(rem - Duration::from_micros(1200));
        } else {
            std::thread::yield_now();
        }
    }
}

/// Deterministic stand-in monolithic decode: each output row derives
/// from the row's non-padding prompt tokens only and ends at its
/// hash-sampled EOS. Costs the full geometry — `batch_size x bucket`
/// prefill plus all `dec_len` decode steps for every row, early exit
/// or not — which is exactly what the split path's A/B measures
/// against.
fn sim_decode(spec: &SimSpec, enc: &[i32], bucket: usize) -> Vec<Vec<i32>> {
    let mut out = Vec::with_capacity(spec.batch_size);
    for row in enc.chunks(bucket) {
        let h = sim_row_hash(row);
        let gen_len = sim_gen_len(h, spec.dec_len);
        let mut tokens = Vec::with_capacity(gen_len);
        for j in 0..gen_len {
            tokens.push(if j + 1 == gen_len { EOS } else { sim_token(h, j, spec.vocab_size) });
        }
        out.push(tokens);
    }
    let prefill = spec.token_ns.saturating_mul((spec.batch_size * bucket) as u64);
    let decode = (spec.dec_len as u64)
        .saturating_mul(spec.dstep_ns + spec.dtoken_ns.saturating_mul(spec.batch_size as u64));
    sim_sleep(prefill + decode);
    out
}

/// Truncate a decoded row at its first EOS (inclusive), aligning the
/// monolithic path's output with what the continuous path actually
/// generated before retiring the slot.
fn truncate_at_eos(tokens: &mut Vec<i32>) {
    if let Some(p) = tokens.iter().position(|&t| t == EOS) {
        tokens.truncate(p + 1);
    }
}

/// Replica entry: build the engine, then run whichever decode
/// discipline it supports (continuous wants the split HLO pair; the
/// batch-level loop works against every artifact).
fn serve_replica(
    id: usize,
    spec: &EngineSpec,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
) -> Result<ServerStats> {
    let mut engine = Engine::build(spec, opts)?;
    let mut stats = ServerStats { replicas: 1, ..Default::default() };
    if opts.continuous && engine.supports_continuous() {
        serve_continuous(id, &mut engine, jobs, opts, &mut stats)?;
    } else {
        serve_batches(id, &mut engine, jobs, &mut stats)?;
    }
    Ok(stats)
}

/// Non-blocking / blocking pop off the shared job queue.
enum Popped {
    Job(BatchJob),
    Empty,
    Gone,
}

fn pop_job(
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    block: bool,
) -> Result<Popped> {
    // Hold the queue lock only for the pop; decode runs unlocked so
    // other replicas pull the next job meanwhile. (A blocking pop only
    // happens when this replica is idle.)
    if block {
        let queue = jobs.lock().map_err(|_| anyhow!("job queue poisoned"))?;
        match queue.recv() {
            Ok(job) => Ok(Popped::Job(job)),
            Err(_) => Ok(Popped::Gone),
        }
    } else {
        // try_lock, not lock: an idle replica parks inside `recv`
        // holding the mutex, and a replica with live slots must keep
        // decoding rather than stall on that hold until the next job
        // arrives.
        let queue = match jobs.try_lock() {
            Ok(q) => q,
            Err(std::sync::TryLockError::WouldBlock) => return Ok(Popped::Empty),
            Err(std::sync::TryLockError::Poisoned(_)) => {
                return Err(anyhow!("job queue poisoned"))
            }
        };
        match queue.try_recv() {
            Ok(job) => Ok(Popped::Job(job)),
            Err(mpsc::TryRecvError::Empty) => Ok(Popped::Empty),
            Err(mpsc::TryRecvError::Disconnected) => Ok(Popped::Gone),
        }
    }
}

/// Run-to-completion batch loop (§Perf L5, and the fallback when the
/// artifact ships no split HLO): pop bucket-homogeneous jobs, pack at
/// the (effective) bucket geometry into a reused scratch buffer,
/// decode to full `dec_len`, and move each output row into its reply.
fn serve_batches(
    id: usize,
    engine: &mut Engine,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    stats: &mut ServerStats,
) -> Result<()> {
    let (batch_size, _enc_len) = engine.dims();
    // Packing scratch reused across every batch on this hot path: the
    // fresh-allocation-per-batch version showed up in router/replica
    // profiles once decode itself got cheap.
    let mut enc_scratch: Vec<i32> = Vec::new();
    let mut trunc_scratch: Vec<bool> = Vec::new();
    loop {
        let job = match pop_job(jobs, true)? {
            Popped::Job(job) => job,
            _ => break, // router gone and queue drained
        };
        let fill = job.requests.len();
        let bucket = engine.effective_bucket(job.bucket);
        {
            let rows: Vec<&[i32]> =
                job.requests.iter().map(|a| a.req.enc_tokens.as_slice()).collect();
            pack_requests_into(&rows, batch_size, bucket, &mut enc_scratch, &mut trunc_scratch);
        }
        let decoded = engine.decode(&enc_scratch, bucket)?;
        let mut decoded = decoded.into_iter();
        for (i, admitted) in job.requests.into_iter().enumerate() {
            let req = admitted.req;
            let latency = req.t0.elapsed();
            let mut tokens = decoded.next().unwrap_or_default();
            truncate_at_eos(&mut tokens);
            stats.note_response(
                latency,
                tokens.len(),
                0, // monolithic decode ran the full dec_len regardless
                req.enc_tokens.len().min(bucket),
                trunc_scratch[i],
            );
            let _ = req.reply.send(Response {
                tokens,
                latency,
                batch_fill: fill,
                truncated: trunc_scratch[i],
                bucket,
                replica: id,
            });
        }
        stats.requests += fill;
        stats.batches += 1;
        stats.total_fill += fill;
        stats.executed_tokens += batch_size * bucket;
    }
    Ok(())
}

/// A request occupying a decode slot.
struct Active {
    req: Request,
    tokens: Vec<i32>,
    bucket: usize,
    fill: usize,
    truncated: bool,
    prompt_len: usize,
}

/// Slot-based continuous batching (§Perf L6): between fused
/// `decode_token` iterations the scheduler admits pending requests
/// into free slots (one batched prefill per same-bucket group) and
/// retires slots the moment they emit EOS or hit `dec_len`.
fn serve_continuous(
    id: usize,
    engine: &mut Engine,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
    stats: &mut ServerStats,
) -> Result<()> {
    let (batch_size, _enc_len) = engine.dims();
    let dec_len = engine.dec_len();
    let slots_n = if opts.slots > 0 { opts.slots } else { batch_size };
    let mut state = engine.init_slots(slots_n)?;
    let mut active: Vec<Option<Active>> = (0..slots_n).map(|_| None).collect();
    let mut pending: VecDeque<(usize, Admitted)> = VecDeque::new();
    let mut router_gone = false;
    let mut enc_scratch: Vec<i32> = Vec::new();
    let mut trunc_scratch: Vec<bool> = Vec::new();
    loop {
        let n_live = active.iter().filter(|s| s.is_some()).count();

        // Pull new work: block when fully idle (nothing to decode),
        // poll otherwise so in-flight slots keep stepping.
        if !router_gone {
            if n_live == 0 && pending.is_empty() {
                match pop_job(jobs, true)? {
                    Popped::Job(job) => stash(&mut pending, job),
                    _ => router_gone = true,
                }
            }
            while pending.len() < slots_n && !router_gone {
                match pop_job(jobs, false)? {
                    Popped::Job(job) => stash(&mut pending, job),
                    Popped::Empty => break,
                    Popped::Gone => router_gone = true,
                }
            }
        }

        // Admit pending requests into free slots, one batched prefill
        // per same-bucket run (bounded by the prefill geometry).
        let mut free: VecDeque<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect();
        while !free.is_empty() && !pending.is_empty() {
            let bucket = pending.front().expect("non-empty pending").0;
            let eff = engine.effective_prefill_bucket(bucket);
            let mut group: Vec<Admitted> = Vec::new();
            let mut slot_ids: Vec<usize> = Vec::new();
            while group.len() < batch_size.min(free.len() + group.len()) {
                match pending.front() {
                    Some((b, _)) if *b == bucket => {}
                    _ => break,
                }
                let (_, admitted) = pending.pop_front().expect("front present");
                slot_ids.push(free.pop_front().expect("free slot"));
                group.push(admitted);
            }
            if group.is_empty() {
                break; // no free capacity for this bucket run
            }
            {
                let rows: Vec<&[i32]> =
                    group.iter().map(|a| a.req.enc_tokens.as_slice()).collect();
                pack_requests_into(&rows, rows.len(), eff, &mut enc_scratch, &mut trunc_scratch);
            }
            engine.prefill(&mut state, &enc_scratch, eff, &slot_ids)?;
            stats.prefills += 1;
            stats.batches += 1;
            stats.total_fill += group.len();
            stats.executed_tokens += group.len() * eff;
            for (i, admitted) in group.into_iter().enumerate() {
                let prompt_len = admitted.req.enc_tokens.len().min(eff);
                active[slot_ids[i]] = Some(Active {
                    req: admitted.req,
                    tokens: Vec::with_capacity(dec_len),
                    bucket: eff,
                    fill: slot_ids.len(),
                    truncated: trunc_scratch[i],
                    prompt_len,
                });
            }
        }

        let n_live = active.iter().filter(|s| s.is_some()).count();
        if n_live == 0 {
            if router_gone && pending.is_empty() {
                break; // drained
            }
            continue;
        }

        // One fused decode iteration over the whole slot geometry.
        let live: Vec<bool> = active.iter().map(|s| s.is_some()).collect();
        let tokens = engine.decode_token(&mut state, &live)?;
        stats.decode_steps += 1;
        stats.occupancy.record(n_live);
        for (s, slot) in active.iter_mut().enumerate() {
            let Some(act) = slot.as_mut() else { continue };
            act.tokens.push(tokens[s]);
            let done = tokens[s] == EOS || act.tokens.len() >= dec_len;
            if !done {
                continue;
            }
            let act = slot.take().expect("live slot");
            let latency = act.req.t0.elapsed();
            stats.note_response(
                latency,
                act.tokens.len(),
                dec_len - act.tokens.len(), // early-exit savings
                act.prompt_len,
                act.truncated,
            );
            stats.requests += 1;
            let _ = act.req.reply.send(Response {
                tokens: act.tokens,
                latency,
                batch_fill: act.fill,
                truncated: act.truncated,
                bucket: act.bucket,
                replica: id,
            });
        }
    }
    Ok(())
}

/// Unpack a router job into the replica's pending queue, keeping the
/// job's bucket tag per request (admission regroups by bucket).
fn stash(pending: &mut VecDeque<(usize, Admitted)>, job: BatchJob) {
    let BatchJob { bucket, requests } = job;
    for admitted in requests {
        pending.push_back((bucket, admitted));
    }
}

/// Pack request token rows into a fixed (batch_size, len) geometry:
/// short rows are zero-padded, long rows are cut to fit. `len` is the
/// full `enc_len` or any smaller bucket the group was routed to.
/// Returns the flat batch plus a per-row truncation flag.
pub fn pack_requests(
    rows: &[&[i32]],
    batch_size: usize,
    len: usize,
) -> (Vec<i32>, Vec<bool>) {
    let mut enc = Vec::new();
    let mut truncated = Vec::new();
    pack_requests_into(rows, batch_size, len, &mut enc, &mut truncated);
    (enc, truncated)
}

/// `pack_requests` into caller-provided scratch buffers, so the
/// replica hot loop reuses one allocation across every batch instead
/// of building a fresh padded matrix per job. The scratch is cleared
/// and zero-filled to the new geometry on every call — no stale tokens
/// survive a reuse at a different shape.
pub fn pack_requests_into(
    rows: &[&[i32]],
    batch_size: usize,
    len: usize,
    enc: &mut Vec<i32>,
    truncated: &mut Vec<bool>,
) {
    enc.clear();
    enc.resize(batch_size * len, 0);
    truncated.clear();
    truncated.resize(rows.len(), false);
    for (i, row) in rows.iter().take(batch_size).enumerate() {
        let n = row.len().min(len);
        enc[i * len..i * len + n].copy_from_slice(&row[..n]);
        truncated[i] = row.len() > len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_spec() -> SimSpec {
        SimSpec {
            batch_size: 2,
            enc_len: 32,
            dec_len: 6,
            vocab_size: 97,
            token_ns: 0,
            dtoken_ns: 0,
            dstep_ns: 0,
            split_decode: true,
        }
    }

    #[test]
    fn pack_requests_pads_and_flags_truncation() {
        let short = vec![1, 2, 3];
        let exact = vec![5, 6, 7, 8];
        let long = vec![9, 10, 11, 12, 13, 14];
        let rows: Vec<&[i32]> = vec![&short, &exact, &long];
        let (enc, truncated) = pack_requests(&rows, 4, 4);
        assert_eq!(enc.len(), 16);
        assert_eq!(&enc[0..4], &[1, 2, 3, 0], "short row zero-padded");
        assert_eq!(&enc[4..8], &[5, 6, 7, 8], "exact row untouched");
        assert_eq!(&enc[8..12], &[9, 10, 11, 12], "long row cut to enc_len");
        assert_eq!(&enc[12..16], &[0, 0, 0, 0], "unfilled slot stays zero");
        assert_eq!(truncated, vec![false, false, true]);
    }

    #[test]
    fn pack_requests_empty_and_full() {
        let (enc, truncated) = pack_requests(&[], 2, 3);
        assert_eq!(enc, vec![0; 6]);
        assert!(truncated.is_empty());
        let a = vec![1i32; 3];
        let b = vec![2i32; 4];
        let rows: Vec<&[i32]> = vec![&a, &b];
        let (enc, truncated) = pack_requests(&rows, 2, 3);
        assert_eq!(&enc[3..6], &[2, 2, 2]);
        assert_eq!(truncated, vec![false, true]);
    }

    #[test]
    fn pack_requests_at_smaller_bucket() {
        let a = vec![1, 2, 3];
        let rows: Vec<&[i32]> = vec![&a];
        let (enc, truncated) = pack_requests(&rows, 2, 8);
        assert_eq!(enc.len(), 16, "bucket stride, not enc_len stride");
        assert_eq!(&enc[0..4], &[1, 2, 3, 0]);
        assert_eq!(truncated, vec![false]);
    }

    /// Reusing one scratch across geometry changes must behave exactly
    /// like a fresh allocation: no stale tokens from a previous (and
    /// larger) batch may leak into the next packing.
    #[test]
    fn pack_scratch_reuse_leaves_no_stale_data() {
        let mut enc = Vec::new();
        let mut trunc = Vec::new();
        let big = vec![7i32; 8];
        let rows: Vec<&[i32]> = vec![&big, &big, &big];
        pack_requests_into(&rows, 3, 8, &mut enc, &mut trunc);
        assert_eq!(enc.len(), 24);
        assert!(enc.iter().all(|&t| t == 7));

        let small = vec![1i32, 2];
        let rows: Vec<&[i32]> = vec![&small];
        pack_requests_into(&rows, 2, 4, &mut enc, &mut trunc);
        let (fresh, fresh_trunc) = pack_requests(&rows, 2, 4);
        assert_eq!(enc, fresh, "reused scratch == fresh allocation");
        assert_eq!(trunc, fresh_trunc);
        assert_eq!(&enc[2..8], &[0, 0, 0, 0, 0, 0], "old 7s cleared");
        // Growing again after shrinking also matches.
        let rows: Vec<&[i32]> = vec![&big];
        pack_requests_into(&rows, 2, 8, &mut enc, &mut trunc);
        assert_eq!(enc, pack_requests(&rows, 2, 8).0);
    }

    #[test]
    fn sim_decode_is_bucket_invariant_and_deterministic() {
        let spec = quiet_spec();
        let prompt: Vec<i32> = vec![4, 9, 1, 7];
        let pad_to = |len: usize| {
            let mut v = prompt.clone();
            v.resize(len, 0);
            v
        };
        let mut small = pad_to(8);
        small.extend(pad_to(8));
        let mut full = pad_to(32);
        full.extend(pad_to(32));
        let a = sim_decode(&spec, &small, 8);
        let b = sim_decode(&spec, &full, 32);
        assert_eq!(a, b, "output depends only on the unpadded prompt");
        assert!(!a[0].is_empty() && a[0].len() <= spec.dec_len);
        assert_eq!(*a[0].last().unwrap(), EOS, "rows end at their sampled EOS");
        assert!(a[0][..a[0].len() - 1]
            .iter()
            .all(|&t| t >= 2 && (t as usize) < 97), "non-final tokens stay off PAD/EOS");
        // Different prompts decode differently (not a constant).
        let mut other = vec![5i32, 5, 5, 0, 0, 0, 0, 0];
        other.extend(pad_to(8));
        assert_ne!(sim_decode(&spec, &other, 8)[0], a[0]);
    }

    /// The slot-based stream must equal the monolithic row token for
    /// token: prefill one row, step `decode_token` to EOS, compare.
    #[test]
    fn sim_slot_stream_matches_monolithic_rows() {
        let spec = quiet_spec();
        let mut engine = Engine::Sim(spec.clone());
        let mut state = engine.init_slots(3).unwrap();
        let prompt = vec![11i32, 3, 5, 0, 0, 0, 0, 0];
        engine.prefill(&mut state, &prompt, 8, &[1]).unwrap();
        let mut live = vec![false, true, false];
        let mut stream = Vec::new();
        for _ in 0..spec.dec_len {
            let toks = engine.decode_token(&mut state, &live).unwrap();
            stream.push(toks[1]);
            if toks[1] == EOS {
                live[1] = false;
                break;
            }
        }
        let mut batch = prompt.clone();
        batch.extend(vec![0i32; 8]);
        let rows = sim_decode(&spec, &batch, 8);
        assert_eq!(stream, rows[0], "per-token stream == monolithic row");
        assert_eq!(*stream.last().unwrap(), EOS);
    }

    #[test]
    fn sim_gen_lengths_cover_the_range() {
        // EOS-distributed lengths: over many prompts the sampled
        // generation lengths must span [1, dec_len], not collapse.
        let dec_len = 8;
        let mut seen = std::collections::BTreeSet::new();
        for p in 0..200u64 {
            let h = sim_row_hash(&[(p as i32) + 1, 7, 9]);
            let g = sim_gen_len(h, dec_len);
            assert!((1..=dec_len).contains(&g));
            seen.insert(g);
        }
        assert!(seen.len() >= dec_len / 2, "lengths too concentrated: {seen:?}");
    }

    #[test]
    fn truncate_at_eos_is_inclusive_and_idempotent() {
        let mut row = vec![5, 9, EOS, 7, 8];
        truncate_at_eos(&mut row);
        assert_eq!(row, vec![5, 9, EOS]);
        truncate_at_eos(&mut row);
        assert_eq!(row, vec![5, 9, EOS]);
        let mut none = vec![5, 9, 7];
        truncate_at_eos(&mut none);
        assert_eq!(none, vec![5, 9, 7], "no EOS: row untouched");
    }

    #[test]
    fn server_stats_merge_waste_and_percentiles() {
        let mut a = ServerStats {
            requests: 4,
            batches: 2,
            total_fill: 4,
            replicas: 1,
            prompt_tokens: 40,
            executed_tokens: 64,
            truncated: 1,
            ..Default::default()
        };
        for ms in [1.0, 2.0, 3.0, 4.0] {
            a.latency.record(ms);
        }
        let mut b = ServerStats {
            requests: 2,
            batches: 1,
            total_fill: 2,
            replicas: 1,
            prompt_tokens: 10,
            executed_tokens: 36,
            truncated: 0,
            tokens_generated: 30,
            tokens_saved: 10,
            decode_steps: 5,
            prefills: 2,
            ..Default::default()
        };
        b.latency.record(10.0);
        b.latency.record(20.0);
        b.occupancy.record(4);
        a.merge(&b);
        assert_eq!(a.requests, 6);
        assert_eq!(a.batches, 3);
        assert_eq!(a.replicas, 2);
        assert_eq!(a.truncated, 1);
        assert_eq!(a.tokens_generated, 30);
        assert_eq!(a.tokens_saved, 10);
        assert_eq!(a.decode_steps, 5);
        assert_eq!(a.prefills, 2);
        assert!((a.early_exit_ratio() - 0.25).abs() < 1e-12);
        assert_eq!(a.occupancy.steps(), 1);
        assert_eq!(a.latency_count(), 6);
        assert!((a.waste_ratio() - 0.5).abs() < 1e-12, "50/100 executed tokens were padding");
        // Log-bucketed estimates: within the histogram's ~9% error.
        let p50 = a.p50_ms();
        assert!((p50 - 3.0).abs() / 3.0 < 0.10, "p50={p50}");
        let p100 = a.latency_percentile_ms(100.0);
        assert!((p100 - 20.0).abs() / 20.0 < 0.10, "p100={p100}");
        assert_eq!(ServerStats::default().waste_ratio(), 0.0);
        assert_eq!(ServerStats::default().p99_ms(), 0.0);
        assert_eq!(ServerStats::default().early_exit_ratio(), 0.0);
    }

    #[test]
    fn note_response_accounting() {
        let mut s = ServerStats::default();
        s.note_response(Duration::from_millis(10), 5, 3, 7, true);
        assert_eq!(s.tokens_generated, 5);
        assert_eq!(s.tokens_saved, 3);
        assert_eq!(s.prompt_tokens, 7);
        assert_eq!(s.truncated, 1);
        assert_eq!(s.latency_count(), 1);
        assert_eq!(s.token_latency.count(), 1);
        let per_tok = s.token_ms();
        assert!((per_tok - 2.0).abs() / 2.0 < 0.10, "10ms/5tok ~ 2ms: {per_tok}");
        // Zero generated tokens must not divide by zero.
        s.note_response(Duration::from_millis(1), 0, 0, 0, false);
        assert_eq!(s.token_latency.count(), 2);
    }
}
