//! Threaded inference server with dynamic batching.
//!
//! The PJRT session is !Send (Rc-backed FFI handles), so the server owns
//! client + session on a dedicated model thread; callers submit requests
//! over an mpsc channel and get replies over per-request channels. The
//! batcher groups up to `batch_size` requests within `batch_window`,
//! pads partial batches, and runs one `decode_step` per group — the
//! standard dynamic-batching pattern (vLLM-router-like, scaled to one
//! replica).

use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use crate::runtime::session::Session;
use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

pub struct Request {
    pub enc_tokens: Vec<i32>,
    pub reply: mpsc::Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// Time spent queued + executing, for latency accounting.
    pub latency: Duration,
    pub batch_fill: usize,
    /// True when the request's prompt exceeded the model's `enc_len`
    /// and was cut to fit (previously a silent truncation).
    pub truncated: bool,
}

#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub batch_window: Duration,
    pub seed: u64,
    /// Optional checkpoint to load weights from.
    pub checkpoint: Option<std::path::PathBuf>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions { batch_window: Duration::from_millis(5), seed: 0, checkpoint: None }
    }
}

pub struct ServerHandle {
    pub sender: mpsc::Sender<Request>,
    join: Option<std::thread::JoinHandle<Result<ServerStats>>>,
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub total_fill: usize,
}

impl ServerStats {
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_fill as f64 / self.batches as f64
        }
    }
}

impl ServerHandle {
    /// Spawn the model thread; resolves the artifact by suite name.
    pub fn spawn(artifact_name: &str, opts: ServerOptions) -> ServerHandle {
        let (tx, rx) = mpsc::channel::<Request>();
        let name = artifact_name.to_string();
        let join = std::thread::Builder::new()
            .name("altup-server".into())
            .spawn(move || serve(&name, rx, opts))
            .expect("spawn server");
        ServerHandle { sender: tx, join: Some(join) }
    }

    /// Submit a request and block for the response.
    pub fn infer(&self, enc_tokens: Vec<i32>) -> Result<Response> {
        let (tx, rx) = mpsc::channel();
        self.sender.send(Request { enc_tokens, reply: tx })?;
        Ok(rx.recv()?)
    }

    /// Shut down (drop sender) and collect stats.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        let join = self.join.take().unwrap();
        drop(self.sender);
        join.join().expect("server thread panicked")
    }
}

fn serve(artifact_name: &str, rx: mpsc::Receiver<Request>, opts: ServerOptions) -> Result<ServerStats> {
    let client = Client::cpu()?;
    let artifact = load_named(artifact_name)?;
    let mut session = Session::open_eval(&client, artifact, opts.seed)?;
    if let Some(ckpt) = &opts.checkpoint {
        session.store = crate::runtime::params::ParamStore::load(ckpt, &session.artifact)?;
        session.invalidate_state();
    }
    session.ensure_decode(&client)?;
    // §Perf L4: upload the weights once; every subsequent batch reuses
    // the device-resident buffers instead of re-marshalling the full
    // parameter set per decode.
    session.warm_device_cache(&client)?;
    let cfg = session.artifact.config.clone();
    let mut stats = ServerStats::default();

    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all senders dropped -> shutdown
        };
        let t0 = Instant::now();
        let mut pending = vec![first];
        let deadline = Instant::now() + opts.batch_window;
        while pending.len() < cfg.batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Pad/truncate into the fixed (B, enc_len) geometry.
        let fill = pending.len();
        let rows: Vec<&[i32]> = pending.iter().map(|r| r.enc_tokens.as_slice()).collect();
        let (enc, truncated) = pack_requests(&rows, cfg.batch_size, cfg.enc_len);
        let decoded = session.decode(&client, &enc)?;
        let latency = t0.elapsed();
        for (i, req) in pending.into_iter().enumerate() {
            let _ = req.reply.send(Response {
                tokens: decoded[i].clone(),
                latency,
                batch_fill: fill,
                truncated: truncated[i],
            });
        }
        stats.requests += fill;
        stats.batches += 1;
        stats.total_fill += fill;
    }
    Ok(stats)
}

/// Pack request token rows into the fixed (batch_size, enc_len)
/// geometry: short rows are zero-padded, long rows are cut to fit.
/// Returns the flat batch plus a per-row truncation flag.
pub fn pack_requests(
    rows: &[&[i32]],
    batch_size: usize,
    enc_len: usize,
) -> (Vec<i32>, Vec<bool>) {
    let mut enc = vec![0i32; batch_size * enc_len];
    let mut truncated = vec![false; rows.len()];
    for (i, row) in rows.iter().take(batch_size).enumerate() {
        let n = row.len().min(enc_len);
        enc[i * enc_len..i * enc_len + n].copy_from_slice(&row[..n]);
        truncated[i] = row.len() > enc_len;
    }
    (enc, truncated)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_requests_pads_and_flags_truncation() {
        let short = vec![1, 2, 3];
        let exact = vec![5, 6, 7, 8];
        let long = vec![9, 10, 11, 12, 13, 14];
        let rows: Vec<&[i32]> = vec![&short, &exact, &long];
        let (enc, truncated) = pack_requests(&rows, 4, 4);
        assert_eq!(enc.len(), 16);
        assert_eq!(&enc[0..4], &[1, 2, 3, 0], "short row zero-padded");
        assert_eq!(&enc[4..8], &[5, 6, 7, 8], "exact row untouched");
        assert_eq!(&enc[8..12], &[9, 10, 11, 12], "long row cut to enc_len");
        assert_eq!(&enc[12..16], &[0, 0, 0, 0], "unfilled slot stays zero");
        assert_eq!(truncated, vec![false, false, true]);
    }

    #[test]
    fn pack_requests_empty_and_full() {
        let (enc, truncated) = pack_requests(&[], 2, 3);
        assert_eq!(enc, vec![0; 6]);
        assert!(truncated.is_empty());
        let a = vec![1i32; 3];
        let b = vec![2i32; 4];
        let rows: Vec<&[i32]> = vec![&a, &b];
        let (enc, truncated) = pack_requests(&rows, 2, 3);
        assert_eq!(&enc[3..6], &[2, 2, 2]);
        assert_eq!(truncated, vec![false, true]);
    }
}
