//! Threaded inference server with dynamic batching.
//!
//! The PJRT session is !Send (Rc-backed FFI handles), so the server owns
//! client + session on a dedicated model thread; callers submit requests
//! over an mpsc channel and get replies over per-request channels. The
//! batcher groups up to `batch_size` requests within `batch_window`,
//! pads partial batches, and runs one `decode_step` per group — the
//! standard dynamic-batching pattern (vLLM-router-like, scaled to one
//! replica).

use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use crate::runtime::session::Session;
use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

pub struct Request {
    pub enc_tokens: Vec<i32>,
    pub reply: mpsc::Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// Time spent queued + executing, for latency accounting.
    pub latency: Duration,
    pub batch_fill: usize,
}

#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub batch_window: Duration,
    pub seed: u64,
    /// Optional checkpoint to load weights from.
    pub checkpoint: Option<std::path::PathBuf>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions { batch_window: Duration::from_millis(5), seed: 0, checkpoint: None }
    }
}

pub struct ServerHandle {
    pub sender: mpsc::Sender<Request>,
    join: Option<std::thread::JoinHandle<Result<ServerStats>>>,
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub total_fill: usize,
}

impl ServerStats {
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_fill as f64 / self.batches as f64
        }
    }
}

impl ServerHandle {
    /// Spawn the model thread; resolves the artifact by suite name.
    pub fn spawn(artifact_name: &str, opts: ServerOptions) -> ServerHandle {
        let (tx, rx) = mpsc::channel::<Request>();
        let name = artifact_name.to_string();
        let join = std::thread::Builder::new()
            .name("altup-server".into())
            .spawn(move || serve(&name, rx, opts))
            .expect("spawn server");
        ServerHandle { sender: tx, join: Some(join) }
    }

    /// Submit a request and block for the response.
    pub fn infer(&self, enc_tokens: Vec<i32>) -> Result<Response> {
        let (tx, rx) = mpsc::channel();
        self.sender.send(Request { enc_tokens, reply: tx })?;
        Ok(rx.recv()?)
    }

    /// Shut down (drop sender) and collect stats.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        let join = self.join.take().unwrap();
        drop(self.sender);
        join.join().expect("server thread panicked")
    }
}

fn serve(artifact_name: &str, rx: mpsc::Receiver<Request>, opts: ServerOptions) -> Result<ServerStats> {
    let client = Client::cpu()?;
    let artifact = load_named(artifact_name)?;
    let mut session = Session::open_eval(&client, artifact, opts.seed)?;
    if let Some(ckpt) = &opts.checkpoint {
        session.store = crate::runtime::params::ParamStore::load(ckpt, &session.artifact)?;
        session.invalidate_state();
    }
    session.ensure_decode(&client)?;
    let cfg = session.artifact.config.clone();
    let mut stats = ServerStats::default();

    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // all senders dropped -> shutdown
        };
        let t0 = Instant::now();
        let mut pending = vec![first];
        let deadline = Instant::now() + opts.batch_window;
        while pending.len() < cfg.batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Pad the batch geometry: fixed (B, enc_len).
        let fill = pending.len();
        let mut enc = vec![0i32; cfg.batch_size * cfg.enc_len];
        for (i, req) in pending.iter().enumerate() {
            let n = req.enc_tokens.len().min(cfg.enc_len);
            enc[i * cfg.enc_len..i * cfg.enc_len + n].copy_from_slice(&req.enc_tokens[..n]);
        }
        let decoded = session.decode(&client, &enc)?;
        let latency = t0.elapsed();
        for (i, req) in pending.into_iter().enumerate() {
            let _ = req.reply.send(Response {
                tokens: decoded[i].clone(),
                latency,
                batch_fill: fill,
            });
        }
        stats.requests += fill;
        stats.batches += 1;
        stats.total_fill += fill;
    }
    Ok(stats)
}
