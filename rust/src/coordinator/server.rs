//! Multi-replica inference server with shape-bucketed dynamic batching
//! (§Perf L5).
//!
//! The PJRT session is !Send (Rc-backed FFI handles), so each replica
//! owns its client + session on a dedicated model thread. A router
//! thread admits requests continuously, groups them by sequence-length
//! bucket (`runtime::session::bucket_for`), and emits full-or-expired
//! batches onto a shared job queue; the first idle replica picks each
//! job up — the standard continuous-batching layout (vLLM-router-like),
//! scaled to N replicas. A batch of short prompts runs the smallest
//! bucket that fits instead of always padding to `enc_len`, so padded-
//! token waste drops with the workload's length mix.
//!
//! Backends: `EngineSpec::Artifact` serves a compiled artifact through
//! a warmed device cache (§Perf L4); `EngineSpec::Sim` is a
//! deterministic backend-free decode (cost proportional to the executed
//! `batch_size x bucket` geometry) so the scheduler, bucketing, and
//! replica machinery can be exercised and benchmarked without linking
//! the real xla-rs bindings.

use crate::coordinator::metrics::LatencyHistogram;
use crate::runtime::artifact::load_named;
use crate::runtime::client::Client;
use crate::runtime::session::{bucket_for, Session};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub struct Request {
    pub enc_tokens: Vec<i32>,
    pub reply: mpsc::Sender<Response>,
    /// When the request was created (client side), so reported latency
    /// includes time queued in the request channel, not just time after
    /// router admission. `Request::new` stamps it.
    pub t0: Instant,
}

impl Request {
    pub fn new(enc_tokens: Vec<i32>, reply: mpsc::Sender<Response>) -> Request {
        Request { enc_tokens, reply, t0: Instant::now() }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// Time spent queued + executing, for latency accounting.
    pub latency: Duration,
    pub batch_fill: usize,
    /// True when the request's prompt exceeded the model's `enc_len`
    /// and was cut to fit (previously a silent truncation).
    pub truncated: bool,
    /// Sequence-length bucket the request actually executed at.
    pub bucket: usize,
    /// Which model replica served the request.
    pub replica: usize,
}

#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub batch_window: Duration,
    pub seed: u64,
    /// Optional checkpoint to load weights from.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Number of model threads behind the shared router queue.
    /// `ALTUP_SERVER_REPLICAS` sets the default (else 1); 0 means 1.
    pub replicas: usize,
    /// Shape-bucketed batching (default on; `ALTUP_NO_BUCKETS=1` pads
    /// every batch to the full `enc_len` — the A/B baseline).
    pub bucketed: bool,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            batch_window: Duration::from_millis(5),
            seed: 0,
            checkpoint: None,
            replicas: replicas_from_env(),
            bucketed: std::env::var_os("ALTUP_NO_BUCKETS").is_none(),
        }
    }
}

fn replicas_from_env() -> usize {
    std::env::var("ALTUP_SERVER_REPLICAS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Which decode backend the replicas run.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// A compiled artifact by suite name (requires a real PJRT backend).
    Artifact { name: String },
    /// Deterministic backend-free decode with a token-proportional cost
    /// model — for scheduler tests/benches on machines without the
    /// xla-rs bindings.
    Sim(SimSpec),
}

#[derive(Debug, Clone)]
pub struct SimSpec {
    pub batch_size: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    pub vocab_size: usize,
    /// Simulated device nanoseconds per executed token
    /// (`batch_size * bucket` tokens per batch). `ALTUP_SIM_TOKEN_NS`
    /// sets the default (else 20000 — ~20 ms per full (8,128) batch,
    /// in the ballpark of a micro-model CPU decode — so service time,
    /// not router/scheduler overhead, dominates benches even on small
    /// shared machines).
    pub token_ns: u64,
}

impl SimSpec {
    pub fn new(batch_size: usize, enc_len: usize, dec_len: usize) -> SimSpec {
        let token_ns = std::env::var("ALTUP_SIM_TOKEN_NS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(20000);
        SimSpec { batch_size, enc_len, dec_len, vocab_size: 512, token_ns }
    }
}

/// Aggregate serving counters; per-replica stats are merged by
/// `ServerHandle::shutdown`.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub total_fill: usize,
    /// How many replica stat sets were merged in.
    pub replicas: usize,
    /// Real prompt tokens submitted (post-truncation).
    pub prompt_tokens: usize,
    /// Tokens actually executed (`batch_size * effective bucket` per
    /// batch) — the denominator of the padded-waste ratio.
    pub executed_tokens: usize,
    pub truncated: usize,
    /// Per-request queued+executed latency, log-bucketed (O(1) memory
    /// over a server's lifetime, mergeable across replicas).
    pub latency: LatencyHistogram,
}

impl ServerStats {
    pub fn mean_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_fill as f64 / self.batches as f64
        }
    }

    /// Fraction of executed tokens that were padding: 1 - prompt/executed.
    pub fn waste_ratio(&self) -> f64 {
        if self.executed_tokens == 0 {
            0.0
        } else {
            1.0 - self.prompt_tokens as f64 / self.executed_tokens as f64
        }
    }

    /// Number of latency samples recorded (== requests served).
    pub fn latency_count(&self) -> u64 {
        self.latency.count()
    }

    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        self.latency.percentile_ms(p)
    }
    pub fn p50_ms(&self) -> f64 {
        self.latency_percentile_ms(50.0)
    }
    pub fn p95_ms(&self) -> f64 {
        self.latency_percentile_ms(95.0)
    }
    pub fn p99_ms(&self) -> f64 {
        self.latency_percentile_ms(99.0)
    }

    /// Fold another replica's counters into this aggregate.
    pub fn merge(&mut self, other: &ServerStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.total_fill += other.total_fill;
        self.replicas += other.replicas;
        self.prompt_tokens += other.prompt_tokens;
        self.executed_tokens += other.executed_tokens;
        self.truncated += other.truncated;
        self.latency.merge(&other.latency);
    }

    pub fn summary(&self) -> String {
        format!(
            "{} requests / {} batches on {} replica(s), mean fill {:.2}, \
             padded waste {:.1}%, latency p50 {:.2} / p95 {:.2} / p99 {:.2} ms",
            self.requests,
            self.batches,
            self.replicas.max(1),
            self.mean_fill(),
            self.waste_ratio() * 100.0,
            self.p50_ms(),
            self.p95_ms(),
            self.p99_ms()
        )
    }
}

/// A request the router has accepted into a bucket group. Latency is
/// reported from the client-side `Request::t0`; the batch-window
/// deadline runs from `admitted`, so a request that sat in the request
/// channel does not count that wait against its group's window (which
/// would ship burst arrivals as tiny immediately-due batches).
struct Admitted {
    req: Request,
    admitted: Instant,
}

/// A bucket-homogeneous batch ready for a replica.
struct BatchJob {
    bucket: usize,
    requests: Vec<Admitted>,
}

pub struct ServerHandle {
    pub sender: mpsc::Sender<Request>,
    router: Option<std::thread::JoinHandle<Result<()>>>,
    replicas: Vec<std::thread::JoinHandle<Result<ServerStats>>>,
}

impl ServerHandle {
    /// Spawn router + replicas serving the named artifact.
    pub fn spawn(artifact_name: &str, opts: ServerOptions) -> ServerHandle {
        ServerHandle::spawn_engine(
            EngineSpec::Artifact { name: artifact_name.to_string() },
            opts,
        )
    }

    /// Spawn router + replicas over an explicit decode backend.
    pub fn spawn_engine(engine: EngineSpec, opts: ServerOptions) -> ServerHandle {
        let n = opts.replicas.max(1);
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        // Bounded job queue = backpressure: when every replica is busy
        // and the queue is full, the router keeps accumulating instead
        // of window-flushing tiny partial batches at a wall of busy
        // replicas (which craters fill and wastes executed tokens).
        let (job_tx, job_rx) = mpsc::sync_channel::<BatchJob>(n);
        let job_rx = Arc::new(Mutex::new(job_rx));

        let router = {
            let spec = engine.clone();
            let ropts = opts.clone();
            std::thread::Builder::new()
                .name("altup-router".into())
                .spawn(move || route(&spec, req_rx, job_tx, &ropts))
                .expect("spawn router")
        };
        let replicas = (0..n)
            .map(|i| {
                let spec = engine.clone();
                let jobs = Arc::clone(&job_rx);
                let sopts = opts.clone();
                std::thread::Builder::new()
                    .name(format!("altup-replica-{i}"))
                    .spawn(move || serve_replica(i, &spec, &jobs, &sopts))
                    .expect("spawn replica")
            })
            .collect();
        ServerHandle { sender: req_tx, router: Some(router), replicas }
    }

    /// Submit a request and block for the response. Returns an error
    /// (rather than hanging) when the router or the serving replica has
    /// died — the reply channel is dropped with the request.
    pub fn infer(&self, enc_tokens: Vec<i32>) -> Result<Response> {
        let (tx, rx) = mpsc::channel();
        self.sender
            .send(Request::new(enc_tokens, tx))
            .map_err(|_| anyhow!("server router is down; request not admitted"))?;
        rx.recv().map_err(|_| {
            anyhow!("model replica died before replying (shutdown() reports the cause)")
        })
    }

    /// Shut down (drop sender, drain, join) and return merged stats
    /// from every replica.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        let router = self.router.take().expect("router handle");
        let replicas = std::mem::take(&mut self.replicas);
        drop(self.sender);
        let mut first_err: Option<anyhow::Error> = None;
        match router.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => first_err = Some(e),
            Err(_) => first_err = Some(anyhow!("router thread panicked")),
        }
        let mut merged = ServerStats::default();
        for handle in replicas {
            match handle.join() {
                Ok(Ok(stats)) => merged.merge(&stats),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("replica thread panicked"));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(merged),
        }
    }
}

/// Router loop: admit continuously, group by bucket, and hand batches
/// to the replicas. A group ships as soon as it fills (blocking send —
/// genuine backpressure once the bounded job queue is full). A group
/// whose oldest request has waited out the batch window ships
/// best-effort (`try_send`): if every replica is busy and the queue is
/// full it simply keeps accumulating — arriving requests top it up
/// toward a full batch instead of the router spraying tiny partial
/// batches at a wall of busy replicas.
fn route(
    spec: &EngineSpec,
    rx: mpsc::Receiver<Request>,
    tx: mpsc::SyncSender<BatchJob>,
    opts: &ServerOptions,
) -> Result<()> {
    let (batch_size, enc_len) = match spec {
        EngineSpec::Artifact { name } => {
            let artifact = load_named(name)?;
            (artifact.config.batch_size, artifact.config.enc_len)
        }
        EngineSpec::Sim(s) => (s.batch_size, s.enc_len),
    };
    let mut groups: BTreeMap<usize, Vec<Admitted>> = BTreeMap::new();
    let mut disconnected = false;
    while !(disconnected && groups.is_empty()) {
        // Flush pass. In drain mode (clients gone) everything ships
        // with a blocking send.
        let now = Instant::now();
        let mut due_unsent = false;
        let buckets: Vec<usize> = groups.keys().copied().collect();
        for bucket in buckets {
            let group = groups.get(&bucket).expect("group present");
            let full = group.len() >= batch_size;
            let due =
                group.first().map_or(false, |a| now >= a.admitted + opts.batch_window);
            if full || disconnected {
                let requests = groups.remove(&bucket).expect("group present");
                if tx.send(BatchJob { bucket, requests }).is_err() {
                    return Ok(()); // every replica is gone
                }
            } else if due {
                let requests = groups.remove(&bucket).expect("group present");
                match tx.try_send(BatchJob { bucket, requests }) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(job)) => {
                        groups.insert(bucket, job.requests);
                        due_unsent = true;
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return Ok(()),
                }
            }
        }
        if disconnected {
            continue; // drain until groups run dry
        }

        // Admit pass: block until the next request, the next group
        // deadline, or (when a due group couldn't ship) a short park so
        // the flush retries once a replica frees up.
        let message = if groups.is_empty() {
            match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => {
                    disconnected = true;
                    None
                }
            }
        } else {
            let wait = if due_unsent {
                // Floor the park so a zero batch window cannot busy-spin
                // while replicas are saturated and the job queue is full.
                opts.batch_window.max(Duration::from_micros(200))
            } else {
                let oldest = groups
                    .values()
                    .filter_map(|g| g.first())
                    .map(|a| a.admitted)
                    .min()
                    .expect("non-empty groups");
                (oldest + opts.batch_window).saturating_duration_since(Instant::now())
            };
            if wait.is_zero() {
                None // a group came due during the flush pass
            } else {
                match rx.recv_timeout(wait) {
                    Ok(r) => Some(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                        None
                    }
                }
            }
        };
        if let Some(req) = message {
            let bucket = if opts.bucketed {
                bucket_for(req.enc_tokens.len(), enc_len)
            } else {
                enc_len
            };
            groups
                .entry(bucket)
                .or_default()
                .push(Admitted { req, admitted: Instant::now() });
        }
    }
    Ok(())
}

/// The per-replica decode backend (built inside the replica thread:
/// `Session` is !Send).
enum Engine {
    Real { client: Client, session: Session },
    Sim(SimSpec),
}

impl Engine {
    fn build(spec: &EngineSpec, opts: &ServerOptions) -> Result<Engine> {
        match spec {
            EngineSpec::Artifact { name } => {
                let client = Client::cpu()?;
                let artifact = load_named(name)?;
                let mut session = Session::open_eval(&client, artifact, opts.seed)?;
                if let Some(ckpt) = &opts.checkpoint {
                    session.store =
                        crate::runtime::params::ParamStore::load(ckpt, &session.artifact)?;
                    session.invalidate_state();
                }
                session.ensure_decode(&client)?;
                // §Perf L4: upload the weights once; every batch reuses
                // the device-resident buffers.
                session.warm_device_cache(&client)?;
                Ok(Engine::Real { client, session })
            }
            EngineSpec::Sim(s) => Ok(Engine::Sim(s.clone())),
        }
    }

    /// (batch_size, enc_len) of the serving geometry.
    fn dims(&self) -> (usize, usize) {
        match self {
            Engine::Real { session, .. } => {
                (session.artifact.config.batch_size, session.artifact.config.enc_len)
            }
            Engine::Sim(s) => (s.batch_size, s.enc_len),
        }
    }

    /// The sequence length a job at `bucket` actually executes at (the
    /// real backend falls back to `enc_len` when the artifact has no
    /// shape-specialized HLO for the bucket).
    fn effective_bucket(&self, bucket: usize) -> usize {
        match self {
            Engine::Real { session, .. } => session.effective_bucket(bucket),
            Engine::Sim(s) => bucket.min(s.enc_len),
        }
    }

    /// Decode a (batch_size, bucket) packed batch.
    fn decode(&mut self, enc: &[i32], bucket: usize) -> Result<Vec<Vec<i32>>> {
        match self {
            Engine::Real { client, session } => session.decode_bucketed(client, enc, bucket),
            Engine::Sim(s) => Ok(sim_decode(s, enc, bucket)),
        }
    }
}

/// Deterministic stand-in decode: each output row is a hash function of
/// the row's non-padding prompt tokens only, so results are identical
/// no matter which bucket executed them (the parity contract real
/// bucketed decode must also satisfy). Costs a simulated
/// `token_ns * batch_size * bucket` of device time.
fn sim_decode(spec: &SimSpec, enc: &[i32], bucket: usize) -> Vec<Vec<i32>> {
    let mut out = Vec::with_capacity(spec.batch_size);
    for row in enc.chunks(bucket) {
        let used = row.iter().rposition(|&t| t != 0).map_or(0, |i| i + 1);
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &t in &row[..used] {
            h = (h ^ t as u32 as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        let mut tokens = Vec::with_capacity(spec.dec_len);
        for j in 0..spec.dec_len {
            let mut x = h.wrapping_mul(j as u64 + 1).wrapping_add(0x9E37_79B9_7F4A_7C15);
            x ^= x >> 29;
            tokens.push((x % (spec.vocab_size.max(2) as u64 - 1)) as i32 + 1);
        }
        out.push(tokens);
    }
    let ns = spec.token_ns.saturating_mul((spec.batch_size * bucket) as u64);
    if ns > 0 {
        std::thread::sleep(Duration::from_nanos(ns));
    }
    out
}

/// Replica loop: pop bucket-homogeneous jobs off the shared queue, pack
/// at the (effective) bucket geometry, decode, and move each output row
/// into its reply (no per-row clone).
fn serve_replica(
    id: usize,
    spec: &EngineSpec,
    jobs: &Arc<Mutex<mpsc::Receiver<BatchJob>>>,
    opts: &ServerOptions,
) -> Result<ServerStats> {
    let mut engine = Engine::build(spec, opts)?;
    let (batch_size, _enc_len) = engine.dims();
    let mut stats = ServerStats { replicas: 1, ..Default::default() };
    loop {
        // Hold the queue lock only for the pop; decode runs unlocked so
        // other replicas pull the next job meanwhile.
        let job = {
            let queue = jobs.lock().map_err(|_| anyhow!("job queue poisoned"))?;
            match queue.recv() {
                Ok(job) => job,
                Err(_) => break, // router gone and queue drained
            }
        };
        let fill = job.requests.len();
        let bucket = engine.effective_bucket(job.bucket);
        let (enc, truncated) = {
            let rows: Vec<&[i32]> =
                job.requests.iter().map(|a| a.req.enc_tokens.as_slice()).collect();
            pack_requests(&rows, batch_size, bucket)
        };
        let decoded = engine.decode(&enc, bucket)?;
        let mut decoded = decoded.into_iter();
        for (i, admitted) in job.requests.into_iter().enumerate() {
            let req = admitted.req;
            let latency = req.t0.elapsed();
            stats.prompt_tokens += req.enc_tokens.len().min(bucket);
            stats.latency.record(latency.as_secs_f64() * 1e3);
            if truncated[i] {
                stats.truncated += 1;
            }
            let _ = req.reply.send(Response {
                tokens: decoded.next().unwrap_or_default(),
                latency,
                batch_fill: fill,
                truncated: truncated[i],
                bucket,
                replica: id,
            });
        }
        stats.requests += fill;
        stats.batches += 1;
        stats.total_fill += fill;
        stats.executed_tokens += batch_size * bucket;
    }
    Ok(stats)
}

/// Pack request token rows into a fixed (batch_size, len) geometry:
/// short rows are zero-padded, long rows are cut to fit. `len` is the
/// full `enc_len` or any smaller bucket the group was routed to.
/// Returns the flat batch plus a per-row truncation flag.
pub fn pack_requests(
    rows: &[&[i32]],
    batch_size: usize,
    len: usize,
) -> (Vec<i32>, Vec<bool>) {
    let mut enc = vec![0i32; batch_size * len];
    let mut truncated = vec![false; rows.len()];
    for (i, row) in rows.iter().take(batch_size).enumerate() {
        let n = row.len().min(len);
        enc[i * len..i * len + n].copy_from_slice(&row[..n]);
        truncated[i] = row.len() > len;
    }
    (enc, truncated)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_requests_pads_and_flags_truncation() {
        let short = vec![1, 2, 3];
        let exact = vec![5, 6, 7, 8];
        let long = vec![9, 10, 11, 12, 13, 14];
        let rows: Vec<&[i32]> = vec![&short, &exact, &long];
        let (enc, truncated) = pack_requests(&rows, 4, 4);
        assert_eq!(enc.len(), 16);
        assert_eq!(&enc[0..4], &[1, 2, 3, 0], "short row zero-padded");
        assert_eq!(&enc[4..8], &[5, 6, 7, 8], "exact row untouched");
        assert_eq!(&enc[8..12], &[9, 10, 11, 12], "long row cut to enc_len");
        assert_eq!(&enc[12..16], &[0, 0, 0, 0], "unfilled slot stays zero");
        assert_eq!(truncated, vec![false, false, true]);
    }

    #[test]
    fn pack_requests_empty_and_full() {
        let (enc, truncated) = pack_requests(&[], 2, 3);
        assert_eq!(enc, vec![0; 6]);
        assert!(truncated.is_empty());
        let a = vec![1i32; 3];
        let b = vec![2i32; 4];
        let rows: Vec<&[i32]> = vec![&a, &b];
        let (enc, truncated) = pack_requests(&rows, 2, 3);
        assert_eq!(&enc[3..6], &[2, 2, 2]);
        assert_eq!(truncated, vec![false, true]);
    }

    #[test]
    fn pack_requests_at_smaller_bucket() {
        let a = vec![1, 2, 3];
        let rows: Vec<&[i32]> = vec![&a];
        let (enc, truncated) = pack_requests(&rows, 2, 8);
        assert_eq!(enc.len(), 16, "bucket stride, not enc_len stride");
        assert_eq!(&enc[0..4], &[1, 2, 3, 0]);
        assert_eq!(truncated, vec![false]);
    }

    #[test]
    fn sim_decode_is_bucket_invariant_and_deterministic() {
        let spec = SimSpec { batch_size: 2, enc_len: 32, dec_len: 6, vocab_size: 97, token_ns: 0 };
        let prompt: Vec<i32> = vec![4, 9, 1, 7];
        let pad_to = |len: usize| {
            let mut v = prompt.clone();
            v.resize(len, 0);
            v
        };
        let mut small = pad_to(8);
        small.extend(pad_to(8));
        let mut full = pad_to(32);
        full.extend(pad_to(32));
        let a = sim_decode(&spec, &small, 8);
        let b = sim_decode(&spec, &full, 32);
        assert_eq!(a, b, "output depends only on the unpadded prompt");
        assert_eq!(a[0].len(), 6);
        assert!(a[0].iter().all(|&t| t >= 1 && (t as usize) < 97));
        // Different prompts decode differently (not a constant).
        let mut other = vec![5i32, 5, 5, 0, 0, 0, 0, 0];
        other.extend(pad_to(8));
        assert_ne!(sim_decode(&spec, &other, 8)[0], a[0]);
    }

    #[test]
    fn server_stats_merge_waste_and_percentiles() {
        let mut a = ServerStats {
            requests: 4,
            batches: 2,
            total_fill: 4,
            replicas: 1,
            prompt_tokens: 40,
            executed_tokens: 64,
            truncated: 1,
            ..Default::default()
        };
        for ms in [1.0, 2.0, 3.0, 4.0] {
            a.latency.record(ms);
        }
        let mut b = ServerStats {
            requests: 2,
            batches: 1,
            total_fill: 2,
            replicas: 1,
            prompt_tokens: 10,
            executed_tokens: 36,
            truncated: 0,
            ..Default::default()
        };
        b.latency.record(10.0);
        b.latency.record(20.0);
        a.merge(&b);
        assert_eq!(a.requests, 6);
        assert_eq!(a.batches, 3);
        assert_eq!(a.replicas, 2);
        assert_eq!(a.truncated, 1);
        assert_eq!(a.latency_count(), 6);
        assert!((a.waste_ratio() - 0.5).abs() < 1e-12, "50/100 executed tokens were padding");
        // Log-bucketed estimates: within the histogram's ~9% error.
        let p50 = a.p50_ms();
        assert!((p50 - 3.0).abs() / 3.0 < 0.10, "p50={p50}");
        let p100 = a.latency_percentile_ms(100.0);
        assert!((p100 - 20.0).abs() / 20.0 < 0.10, "p100={p100}");
        assert_eq!(ServerStats::default().waste_ratio(), 0.0);
        assert_eq!(ServerStats::default().p99_ms(), 0.0);
    }
}
