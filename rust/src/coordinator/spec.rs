//! §L8 speculative decoding: the per-slot draft/verify state machine
//! that rides on the continuous-batching engine (`coordinator::server`).
//!
//! AltUp's predict-and-correct mechanism applied to serving (PAPER.md
//! §3; cf. Pope et al. 2022 for the serving-side framing): a cheap
//! draft model advances every live slot by γ proposed tokens (γ cheap
//! draft-model steps), then ONE fused full-model `verify@γ` step
//! scores all proposals across all active slots, accepting the longest
//! prefix greedy full-model decode would have emitted and supplying
//! the next token (the "correction") itself. Each verify round thus
//! delivers between 1 and γ+1 tokens per live slot for the price of
//! one full-model step plus γ draft steps — while the emitted stream
//! stays token-for-token identical to plain greedy decode: accepted
//! tokens ARE the full model's greedy tokens, and the round's final
//! token always comes from the full model.
//!
//! The per-round state machine, over all live slots at once:
//!
//! ```text
//!   draft γ tokens ────► fused verify@γ ────► emit accepted prefix
//!   (draft model,        (full model,          + 1 correction token
//!    γ cheap steps)       ONE step, all slots)   per live slot
//! ```
//!
//! The server (`serve_continuous`) keeps owning slot admission and
//! retirement: it truncates each slot's emission at EOS or `dec_len`
//! and retires the slot exactly as on the plain path, so deadlines,
//! drain, and crash recovery are untouched by speculation. When the
//! artifact ships no draft (or the sim spec carries no draft cost
//! model), `Engine::effective_spec_gamma` resolves to 0 and the
//! replica falls back to plain per-token decode.

use crate::coordinator::metrics::SpecMeter;
use crate::coordinator::server::{Engine, SlotState};
use crate::coordinator::trace::{Phase, PhaseBreakdown};
use crate::util::env;
use anyhow::Result;
use std::time::Instant;

/// The serving-default draft length: `ALTUP_SPEC_GAMMA` (0 or unset =
/// speculative decoding off).
pub fn gamma_from_env() -> usize {
    env::usize_or("ALTUP_SPEC_GAMMA", 0)
}

/// Per-replica speculative-decode driver: owns the draft length γ and
/// runs one draft→verify round per decode iteration.
pub(crate) struct SpecDecoder {
    gamma: usize,
}

impl SpecDecoder {
    pub(crate) fn new(gamma: usize) -> SpecDecoder {
        SpecDecoder { gamma: gamma.max(1) }
    }

    pub(crate) fn gamma(&self) -> usize {
        self.gamma
    }

    /// §L10: retune the draft length mid-serve (the overload
    /// controller halves γ under sustained pressure and restores it
    /// when calm). Clamped to ≥ 1 — γ 0 means "speculation off", which
    /// is a replica-startup decision, not a per-round one.
    pub(crate) fn set_gamma(&mut self, gamma: usize) {
        self.gamma = gamma.max(1);
    }

    /// One draft→verify round over every live slot. Returns the
    /// per-slot emission — the accepted drafted prefix plus the
    /// correction token; empty rows for dead slots. The caller pushes
    /// tokens into each slot's stream, truncating at EOS/`dec_len`,
    /// retires slots exactly as under plain decode, and reports the
    /// tokens it actually delivered via `SpecMeter::note_delivered`
    /// (the round fills every meter counter except that one — only
    /// the serving loop knows the truncation).
    ///
    /// `page_table` is the flattened (S, max_pages) slot-to-pool
    /// mapping when the replica serves on the §L9 paged path (`None`
    /// on the monolithic path): the full-model verify then runs as
    /// `verify_paged`, while the draft keeps its own monolithic slot
    /// state either way — prefix reuse applies to the main model's KV,
    /// not the draft's.
    ///
    /// `trace` (§L13) splits the round's wall time into the nested
    /// `spec-draft` / `spec-verify` phases when the replica serves
    /// with tracing on; `None` keeps the round timestamp-free.
    pub(crate) fn round(
        &mut self,
        engine: &mut Engine,
        state: &mut SlotState,
        live: &[bool],
        page_table: Option<&[i32]>,
        meter: &mut SpecMeter,
        trace: Option<&mut PhaseBreakdown>,
    ) -> Result<Vec<Vec<i32>>> {
        let t_draft = trace.is_some().then(Instant::now);
        let drafted = engine.draft_tokens(state, live, self.gamma)?;
        let t_verify = trace.is_some().then(Instant::now);
        let (accept, correction) = match page_table {
            Some(table) => engine.verify_paged(state, &drafted, live, self.gamma, table)?,
            None => engine.verify(state, &drafted, live, self.gamma)?,
        };
        if let (Some(phases), Some(t0), Some(t1)) = (trace, t_draft, t_verify) {
            phases.add(Phase::SpecDraft, (t1 - t0).as_nanos() as u64);
            phases.add(Phase::SpecVerify, t1.elapsed().as_nanos() as u64);
        }
        meter.draft_steps += self.gamma as u64;
        meter.verify_steps += 1;
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); live.len()];
        for (s, emitted) in out.iter_mut().enumerate() {
            if !live[s] {
                continue;
            }
            // Clamp defensively: a buggy verify result must degrade to
            // bad accounting, not panic the replica out of its slots.
            let a = (accept[s].max(0) as usize).min(self.gamma).min(drafted[s].len());
            meter.drafted += self.gamma as u64;
            meter.accepted += a as u64;
            emitted.reserve_exact(a + 1);
            emitted.extend_from_slice(&drafted[s][..a]);
            emitted.push(correction[s]);
        }
        Ok(out)
    }
}

// The state machine's behavioral tests (round-level parity with plain
// decode, acceptance extremes, meter accounting) live in
// `coordinator::server::tests` alongside the sim engine they drive;
// end-to-end spec-vs-plain serving parity is pinned by
// `rust/tests/server.rs`.
