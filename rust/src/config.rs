//! Model / experiment configuration, mirroring `python/compile/configs.py`.
//!
//! The rust side never *constructs* model configs from scratch for the
//! runtime — it reads the authoritative copy out of each artifact's
//! `meta.json` — but experiments use these structs for analytic
//! accounting (param counts, FLOPs, roofline) including at paper scale
//! where no artifact exists.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Variant {
    Baseline,
    DenseWide,
    AltUp,
    SameUp,
    Sum,
    Recycled,
    SeqAltUp,
    StrideSkip,
    AvgPool,
}

impl Variant {
    pub fn from_str(s: &str) -> Result<Variant> {
        Ok(match s {
            "baseline" => Variant::Baseline,
            "dense_wide" => Variant::DenseWide,
            "altup" => Variant::AltUp,
            "sameup" => Variant::SameUp,
            "sum" => Variant::Sum,
            "recycled" => Variant::Recycled,
            "seq_altup" => Variant::SeqAltUp,
            "stride_skip" => Variant::StrideSkip,
            "avg_pool" => Variant::AvgPool,
            _ => bail!("unknown variant: {s}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Baseline => "baseline",
            Variant::DenseWide => "dense_wide",
            Variant::AltUp => "altup",
            Variant::SameUp => "sameup",
            Variant::Sum => "sum",
            Variant::Recycled => "recycled",
            Variant::SeqAltUp => "seq_altup",
            Variant::StrideSkip => "stride_skip",
            Variant::AvgPool => "avg_pool",
        }
    }

    /// Does the representation carry K blocks between layers?
    pub fn is_block_widened(&self) -> bool {
        matches!(self, Variant::AltUp | Variant::SameUp | Variant::Recycled)
    }
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub d_ff: usize,
    pub num_heads: usize,
    pub d_head: usize,
    pub enc_layers: usize,
    pub dec_layers: usize,
    pub vocab_size: usize,
    pub rel_pos_buckets: usize,
    pub enc_len: usize,
    pub dec_len: usize,
    pub batch_size: usize,
    pub variant: Variant,
    pub k: usize,
    pub seq_stride: usize,
    pub moe: bool,
    pub moe_experts: usize,
    pub moe_hidden: usize,
    pub dropout: f64,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        let g = |k: &str| -> Result<usize> {
            j.get(k).as_usize().with_context(|| format!("config field {k}"))
        };
        Ok(ModelConfig {
            name: j.get("name").as_str().unwrap_or("unnamed").to_string(),
            d_model: g("d_model")?,
            d_ff: g("d_ff")?,
            num_heads: g("num_heads")?,
            d_head: g("d_head")?,
            enc_layers: g("enc_layers")?,
            dec_layers: g("dec_layers")?,
            vocab_size: g("vocab_size")?,
            rel_pos_buckets: g("rel_pos_buckets")?,
            enc_len: g("enc_len")?,
            dec_len: g("dec_len")?,
            batch_size: g("batch_size")?,
            variant: Variant::from_str(j.get("variant").as_str().context("variant")?)?,
            k: g("k")?,
            seq_stride: g("seq_stride")?,
            moe: j.get("moe").as_bool().unwrap_or(false),
            moe_experts: g("moe_experts").unwrap_or(16),
            moe_hidden: g("moe_hidden").unwrap_or(16),
            dropout: j.get("dropout").as_f64().unwrap_or(0.0),
        })
    }

    /// Width of each transformer layer (paper's d_model).
    pub fn layer_width(&self) -> usize {
        match self.variant {
            Variant::DenseWide => self.k * self.d_model,
            _ => self.d_model,
        }
    }

    /// Width of the carried token representation.
    pub fn repr_width(&self) -> usize {
        match self.variant {
            Variant::AltUp | Variant::SameUp | Variant::Recycled | Variant::DenseWide => {
                self.k * self.d_model
            }
            _ => self.d_model,
        }
    }

    pub fn tokens_per_batch(&self) -> usize {
        self.batch_size * (self.enc_len + self.dec_len)
    }
}

/// Paper-scale T5 presets, mirroring `python/compile/configs.py::SIZES`
/// with the paper's layer counts (S is 4+4 per App. A).
pub fn paper_preset(size: &str, variant: Variant, k: usize) -> ModelConfig {
    let (d_model, d_ff, num_heads, d_head, enc_layers, dec_layers) = match size {
        "S" => (512, 1024, 6, 64, 4, 4),
        "B" => (768, 2048, 12, 64, 12, 12),
        "L" => (1024, 2816, 16, 64, 24, 24),
        "XL" => (2048, 5120, 32, 64, 24, 24),
        _ => panic!("unknown paper size {size}"),
    };
    ModelConfig {
        name: format!("paper-{size}-{}", variant.as_str()),
        d_model,
        d_ff,
        num_heads,
        d_head,
        enc_layers,
        dec_layers,
        vocab_size: 32128,
        rel_pos_buckets: 32,
        enc_len: 512,
        dec_len: 114,
        batch_size: 256,
        variant,
        k,
        seq_stride: 4,
        moe: false,
        moe_experts: 128,
        moe_hidden: 16,
        dropout: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_roundtrip() {
        for s in [
            "baseline", "dense_wide", "altup", "sameup", "sum", "recycled",
            "seq_altup", "stride_skip", "avg_pool",
        ] {
            assert_eq!(Variant::from_str(s).unwrap().as_str(), s);
        }
        assert!(Variant::from_str("bogus").is_err());
    }

    #[test]
    fn widths() {
        let mut c = paper_preset("S", Variant::AltUp, 2);
        assert_eq!(c.layer_width(), 512);
        assert_eq!(c.repr_width(), 1024);
        c.variant = Variant::DenseWide;
        assert_eq!(c.layer_width(), 1024);
        c.variant = Variant::Baseline;
        assert_eq!(c.repr_width(), 512);
    }

    #[test]
    fn from_json_parses_meta_config() {
        let j = Json::parse(
            r#"{"name":"x","d_model":64,"d_ff":128,"num_heads":4,"d_head":16,
                "enc_layers":2,"dec_layers":2,"vocab_size":2048,
                "rel_pos_buckets":32,"rel_pos_max_dist":128,"enc_len":64,
                "dec_len":32,"batch_size":8,"variant":"altup","k":2,
                "seq_stride":4,"seq_first_layer":1,"moe":false,
                "moe_experts":16,"moe_hidden":16,"kernels":"jnp",
                "dropout":0.0,"label_smoothing":0.0,"tie_embeddings":false}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.variant, Variant::AltUp);
        assert_eq!(c.repr_width(), 128);
    }
}
