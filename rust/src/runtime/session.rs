//! Typed step sessions: the bridge between the coordinator's training
//! loop and the compiled HLO executables.
//!
//! A `Session` owns the param store and the compiled train/eval/decode
//! executables for one artifact, and marshals the flat input/output
//! signature recorded in meta.json:
//!
//!   train:  (params..., opt..., step, lr, seed, enc, dec_in, dec_tgt)
//!           -> (params'..., opt'..., loss, correct, ntok)
//!   eval:   (params..., enc, dec_in, dec_tgt) -> (loss_sum, correct, ntok)
//!   decode: (params..., enc) -> (tokens,)

use crate::data::batcher::Batch;
use crate::runtime::artifact::Artifact;
use crate::runtime::client::{Client, Executable};
use crate::runtime::params::ParamStore;
use crate::runtime::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::rc::Rc;
use std::time::Instant;

pub struct Session {
    pub artifact: Artifact,
    pub store: ParamStore,
    train: Option<Rc<Executable>>,
    eval: Option<Rc<Executable>>,
    decode: Option<Rc<Executable>>,
    forward: Option<Rc<Executable>>,
    /// §Perf (L3): params/opt kept as XLA literals between train steps,
    /// skipping the literal -> Vec<f32> -> literal round-trip that
    /// dominated marshalling time (2 full copies of all parameters per
    /// step). `state_step` records the store step the cache mirrors; a
    /// mismatch (e.g. after loading a checkpoint) invalidates it.
    state: Option<(Vec<xla::Literal>, Vec<xla::Literal>)>,
    state_step: u64,
    /// Wall-clock spent inside PJRT execute (per step kind).
    pub exec_seconds: f64,
    /// Wall-clock spent marshalling literals.
    pub marshal_seconds: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct StepMetrics {
    pub loss: f32,
    pub correct: f32,
    pub ntok: f32,
}

impl StepMetrics {
    pub fn accuracy(&self) -> f32 {
        if self.ntok > 0.0 {
            self.correct / self.ntok
        } else {
            0.0
        }
    }
}

impl Session {
    /// Load + compile the artifact's executables (lazily per kind).
    pub fn open(client: &Client, artifact: Artifact, seed: u64) -> Result<Session> {
        let store = ParamStore::init(&artifact, seed);
        let mut s = Session {
            artifact,
            store,
            train: None,
            eval: None,
            decode: None,
            forward: None,
            state: None,
            state_step: 0,
            exec_seconds: 0.0,
            marshal_seconds: 0.0,
        };
        // Compile the train step eagerly: it is the common case and we
        // want compile failures surfaced at open().
        s.train = Some(s.compile(client, "train_step")?);
        Ok(s)
    }

    /// Open for inference/eval only (no train executable).
    pub fn open_eval(_client: &Client, artifact: Artifact, seed: u64) -> Result<Session> {
        let store = ParamStore::init(&artifact, seed);
        Ok(Session {
            artifact,
            store,
            train: None,
            eval: None,
            decode: None,
            forward: None,
            state: None,
            state_step: 0,
            exec_seconds: 0.0,
            marshal_seconds: 0.0,
        })
    }

    /// Drop the cached literal state (call after replacing `store`).
    pub fn invalidate_state(&mut self) {
        self.state = None;
    }

    fn state_is_fresh(&self) -> bool {
        // ALTUP_NO_STATE_CACHE=1 disables the cache (perf A/B switch
        // used by the §Perf log in EXPERIMENTS.md).
        if std::env::var_os("ALTUP_NO_STATE_CACHE").is_some() {
            return false;
        }
        self.state.is_some() && self.state_step == self.store.step
    }

    /// Write the cached literal state back into the host param store
    /// (no-op if the cache is absent or stale). Must be called before
    /// reading `store.params` after training — `checkpoint()` and the
    /// eval paths do so automatically.
    pub fn sync_store(&mut self) -> Result<()> {
        if !self.state_is_fresh() {
            return Ok(());
        }
        let (params, opt) = self.state.as_ref().unwrap();
        for (i, lit) in params.iter().enumerate() {
            self.store.params[i] = Tensor::from_literal(lit)?;
        }
        for (i, lit) in opt.iter().enumerate() {
            self.store.opt[i] = Tensor::from_literal(lit)?;
        }
        Ok(())
    }

    /// Sync + save a checkpoint.
    pub fn checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.sync_store()?;
        self.store.save(path)
    }

    /// Upload params from the host store unless the cache is fresh (in
    /// which case the caller chains refs to the cache instead).
    fn upload_params_if_stale(&self) -> Result<Vec<xla::Literal>> {
        if self.state_is_fresh() {
            Ok(Vec::new())
        } else {
            self.store.params.iter().map(|t| t.to_literal()).collect()
        }
    }

    fn compile(&self, client: &Client, kind: &str) -> Result<Rc<Executable>> {
        let key = format!("{}:{}", self.artifact.name, kind);
        client.compile_hlo(&key, self.artifact.hlo_path(kind)?)
    }

    pub fn ensure_eval(&mut self, client: &Client) -> Result<()> {
        if self.eval.is_none() {
            self.eval = Some(self.compile(client, "eval_step")?);
        }
        Ok(())
    }
    pub fn ensure_decode(&mut self, client: &Client) -> Result<()> {
        if self.decode.is_none() {
            self.decode = Some(self.compile(client, "decode_step")?);
        }
        Ok(())
    }
    pub fn ensure_forward(&mut self, client: &Client) -> Result<()> {
        if self.forward.is_none() {
            self.forward = Some(self.compile(client, "forward")?);
        }
        Ok(())
    }

    fn batch_literals(&self, batch: &Batch) -> Result<Vec<xla::Literal>> {
        let cfg = &self.artifact.config;
        if batch.enc_tokens.len() != cfg.batch_size * cfg.enc_len {
            bail!(
                "batch enc size {} != {}x{}",
                batch.enc_tokens.len(),
                cfg.batch_size,
                cfg.enc_len
            );
        }
        let enc = Tensor::i32(vec![cfg.batch_size, cfg.enc_len], batch.enc_tokens.clone());
        let dec_in = Tensor::i32(vec![cfg.batch_size, cfg.dec_len], batch.dec_input.clone());
        let dec_tgt = Tensor::i32(vec![cfg.batch_size, cfg.dec_len], batch.dec_targets.clone());
        Ok(vec![enc.to_literal()?, dec_in.to_literal()?, dec_tgt.to_literal()?])
    }

    /// One optimizer step. Keeps params/opt as cached literals between
    /// steps (§Perf L3); the host store is synced lazily via
    /// `sync_store()` / `checkpoint()`.
    pub fn train_step(&mut self, lr: f32, seed: u32, batch: &Batch) -> Result<StepMetrics> {
        let exe = Rc::clone(self.train.as_ref().context("train exe not compiled")?);
        let np = self.store.params.len();
        let no = self.store.opt.len();

        let t0 = Instant::now();
        let use_cache = self.state_is_fresh();
        let mut scratch: Vec<xla::Literal> = Vec::with_capacity(if use_cache {
            6
        } else {
            np + no + 6
        });
        if !use_cache {
            for t in &self.store.params {
                scratch.push(t.to_literal()?);
            }
            for t in &self.store.opt {
                scratch.push(t.to_literal()?);
            }
        }
        let step_f = (self.store.step + 1) as f32;
        scratch.push(Tensor::scalar_f32(step_f).to_literal()?);
        scratch.push(Tensor::scalar_f32(lr).to_literal()?);
        scratch.push(Tensor::scalar_u32(seed).to_literal()?);
        scratch.extend(self.batch_literals(batch)?);
        let refs: Vec<&xla::Literal> = if use_cache {
            let (p, o) = self.state.as_ref().unwrap();
            p.iter().chain(o.iter()).chain(scratch.iter()).collect()
        } else {
            scratch.iter().collect()
        };
        self.marshal_seconds += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let mut outs = exe.run(&refs)?;
        self.exec_seconds += t1.elapsed().as_secs_f64();

        if outs.len() != np + no + 3 {
            bail!("train_step returned {} outputs, expected {}", outs.len(), np + no + 3);
        }
        let t2 = Instant::now();
        let metrics = outs.split_off(np + no);
        let opt_lits = outs.split_off(np);
        if std::env::var_os("ALTUP_NO_STATE_CACHE").is_some() {
            // A/B mode: full host round-trip, as before the §Perf pass.
            for (i, lit) in outs.iter().enumerate() {
                self.store.params[i] = Tensor::from_literal(lit)?;
            }
            for (i, lit) in opt_lits.iter().enumerate() {
                self.store.opt[i] = Tensor::from_literal(lit)?;
            }
            self.state = None;
        } else {
            self.state = Some((outs, opt_lits));
        }
        self.store.step += 1;
        self.state_step = self.store.step;
        self.marshal_seconds += t2.elapsed().as_secs_f64();
        let loss = Tensor::from_literal(&metrics[0])?.as_f32()?[0];
        let correct = Tensor::from_literal(&metrics[1])?.as_f32()?[0];
        let ntok = Tensor::from_literal(&metrics[2])?.as_f32()?[0];
        Ok(StepMetrics { loss, correct, ntok })
    }

    /// Run an executable with `params... + extra` inputs, reusing the
    /// cached parameter literals when fresh.
    fn run_with_params(
        &mut self,
        exe: Rc<Executable>,
        extra: Vec<xla::Literal>,
    ) -> Result<Vec<xla::Literal>> {
        let scratch = self.upload_params_if_stale()?;
        let refs: Vec<&xla::Literal> = if scratch.is_empty() {
            let (p, _) = self.state.as_ref().unwrap();
            p.iter().chain(extra.iter()).collect()
        } else {
            scratch.iter().chain(extra.iter()).collect()
        };
        let t1 = Instant::now();
        let outs = exe.run(&refs)?;
        self.exec_seconds += t1.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Teacher-forced eval on one batch (sums, not means).
    pub fn eval_step(&mut self, client: &Client, batch: &Batch) -> Result<StepMetrics> {
        self.ensure_eval(client)?;
        let exe = Rc::clone(self.eval.as_ref().unwrap());
        let extra = self.batch_literals(batch)?;
        let outs = self.run_with_params(exe, extra)?;
        Ok(StepMetrics {
            loss: Tensor::from_literal(&outs[0])?.as_f32()?[0],
            correct: Tensor::from_literal(&outs[1])?.as_f32()?[0],
            ntok: Tensor::from_literal(&outs[2])?.as_f32()?[0],
        })
    }

    /// Greedy decode: (B, enc_len) token ids -> (B, dec_len) outputs.
    pub fn decode(&mut self, client: &Client, enc_tokens: &[i32]) -> Result<Vec<Vec<i32>>> {
        self.ensure_decode(client)?;
        let cfg = self.artifact.config.clone();
        if enc_tokens.len() != cfg.batch_size * cfg.enc_len {
            bail!("decode batch must be exactly (batch_size, enc_len)");
        }
        let exe = Rc::clone(self.decode.as_ref().unwrap());
        let extra = vec![
            Tensor::i32(vec![cfg.batch_size, cfg.enc_len], enc_tokens.to_vec()).to_literal()?,
        ];
        let outs = self.run_with_params(exe, extra)?;
        let t = Tensor::from_literal(&outs[0])?;
        let data = t.as_i32()?;
        Ok(data.chunks(cfg.dec_len).map(|c| c.to_vec()).collect())
    }

    /// Forward-only latency probe: logits for (enc, dec_in).
    pub fn forward_step(&mut self, client: &Client, batch: &Batch) -> Result<()> {
        self.ensure_forward(client)?;
        let exe = Rc::clone(self.forward.as_ref().unwrap());
        let lits = self.batch_literals(batch)?;
        let extra = vec![lits[0].clone(), lits[1].clone()];
        let _ = self.run_with_params(exe, extra)?;
        Ok(())
    }
}
